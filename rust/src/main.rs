//! `cascade` — CLI for the Cascade CGRA pipelining toolkit.
//!
//! ```text
//! cascade compile --app gaussian --level full [--seed N]   compile one app, print report
//! cascade sta --app harris --level compute                 STA report for a config
//! cascade exp <fig6|fig7|table1|fig8|fig9|fig10|table2|fig11|summary|all> [--fast] [--no-cache]
//! cascade explore [--apps a,b] [--levels l1,l2] [--alphas 1.0,1.35|sweep]
//!                 [--seeds 1,2] [--iters 25,200] [--tracks 3,5] [--regwords 16,32]
//!                 [--fifo 2,4] [--fuse on,off] [--search grid|halving] [--eta N] [--min-budget N]
//!                 [--objective knee|crit|edp|regs] [--shard K/N] [--cache-cap CAP]
//!                 [--threads N] [--power-cap MW] [--fast] [--tiny] [--no-cache]
//!                 [--profile]                              + per-stage compile-time breakdown
//! cascade explore-merge <dir>...                           merge shard runs into one report
//! cascade encode --app gaussian [--level l] [--seed N] [--from-cache|--key HEX] [--out F]
//!                                                          emit a bitstream (from the
//!                                                          artifact store: zero recompiles)
//! cascade cache <stat|gc> [--dir D] [--cache-cap CAP] [--json]
//!                                                          inspect / bound explore_cache/
//! cascade serve [--addr H:P] [--workers N] [--queue N] [--pipeline N] [--cache-dir D]
//!               [--cache-cap CAP] [--gc-every SECS]        compile/encode daemon over the store
//!               [--log PATH|none] [--log-cap CAP]          + structured JSONL request log
//!               [--auth-token T] [--route A1,A2,...]       shared-secret auth; or run as a
//!                                                          front that hash-routes to backends
//! cascade client <ping|stat|compile|encode|metrics|shutdown> [--addr H:P] [point flags]
//!               [--key HEX] [--out F] [--timeout SECS]     drive a running daemon
//!               [--retries N] [--auth-token T]             (retries redial; token for auth)
//! cascade loadgen --app NAME [point flags] [--addr H:P] [--requests N] [--rate R]
//!                 [--conns N] [--seed S] [--spread N]      deterministic open-loop load
//!                 [--encode-every N] [--auth-token T]      generator; writes BENCH_serve.json
//!                 [--out F] [--assert-split]               with p50/p99/p999 latencies
//! cascade bench [--suite s1,s2|compile|pnr|sta|fuse|sim|tables] [--json] [--fast]
//!               [--compare OLD.json [--against NEW.json] [--tolerance PCT]]
//!                                                          run benchmark suites, or diff two
//!                                                          snapshots (non-zero on regression)
//! cascade trace <requests.jsonl> [--id HEX | --top N]      render request-log span trees as
//!                                                          flame tables + critical paths
//! cascade arch                                             print architecture + timing model
//! ```
//!
//! Every command accepts the global `--no-incremental` flag, which switches
//! the placement / routing / STA hot kernels from incremental to
//! full-recompute evaluation. Outputs (bitstreams, reports, cache keys) are
//! byte-identical in both modes — the flag trades compile speed for kernel
//! simplicity when debugging; see `docs/performance.md`.
//!
//! `explore` sweeps the cross-product of compiler axes (app × pipelining
//! level × placement alpha × PnR seed × post-PnR iteration budget) and
//! architecture axes (routing tracks × regfile words × FIFO depth) on a
//! parallel work queue, memoizes compiled artifacts by content hash
//! (repeat runs are served from `results/explore_cache/`), filters points
//! that exceed the optional power cap, and reports the Pareto frontier
//! over (critical-path delay, EDP, pipelining-register count) plus a knee
//! point. Results land in `results/explore.{md,json}`; every completed
//! evaluation is also streamed to `results/explore_partial.jsonl` so long
//! sweeps are inspectable (and, via the disk cache, resumable) mid-run.
//!
//! `--search halving` switches from the exhaustive grid to adaptive
//! successive halving: all candidates are evaluated at a cheap post-PnR
//! budget, each application's cohort keeps its best `1/eta` under
//! `--objective` (power-capped points dropped first), and survivors are
//! promoted up the budget ladder until the full budget — far fewer
//! full-fidelity compiles on spaces where cheap budgets already separate
//! winners.
//!
//! Compiled artifacts persist in `results/explore_cache/artifacts/` (see
//! `docs/cache.md`): `cascade encode --from-cache` turns a cached point
//! into configuration words without recompiling, `--cache-cap` bounds the
//! store with LRU eviction (Pareto/knee survivors are pinned), and
//! `cascade cache stat|gc` inspects or shrinks a store standalone.
//!
//! `serve` keeps one warm session — compile contexts, in-flight compile
//! deduplication, the metrics cache and the fingerprint-verified artifact
//! store — behind a newline-delimited JSON socket protocol (spec:
//! `docs/serve.md`), so many clients share one cache instead of each
//! paying a cold start. Connections are kept alive and pipelined (up to
//! `--pipeline` requests read ahead per connection), `--auth-token`
//! gates every request behind a shared secret (required off loopback),
//! and `--route addr1,addr2,...` runs the daemon as a *front* that
//! hash-routes `compile`/`encode` to N backends by effective cache key —
//! the same partition as `--shard K/N`, so each key has exactly one home
//! cache. `client` drives any of them from the CLI via the keep-alive
//! [`cascade::serve::Client`] API; responses carry the effective cache
//! key and provenance (`fresh|warm_mem|warm_art|warm_rec`), a
//! daemon-served `encode` is byte-identical to offline `cascade encode
//! --from-cache`, and a routed front is payload-transparent. `loadgen`
//! measures a running daemon with a deterministic open-loop schedule and
//! writes latency percentiles to `BENCH_serve.json`.
//!
//! `--shard K/N` distributes either search across processes or machines:
//! the shard evaluates only the points whose effective cache key it owns
//! and writes `results/shard_K_of_N.json` (plus its `explore_cache/` and
//! shard-tagged partial log) instead of the report. `cascade
//! explore-merge <dir>...` then validates that the shard manifests cover
//! the space under one spec fingerprint, unions the caches, concatenates
//! the logs, and emits `results/explore.{md,json}` byte-identical to an
//! unsharded run.

use cascade::experiments;
use cascade::explore::ExploreSpec;
use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: cascade <command> [options]\n\
         commands:\n\
           compile --app <name> [--level <level>] [--seed N]   compile + report\n\
           sta     --app <name> [--level <level>] [--seed N]   timing report\n\
           exp     <id|all> [--fast] [--seed N] [--no-cache]   regenerate paper tables/figures\n\
           explore [--apps a,b] [--levels l1,l2] [--alphas x,y|sweep] [--seeds 1,2]\n\
                   [--iters 25,200] [--tracks 3,5] [--regwords 16,32] [--fifo 2,4]\n\
                   [--fuse on,off]\n\
                   [--search grid|halving] [--eta N] [--min-budget N]\n\
                   [--objective knee|crit|edp|regs] [--shard K/N]\n\
                   [--threads N] [--power-cap MW] [--fast] [--tiny]\n\
                   [--no-cache] [--cache-cap CAP] [--profile]  design-space exploration\n\
                                                                (--profile appends a per-stage\n\
                                                                compile-time breakdown)\n\
           explore-merge <dir>...                               merge shard manifests + caches\n\
                                                                into one results/explore report\n\
           encode  --app <name> [--level <level>] [--seed N] [--alpha X] [--iters N]\n\
                   [--tracks N] [--regwords N] [--fifo N] [--fuse on|off] [--fast] [--tiny]\n\
                   [--from-cache | --key HEX] [--out FILE]     emit bitstream config words;\n\
                                                                --from-cache loads the compiled\n\
                                                                artifact (zero recompiles)\n\
           cache   <stat|gc> [--dir DIR] [--cache-cap CAP]     artifact-store statistics / GC\n\
                   [--json]                                     (CAP: bytes, 512K/8M/1G, or Nn;\n\
                                                                stat --json is machine-readable)\n\
           serve   [--addr HOST:PORT] [--workers N] [--queue N] [--pipeline N]\n\
                   [--cache-dir DIR] [--cache-cap CAP]          long-running compile/encode\n\
                   [--gc-every SECS] [--log PATH|none]          daemon over the artifact store\n\
                   [--log-cap CAP] [--auth-token TOKEN]         (NDJSON protocol, docs/serve.md;\n\
                   [--route ADDR1,ADDR2,...]                    --route runs a front that hash-\n\
                                                                routes to backends by cache key;\n\
                                                                --auth-token gates every request\n\
                                                                and is required off loopback)\n\
           client  <ping|stat|compile|encode|metrics|shutdown> [--addr HOST:PORT]\n\
                   [point flags as for encode] [--key HEX]      drive a running serve daemon;\n\
                   [--out FILE] [--timeout SECS]                encode writes the bitstream file,\n\
                   [--retries N] [--auth-token TOKEN]           metrics prints the exposition\n\
           loadgen --app NAME [point flags] [--addr HOST:PORT] [--requests N] [--rate R]\n\
                   [--conns N] [--seed S] [--spread N]          deterministic open-loop load\n\
                   [--encode-every N] [--timeout SECS]          generator against a daemon or\n\
                   [--auth-token TOKEN] [--out FILE]            front; prints p50/p99/p999 and\n\
                   [--assert-split]                             writes BENCH_serve.json\n\
           bench   [--suite s1,s2,...] [--json] [--fast]        run benchmark suite(s); --json\n\
                   [--compare OLD.json [--against NEW.json]     writes BENCH_<suite>.json;\n\
                   [--tolerance PCT]]                           --compare diffs two snapshots\n\
                                                                and exits non-zero on regression\n\
           trace   <requests.jsonl> [--id HEX | --top N]        render request-log span trees:\n\
                                                                flame table, critical path,\n\
                                                                per-hop attribution\n\
           arch                                                 architecture + timing summary\n\
         global: [--no-incremental]                             full-recompute PnR/STA kernels\n\
                                                                (byte-identical outputs; see\n\
                                                                docs/performance.md)\n\
         levels: {}\n\
         apps: {}",
        PipelineConfig::LEVEL_NAMES.join(" "),
        cascade::apps::APP_NAMES.join(" ")
    );
    std::process::exit(2);
}

fn level(name: &str) -> PipelineConfig {
    PipelineConfig::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown level '{name}'");
        std::process::exit(2);
    })
}

fn app_by_name(name: &str) -> cascade::apps::App {
    cascade::apps::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown app '{name}'");
        std::process::exit(2);
    })
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(1)
}

/// Parse `--search grid|halving` plus the halving knobs (`--eta`,
/// `--objective`, `--min-budget`).
fn search_kind(args: &Args) -> Result<cascade::explore::SearchKind, String> {
    use cascade::explore::{HalvingParams, Objective, SearchKind};
    match args.opt_or("search", "grid") {
        "grid" => Ok(SearchKind::Grid),
        "halving" => {
            let defaults = HalvingParams::default();
            let parse_num = |name: &str, default: usize| -> Result<usize, String> {
                match args.opt(name) {
                    Some(s) => s.parse().map_err(|_| format!("bad --{name} value '{s}'")),
                    None => Ok(default),
                }
            };
            let objective = match args.opt("objective") {
                Some(o) => Objective::parse(o)?,
                None => defaults.objective,
            };
            let p = HalvingParams {
                eta: parse_num("eta", defaults.eta)?,
                min_budget: parse_num("min-budget", defaults.min_budget)?,
                objective,
            };
            p.validate()?;
            Ok(SearchKind::Halving(p))
        }
        other => Err(format!("unknown --search '{other}' (grid|halving)")),
    }
}

/// `cascade encode`: resolve one exploration point (the same axis flags as
/// `explore`, single-valued) to its effective cache key, then emit its
/// bitstream. `--from-cache` rehydrates the compiled artifact from
/// `results/explore_cache/artifacts/` — fingerprint-verified, zero
/// recompiles — and is byte-identical to encoding a fresh compile of the
/// same point; `--key HEX` addresses the store directly. A fresh compile
/// (no `--from-cache`) stores its artifact, warming the cache.
///
/// The point flags resolve through the one shared
/// [`cascade::serve::proto::PointQuery`] vocabulary, so this command, the
/// serve daemon and `cascade client` always derive the same effective key.
fn encode_cmd(args: &Args) -> Result<(), String> {
    use cascade::arch::params::ArchParams;
    use cascade::explore::{runner, DiskCache};
    use cascade::serve::proto::PointQuery;

    let dc = DiskCache::open_default();
    if let Some(hex) = args.opt("key") {
        let key =
            u64::from_str_radix(hex, 16).map_err(|_| format!("bad --key '{hex}' (hex)"))?;
        let expect = dc.load(key).map(|m| m.artifact_fp);
        let c = dc.artifacts().load(key, expect).ok_or_else(|| no_artifact(&dc, key))?;
        println!("encode: artifact {key:016x} rehydrated (0 recompiles)");
        return write_bitstream(&c, key, args, true);
    }

    let (spec, point) = PointQuery::from_args(args)?.resolve()?;
    let base = ArchParams::paper();
    let (cfg, arch, key) = runner::effective_point(&spec, &base, &point);

    if args.flag("from-cache") {
        let expect = dc.load(key).map(|m| m.artifact_fp);
        let c = dc.artifacts().load(key, expect).ok_or_else(|| no_artifact(&dc, key))?;
        println!("encode: {} -> artifact {key:016x} rehydrated (0 recompiles)", point.label());
        write_bitstream(&c, key, args, true)
    } else {
        println!("building compile context ({}x{} array, timing model)...", arch.cols, arch.rows);
        let ctx = CompileCtx::new(arch);
        let c = runner::compile_effective(&spec, &point, &cfg, &ctx)?;
        dc.artifacts().store(key, &c);
        println!("encode: {} compiled fresh; artifact stored as {key:016x}", point.label());
        write_bitstream(&c, key, args, false)
    }
}

fn no_artifact(dc: &cascade::explore::DiskCache, key: u64) -> String {
    format!(
        "no valid compiled artifact for key {key:016x} in {} — run `cascade explore` (or \
         `cascade encode` without --from-cache) first; a torn file is reported rejected and \
         must be recompiled",
        dc.artifacts().dir().display()
    )
}

fn write_bitstream(
    c: &cascade::pipeline::Compiled,
    key: u64,
    args: &Args,
    from_cache: bool,
) -> Result<(), String> {
    let bs = cascade::sim::encode::encode_compiled(c);
    let out = args
        .opt("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(format!("results/bitstream_{key:016x}.txt")));
    if let Some(dir) = out.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&out, bs.to_text())
        .map_err(|e| format!("cannot write {}: {e}", out.display()))?;
    println!(
        "bitstream: {} configuration word(s) -> {}{}",
        bs.len(),
        out.display(),
        if from_cache { " (served from the artifact store)" } else { "" }
    );
    Ok(())
}

/// `cascade cache stat|gc`: inspect or bound an `explore_cache/` directory
/// (the default one, or `--dir`).
fn cache_cmd(args: &Args) -> Result<(), String> {
    use cascade::explore::{CacheCap, DiskCache};
    let sub = args
        .positionals
        .get(1)
        .map(|s| s.as_str())
        .ok_or("cache: expected a subcommand (stat|gc)")?;
    let dir = args
        .opt("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(DiskCache::default_dir);
    let dc = DiskCache::at(&dir);
    match sub {
        "stat" => {
            if args.flag("json") {
                // The same formatter the serve daemon's `stat` response
                // uses — scripts can consume either interchangeably.
                println!("{}", dc.stat_json().to_string_pretty());
            } else {
                println!("{}", dc.stat_string());
            }
            Ok(())
        }
        "gc" => {
            let cap_s = args.opt("cache-cap").ok_or("cache gc: --cache-cap required")?;
            let cap = CacheCap::parse(cap_s)?;
            println!("cache gc: {}", dc.artifacts().gc(&cap).summary());
            println!("{}", dc.stat_string());
            Ok(())
        }
        other => Err(format!("unknown cache subcommand '{other}' (stat|gc)")),
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // Global escape hatch: run the PnR/STA hot kernels in full-recompute
    // mode. Outputs are byte-identical either way (docs/performance.md);
    // this only trades compile speed for simplicity when debugging.
    if args.flag("no-incremental") {
        cascade::pnr::IncrementalCfg::off().install();
    }
    let Some(cmd) = args.positionals.first().map(|s| s.as_str()) else { usage() };
    let seed = args.opt_u64("seed", 3);

    match cmd {
        "compile" | "sta" => {
            let app_name = args.opt("app").unwrap_or_else(|| usage());
            let cfg = level(args.opt_or("level", "full"));
            let app = app_by_name(app_name);
            println!("building compile context (32x16 array, timing model)...");
            let ctx = CompileCtx::paper();
            let t0 = std::time::Instant::now();
            let c = match compile(&app, &ctx, &cfg, seed) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("compile failed: {e}");
                    std::process::exit(1);
                }
            };
            println!("compiled '{app_name}' in {:.2?}", t0.elapsed());
            println!("  nodes: {} | edges: {}", c.design.dfg.nodes.len(), c.design.dfg.edges.len());
            println!(
                "  utilization: {:.1}% (PE {}/{}, MEM {}/{})",
                c.map_report.utilization() * 100.0,
                c.map_report.pe_used,
                c.map_report.pe_capacity,
                c.map_report.mem_used,
                c.map_report.mem_capacity
            );
            let (sb, rf, fifos) = c.design.pipelining_resources();
            println!("  pipelining: {} SB regs, {} RF words, {} FIFO stages", sb, rf, fifos);
            println!(
                "  critical path: {:.2} ns -> fmax {:.0} MHz ({} timing segments)",
                c.sta.period_ps / 1000.0,
                c.fmax_mhz(),
                c.sta.num_segments
            );
            if cmd == "compile" {
                println!(
                    "  schedule: {} cycles/frame (fill latency {}) -> runtime {:.3} ms",
                    c.schedule.total_cycles,
                    c.schedule.fill_latency,
                    c.runtime_ms()
                );
                let p = cascade::sim::power::estimate(
                    &c.design,
                    c.fmax_mhz(),
                    &cascade::sim::power::EnergyModel::default(),
                );
                println!(
                    "  power: {:.0} mW ({:.2} nJ/cycle) | EDP {:.4} mJ*ms",
                    p.total_mw(),
                    p.energy_per_cycle_nj,
                    p.edp(c.runtime_ms())
                );
            }
        }
        "exp" => {
            let id = args.positionals.get(1).map(|s| s.as_str()).unwrap_or("all");
            let fast = args.flag("fast");
            println!("building compile context (32x16 array, timing model)...");
            let ctx = CompileCtx::paper();
            if let Err(e) = experiments::run(id, &ctx, fast, seed, !args.flag("no-cache")) {
                eprintln!("experiment failed: {e}");
                std::process::exit(1);
            }
        }
        "explore" => {
            let spec = match ExploreSpec::from_args(&args) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let search = match search_kind(&args) {
                Ok(k) => k,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let shard = match args.opt("shard").map(cascade::explore::ShardSpec::parse) {
                None => None,
                Some(Ok(s)) => Some(s),
                Some(Err(e)) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let cache_cap = match args.opt("cache-cap").map(cascade::explore::CacheCap::parse) {
                None => None,
                Some(Ok(c)) => Some(c),
                Some(Err(e)) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let threads = args.opt_usize("threads", default_threads());
            println!("building compile context (32x16 array, timing model)...");
            let ctx = CompileCtx::paper();
            if let Err(e) = cascade::explore::run_cli(
                &spec,
                &ctx,
                threads,
                !args.flag("no-cache"),
                &search,
                shard.as_ref(),
                cache_cap.as_ref(),
                args.flag("profile"),
            ) {
                eprintln!("explore failed: {e}");
                std::process::exit(1);
            }
        }
        "encode" => {
            if let Err(e) = encode_cmd(&args) {
                eprintln!("encode failed: {e}");
                std::process::exit(1);
            }
        }
        "cache" => {
            if let Err(e) = cache_cmd(&args) {
                eprintln!("cache failed: {e}");
                std::process::exit(1);
            }
        }
        "serve" => {
            if let Err(e) = cascade::serve::serve_cli(&args) {
                eprintln!("serve failed: {e}");
                std::process::exit(1);
            }
        }
        "client" => {
            if let Err(e) = cascade::serve::client::run_cli(&args) {
                eprintln!("client failed: {e}");
                std::process::exit(1);
            }
        }
        "loadgen" => {
            if let Err(e) = cascade::serve::loadgen::run_cli(&args) {
                eprintln!("loadgen failed: {e}");
                std::process::exit(1);
            }
        }
        "bench" => {
            if let Err(e) = cascade::benchsuite::bench_cli(&args) {
                eprintln!("bench failed: {e}");
                std::process::exit(1);
            }
        }
        "trace" => {
            if let Err(e) = cascade::obs::traceview::trace_cli(&args) {
                eprintln!("trace failed: {e}");
                std::process::exit(1);
            }
        }
        "explore-merge" => {
            let dirs: Vec<std::path::PathBuf> =
                args.positionals[1..].iter().map(std::path::PathBuf::from).collect();
            if dirs.is_empty() {
                eprintln!("explore-merge: at least one shard directory required");
                std::process::exit(2);
            }
            // No compile context: the merge re-derives keys from manifest
            // specs and loads metrics from the unioned cache.
            if let Err(e) = cascade::explore::merge_cli(&dirs) {
                eprintln!("explore-merge failed: {e}");
                std::process::exit(1);
            }
        }
        "arch" => {
            let ctx = CompileCtx::paper();
            let (pe, mem) = ctx.arch.core_tile_counts();
            println!(
                "array: {}x{} ({} PE, {} MEM, {} IO tiles)",
                ctx.arch.cols, ctx.arch.rows, pe, mem, ctx.arch.cols
            );
            println!(
                "interconnect: {} tracks/side/layer, {} RRG nodes, {} edges",
                ctx.arch.tracks,
                ctx.graph.num_nodes(),
                ctx.graph.num_edges()
            );
            println!("timing model ({} characterized path classes):", ctx.lib.records.len());
            for r in ctx.lib.records.iter().take(12) {
                println!(
                    "  {:?} {:?} {}: {} ps",
                    r.class,
                    r.tile_kind,
                    if r.horizontal { "H" } else { "V" },
                    r.delay_ps
                );
            }
            println!("  ... ({} more)", ctx.lib.records.len().saturating_sub(12));
            println!("max clock-skew margin: {} ps", ctx.lib.max_skew_margin_ps());
        }
        _ => usage(),
    }
}
