//! `cascade` — CLI for the Cascade CGRA pipelining toolkit.
//!
//! ```text
//! cascade compile --app gaussian --level full [--seed N]   compile one app, print report
//! cascade sta --app harris --level compute                 STA report for a config
//! cascade exp <fig6|fig7|table1|fig8|fig9|fig10|table2|fig11|summary|all> [--fast]
//! cascade arch                                             print architecture + timing model
//! ```

use cascade::experiments;
use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: cascade <command> [options]\n\
         commands:\n\
           compile --app <name> [--level <level>] [--seed N]   compile + report\n\
           sta     --app <name> [--level <level>] [--seed N]   timing report\n\
           exp     <id|all> [--fast] [--seed N]                regenerate paper tables/figures\n\
           arch                                                 architecture + timing model summary\n\
         levels: none compute broadcast placement postpnr all-software full\n\
         apps: gaussian unsharp camera harris resnet vec_elemadd mat_elemmul mttkrp ttv"
    );
    std::process::exit(2);
}

fn level(name: &str) -> PipelineConfig {
    match name {
        "none" => PipelineConfig::none(),
        "compute" => PipelineConfig::compute_only(),
        "broadcast" => PipelineConfig::with_broadcast(),
        "placement" => PipelineConfig::with_placement(),
        "postpnr" => PipelineConfig::with_postpnr(),
        "all-software" => PipelineConfig::all_software(),
        "full" => PipelineConfig::full(),
        other => {
            eprintln!("unknown level '{other}'");
            std::process::exit(2);
        }
    }
}

fn app_by_name(name: &str) -> cascade::apps::App {
    match name {
        "gaussian" => cascade::apps::dense::gaussian(6400, 4800, 16),
        "unsharp" => cascade::apps::dense::unsharp(1536, 2560, 4),
        "camera" => cascade::apps::dense::camera(2560, 1920, 4),
        "harris" => cascade::apps::dense::harris(1530, 2554, 4),
        "resnet" => cascade::apps::dense::resnet_conv5x(),
        "vec_elemadd" => cascade::apps::sparse::vec_elemadd(4096, 0.25),
        "mat_elemmul" => cascade::apps::sparse::mat_elemmul(128, 128, 0.1),
        "mttkrp" => cascade::apps::sparse::tensor_mttkrp(32, 32, 32, 8, 0.05),
        "ttv" => cascade::apps::sparse::tensor_ttv(48, 48, 48, 0.05),
        other => {
            eprintln!("unknown app '{other}'");
            std::process::exit(2);
        }
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let Some(cmd) = args.positionals.first().map(|s| s.as_str()) else { usage() };
    let seed = args.opt_u64("seed", 3);

    match cmd {
        "compile" | "sta" => {
            let app_name = args.opt("app").unwrap_or_else(|| usage());
            let cfg = level(args.opt_or("level", "full"));
            let app = app_by_name(app_name);
            println!("building compile context (32x16 array, timing model)...");
            let ctx = CompileCtx::paper();
            let t0 = std::time::Instant::now();
            let c = match compile(&app, &ctx, &cfg, seed) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("compile failed: {e}");
                    std::process::exit(1);
                }
            };
            println!("compiled '{app_name}' in {:.2?}", t0.elapsed());
            println!("  nodes: {} | edges: {}", c.design.dfg.nodes.len(), c.design.dfg.edges.len());
            println!(
                "  utilization: {:.1}% (PE {}/{}, MEM {}/{})",
                c.map_report.utilization() * 100.0,
                c.map_report.pe_used,
                c.map_report.pe_capacity,
                c.map_report.mem_used,
                c.map_report.mem_capacity
            );
            let (sb, rf, fifos) = c.design.pipelining_resources();
            println!("  pipelining: {} SB regs, {} RF words, {} FIFO stages", sb, rf, fifos);
            println!(
                "  critical path: {:.2} ns -> fmax {:.0} MHz ({} timing segments)",
                c.sta.period_ps / 1000.0,
                c.fmax_mhz(),
                c.sta.num_segments
            );
            if cmd == "compile" {
                println!(
                    "  schedule: {} cycles/frame (fill latency {}) -> runtime {:.3} ms",
                    c.schedule.total_cycles,
                    c.schedule.fill_latency,
                    c.runtime_ms()
                );
                let p = cascade::sim::power::estimate(
                    &c.design,
                    c.fmax_mhz(),
                    &cascade::sim::power::EnergyModel::default(),
                );
                println!(
                    "  power: {:.0} mW ({:.2} nJ/cycle) | EDP {:.4} mJ*ms",
                    p.total_mw(),
                    p.energy_per_cycle_nj,
                    p.edp(c.runtime_ms())
                );
            }
        }
        "exp" => {
            let id = args.positionals.get(1).map(|s| s.as_str()).unwrap_or("all");
            let fast = args.flag("fast");
            println!("building compile context (32x16 array, timing model)...");
            let ctx = CompileCtx::paper();
            if let Err(e) = experiments::run(id, &ctx, fast, seed) {
                eprintln!("experiment failed: {e}");
                std::process::exit(1);
            }
        }
        "arch" => {
            let ctx = CompileCtx::paper();
            let (pe, mem) = ctx.arch.core_tile_counts();
            println!("array: {}x{} ({} PE, {} MEM, {} IO tiles)", ctx.arch.cols, ctx.arch.rows, pe, mem, ctx.arch.cols);
            println!(
                "interconnect: {} tracks/side/layer, {} RRG nodes, {} edges",
                ctx.arch.tracks,
                ctx.graph.num_nodes(),
                ctx.graph.num_edges()
            );
            println!("timing model ({} characterized path classes):", ctx.lib.records.len());
            for r in ctx.lib.records.iter().take(12) {
                println!(
                    "  {:?} {:?} {}: {} ps",
                    r.class,
                    r.tile_kind,
                    if r.horizontal { "H" } else { "V" },
                    r.delay_ps
                );
            }
            println!("  ... ({} more)", ctx.lib.records.len().saturating_sub(12));
            println!("max clock-skew margin: {} ps", ctx.lib.max_skew_margin_ps());
        }
        _ => usage(),
    }
}
