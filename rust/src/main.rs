//! `cascade` — CLI for the Cascade CGRA pipelining toolkit.
//!
//! ```text
//! cascade compile --app gaussian --level full [--seed N]   compile one app, print report
//! cascade sta --app harris --level compute                 STA report for a config
//! cascade exp <fig6|fig7|table1|fig8|fig9|fig10|table2|fig11|summary|all> [--fast] [--no-cache]
//! cascade explore [--apps a,b] [--levels l1,l2] [--alphas 1.0,1.35|sweep]
//!                 [--seeds 1,2] [--iters 25,200] [--tracks 3,5] [--regwords 16,32]
//!                 [--fifo 2,4] [--search grid|halving] [--eta N] [--min-budget N]
//!                 [--objective knee|crit|edp|regs] [--shard K/N]
//!                 [--threads N] [--power-cap MW] [--fast] [--tiny] [--no-cache]
//! cascade explore-merge <dir>...                           merge shard runs into one report
//! cascade arch                                             print architecture + timing model
//! ```
//!
//! `explore` sweeps the cross-product of compiler axes (app × pipelining
//! level × placement alpha × PnR seed × post-PnR iteration budget) and
//! architecture axes (routing tracks × regfile words × FIFO depth) on a
//! parallel work queue, memoizes compiled artifacts by content hash
//! (repeat runs are served from `results/explore_cache/`), filters points
//! that exceed the optional power cap, and reports the Pareto frontier
//! over (critical-path delay, EDP, pipelining-register count) plus a knee
//! point. Results land in `results/explore.{md,json}`; every completed
//! evaluation is also streamed to `results/explore_partial.jsonl` so long
//! sweeps are inspectable (and, via the disk cache, resumable) mid-run.
//!
//! `--search halving` switches from the exhaustive grid to adaptive
//! successive halving: all candidates are evaluated at a cheap post-PnR
//! budget, each application's cohort keeps its best `1/eta` under
//! `--objective` (power-capped points dropped first), and survivors are
//! promoted up the budget ladder until the full budget — far fewer
//! full-fidelity compiles on spaces where cheap budgets already separate
//! winners.
//!
//! `--shard K/N` distributes either search across processes or machines:
//! the shard evaluates only the points whose effective cache key it owns
//! and writes `results/shard_K_of_N.json` (plus its `explore_cache/` and
//! shard-tagged partial log) instead of the report. `cascade
//! explore-merge <dir>...` then validates that the shard manifests cover
//! the space under one spec fingerprint, unions the caches, concatenates
//! the logs, and emits `results/explore.{md,json}` byte-identical to an
//! unsharded run.

use cascade::experiments;
use cascade::explore::ExploreSpec;
use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: cascade <command> [options]\n\
         commands:\n\
           compile --app <name> [--level <level>] [--seed N]   compile + report\n\
           sta     --app <name> [--level <level>] [--seed N]   timing report\n\
           exp     <id|all> [--fast] [--seed N] [--no-cache]   regenerate paper tables/figures\n\
           explore [--apps a,b] [--levels l1,l2] [--alphas x,y|sweep] [--seeds 1,2]\n\
                   [--iters 25,200] [--tracks 3,5] [--regwords 16,32] [--fifo 2,4]\n\
                   [--search grid|halving] [--eta N] [--min-budget N]\n\
                   [--objective knee|crit|edp|regs] [--shard K/N]\n\
                   [--threads N] [--power-cap MW] [--fast] [--tiny]\n\
                   [--no-cache]                                design-space exploration\n\
           explore-merge <dir>...                               merge shard manifests + caches\n\
                                                                into one results/explore report\n\
           arch                                                 architecture + timing summary\n\
         levels: {}\n\
         apps: {}",
        PipelineConfig::LEVEL_NAMES.join(" "),
        cascade::apps::APP_NAMES.join(" ")
    );
    std::process::exit(2);
}

fn level(name: &str) -> PipelineConfig {
    PipelineConfig::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown level '{name}'");
        std::process::exit(2);
    })
}

fn app_by_name(name: &str) -> cascade::apps::App {
    cascade::apps::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown app '{name}'");
        std::process::exit(2);
    })
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(1)
}

/// Parse `--search grid|halving` plus the halving knobs (`--eta`,
/// `--objective`, `--min-budget`).
fn search_kind(args: &Args) -> Result<cascade::explore::SearchKind, String> {
    use cascade::explore::{HalvingParams, Objective, SearchKind};
    match args.opt_or("search", "grid") {
        "grid" => Ok(SearchKind::Grid),
        "halving" => {
            let defaults = HalvingParams::default();
            let parse_num = |name: &str, default: usize| -> Result<usize, String> {
                match args.opt(name) {
                    Some(s) => s.parse().map_err(|_| format!("bad --{name} value '{s}'")),
                    None => Ok(default),
                }
            };
            let objective = match args.opt("objective") {
                Some(o) => Objective::parse(o)?,
                None => defaults.objective,
            };
            let p = HalvingParams {
                eta: parse_num("eta", defaults.eta)?,
                min_budget: parse_num("min-budget", defaults.min_budget)?,
                objective,
            };
            p.validate()?;
            Ok(SearchKind::Halving(p))
        }
        other => Err(format!("unknown --search '{other}' (grid|halving)")),
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let Some(cmd) = args.positionals.first().map(|s| s.as_str()) else { usage() };
    let seed = args.opt_u64("seed", 3);

    match cmd {
        "compile" | "sta" => {
            let app_name = args.opt("app").unwrap_or_else(|| usage());
            let cfg = level(args.opt_or("level", "full"));
            let app = app_by_name(app_name);
            println!("building compile context (32x16 array, timing model)...");
            let ctx = CompileCtx::paper();
            let t0 = std::time::Instant::now();
            let c = match compile(&app, &ctx, &cfg, seed) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("compile failed: {e}");
                    std::process::exit(1);
                }
            };
            println!("compiled '{app_name}' in {:.2?}", t0.elapsed());
            println!("  nodes: {} | edges: {}", c.design.dfg.nodes.len(), c.design.dfg.edges.len());
            println!(
                "  utilization: {:.1}% (PE {}/{}, MEM {}/{})",
                c.map_report.utilization() * 100.0,
                c.map_report.pe_used,
                c.map_report.pe_capacity,
                c.map_report.mem_used,
                c.map_report.mem_capacity
            );
            let (sb, rf, fifos) = c.design.pipelining_resources();
            println!("  pipelining: {} SB regs, {} RF words, {} FIFO stages", sb, rf, fifos);
            println!(
                "  critical path: {:.2} ns -> fmax {:.0} MHz ({} timing segments)",
                c.sta.period_ps / 1000.0,
                c.fmax_mhz(),
                c.sta.num_segments
            );
            if cmd == "compile" {
                println!(
                    "  schedule: {} cycles/frame (fill latency {}) -> runtime {:.3} ms",
                    c.schedule.total_cycles,
                    c.schedule.fill_latency,
                    c.runtime_ms()
                );
                let p = cascade::sim::power::estimate(
                    &c.design,
                    c.fmax_mhz(),
                    &cascade::sim::power::EnergyModel::default(),
                );
                println!(
                    "  power: {:.0} mW ({:.2} nJ/cycle) | EDP {:.4} mJ*ms",
                    p.total_mw(),
                    p.energy_per_cycle_nj,
                    p.edp(c.runtime_ms())
                );
            }
        }
        "exp" => {
            let id = args.positionals.get(1).map(|s| s.as_str()).unwrap_or("all");
            let fast = args.flag("fast");
            println!("building compile context (32x16 array, timing model)...");
            let ctx = CompileCtx::paper();
            if let Err(e) = experiments::run(id, &ctx, fast, seed, !args.flag("no-cache")) {
                eprintln!("experiment failed: {e}");
                std::process::exit(1);
            }
        }
        "explore" => {
            let spec = match ExploreSpec::from_args(&args) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let search = match search_kind(&args) {
                Ok(k) => k,
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let shard = match args.opt("shard").map(cascade::explore::ShardSpec::parse) {
                None => None,
                Some(Ok(s)) => Some(s),
                Some(Err(e)) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            };
            let threads = args.opt_usize("threads", default_threads());
            println!("building compile context (32x16 array, timing model)...");
            let ctx = CompileCtx::paper();
            if let Err(e) = cascade::explore::run_cli(
                &spec,
                &ctx,
                threads,
                !args.flag("no-cache"),
                &search,
                shard.as_ref(),
            ) {
                eprintln!("explore failed: {e}");
                std::process::exit(1);
            }
        }
        "explore-merge" => {
            let dirs: Vec<std::path::PathBuf> =
                args.positionals[1..].iter().map(std::path::PathBuf::from).collect();
            if dirs.is_empty() {
                eprintln!("explore-merge: at least one shard directory required");
                std::process::exit(2);
            }
            // No compile context: the merge re-derives keys from manifest
            // specs and loads metrics from the unioned cache.
            if let Err(e) = cascade::explore::merge_cli(&dirs) {
                eprintln!("explore-merge failed: {e}");
                std::process::exit(1);
            }
        }
        "arch" => {
            let ctx = CompileCtx::paper();
            let (pe, mem) = ctx.arch.core_tile_counts();
            println!(
                "array: {}x{} ({} PE, {} MEM, {} IO tiles)",
                ctx.arch.cols, ctx.arch.rows, pe, mem, ctx.arch.cols
            );
            println!(
                "interconnect: {} tracks/side/layer, {} RRG nodes, {} edges",
                ctx.arch.tracks,
                ctx.graph.num_nodes(),
                ctx.graph.num_edges()
            );
            println!("timing model ({} characterized path classes):", ctx.lib.records.len());
            for r in ctx.lib.records.iter().take(12) {
                println!(
                    "  {:?} {:?} {}: {} ps",
                    r.class,
                    r.tile_kind,
                    if r.horizontal { "H" } else { "V" },
                    r.delay_ps
                );
            }
            println!("  ... ({} more)", ctx.lib.records.len().saturating_sub(12));
            println!("max clock-skew margin: {} ps", ctx.lib.max_skew_margin_ps());
        }
        _ => usage(),
    }
}
