//! In-house substrates.
//!
//! The build environment is fully offline with only the `xla` crate (and its
//! dependency closure) vendored, so every general-purpose utility the
//! toolkit needs — deterministic PRNG, JSON emission, a property-testing
//! mini-framework, statistics, and a micro-benchmark harness — is
//! implemented here rather than pulled from crates.io.

pub mod rng;
pub mod json;
pub mod stats;
pub mod prop;
pub mod bench;
pub mod cli;

/// Round a float to `digits` decimal places (used by report emitters so the
/// generated tables are stable across runs).
pub fn round_to(x: f64, digits: u32) -> f64 {
    let p = 10f64.powi(digits as i32);
    (x * p).round() / p
}

/// Format a float with engineering-friendly precision: 3 significant-ish
/// digits without scientific notation for the magnitudes we print.
pub fn fmt_sig(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 100.0 {
        format!("{:.0}", x)
    } else if a >= 10.0 {
        format!("{:.1}", x)
    } else if a >= 1.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.3}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_to_works() {
        assert_eq!(round_to(1.23456, 2), 1.23);
        assert_eq!(round_to(1.235, 2), 1.24);
        assert_eq!(round_to(-1.235, 0), -1.0);
    }

    #[test]
    fn fmt_sig_ranges() {
        assert_eq!(fmt_sig(0.0), "0");
        assert_eq!(fmt_sig(123.4), "123");
        assert_eq!(fmt_sig(12.34), "12.3");
        assert_eq!(fmt_sig(1.234), "1.23");
        assert_eq!(fmt_sig(0.1234), "0.123");
    }
}
