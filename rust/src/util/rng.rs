//! Deterministic pseudo-random number generation.
//!
//! All stochastic stages of the toolkit (simulated-annealing placement, the
//! gate-level-simulation surrogate's instance jitter, property-test input
//! generation, synthetic sparse tensors) draw from this splitmix64-based
//! generator so that every experiment is exactly reproducible from a seed.

/// A splitmix64 PRNG. Small state, passes BigCrush-style smoke statistics,
/// and — critically for reproducibility — trivially portable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zeros fixed point of a raw xorshift by running one
        // splitmix step on construction.
        let mut r = Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) };
        r.next_u64();
        r
    }

    /// Next raw 64-bit value (splitmix64 step).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Derive an independent child generator (for parallel or per-instance
    /// streams that must not perturb the parent's sequence).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here:
        // our ranges are tiny relative to 2^64 so modulo bias is negligible,
        // but use 128-bit multiply to keep it exactly uniform-enough.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.gen_range((hi - lo + 1) as usize) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Bernoulli draw with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// adequate for the jitter models).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn gen_normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gen_normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.gen_range(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(13);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
