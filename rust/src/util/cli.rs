//! Tiny command-line argument parser (in-house `clap` replacement).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text. Only what the `cascade`
//! binary needs.

use std::collections::BTreeMap;

/// Parsed arguments: positionals plus key/value options and boolean flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positionals.push(a);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("exp fig7 --verbose");
        assert_eq!(a.positionals, vec!["exp", "fig7"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn options_space_and_equals() {
        let a = parse("compile --app gaussian --alpha=1.4 --seed 7");
        assert_eq!(a.opt("app"), Some("gaussian"));
        assert_eq!(a.opt_f64("alpha", 1.0), 1.4);
        assert_eq!(a.opt_u64("seed", 0), 7);
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = parse("--fast --out x.json");
        assert!(a.flag("fast"));
        assert_eq!(a.opt("out"), Some("x.json"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.opt_or("mode", "all"), "all");
        assert_eq!(a.opt_usize("n", 3), 3);
    }
}
