//! Mini property-testing framework (in-house `proptest` replacement).
//!
//! Provides seeded generators and a `forall` runner with naive shrinking:
//! when a case fails, the runner reports the seed and the case index so the
//! failure is exactly reproducible, and retries with "smaller" sizes when
//! the generator supports it.
//!
//! Usage:
//! ```no_run
//! use cascade::util::prop::{forall, Gen};
//! forall("addition commutes", 100, |g| {
//!     let a = g.int(0, 1000);
//!     let b = g.int(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Generator context handed to each property-test case. Wraps a seeded RNG
/// and a `size` hint that the runner lowers while shrinking.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0.0, 1.0]; generators scale their output magnitude by
    /// this so the runner can search for smaller counterexamples.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Gen {
        Gen { rng: Rng::new(seed), size }
    }

    /// Access the underlying RNG for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in [lo, hi] inclusive, biased towards the low end when the
    /// runner is shrinking (size < 1).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        let span = ((hi - lo) as f64 * self.size).round() as i64;
        self.rng.gen_range_i64(lo, lo + span.max(0))
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.gen_f64_range(lo, lo + (hi - lo) * self.size.max(0.05))
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    /// Vector of values from an element generator; length in [0, max_len]
    /// scaled by size.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one of the options.
    pub fn pick<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        let i = self.rng.gen_range(options.len());
        &options[i]
    }
}

/// Result of a property run, for tests that want to inspect it rather than
/// panic.
#[derive(Debug)]
pub struct PropResult {
    pub cases: usize,
    pub failure: Option<PropFailure>,
}

#[derive(Debug)]
pub struct PropFailure {
    pub seed: u64,
    pub case: usize,
    pub size: f64,
    pub message: String,
}

/// Run `cases` random cases of `body`. Panics with a reproducible report on
/// the first failure, after attempting to re-fail at smaller sizes.
pub fn forall(name: &str, cases: usize, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    if let Some(f) = run_property(cases, &body) {
        panic!(
            "property '{name}' failed: case {} (seed {}, size {:.2}): {}",
            f.case, f.seed, f.size, f.message
        );
    }
}

/// Non-panicking runner used by `forall` and by the framework's own tests.
pub fn run_property(
    cases: usize,
    body: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
) -> Option<PropFailure> {
    // Base seed is fixed: deterministic CI. Derived per-case seeds are
    // independent streams.
    let mut seeder = Rng::new(0xCA5CADE);
    for case in 0..cases {
        let seed = seeder.next_u64();
        if let Some(msg) = fails_at(seed, 1.0, body) {
            // Shrink: retry the same seed at smaller sizes and keep the
            // smallest size that still fails.
            let mut best = (1.0, msg);
            for &size in &[0.05, 0.1, 0.25, 0.5, 0.75] {
                if let Some(m) = fails_at(seed, size, body) {
                    best = (size, m);
                    break;
                }
            }
            return Some(PropFailure { seed, case, size: best.0, message: best.1 });
        }
    }
    None
}

fn fails_at(
    seed: u64,
    size: f64,
    body: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
) -> Option<String> {
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::new(seed, size);
        body(&mut g);
    });
    match result {
        Ok(()) => None,
        Err(e) => Some(panic_message(&e)),
    }
}

fn panic_message(e: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("reverse twice is identity", 50, |g| {
            let xs = g.vec(20, |g| g.int(-100, 100));
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            assert_eq!(xs, ys);
        });
    }

    #[test]
    fn failing_property_reports() {
        let f = run_property(200, &|g: &mut Gen| {
            let x = g.int(0, 1000);
            assert!(x < 900, "found big value {x}");
        });
        let f = f.expect("property should fail");
        assert!(f.message.contains("found big value"));
    }

    #[test]
    fn shrinking_reduces_size() {
        // A property that fails for any input fails at the smallest size too.
        let f = run_property(5, &|_g: &mut Gen| {
            panic!("always fails");
        })
        .unwrap();
        assert!(f.size <= 0.05 + 1e-9);
    }

    #[test]
    fn generators_respect_bounds() {
        forall("int bounds", 100, |g| {
            let v = g.int(3, 9);
            assert!((3..=9).contains(&v));
            let u = g.usize(0, 5);
            assert!(u <= 5);
            let x = g.f64(1.0, 2.0);
            assert!((1.0..2.0).contains(&x));
        });
    }
}
