//! Minimal JSON document model, serializer and parser.
//!
//! Experiment regenerators emit machine-readable JSON next to the
//! human-readable markdown tables; this module is the (offline-environment)
//! replacement for `serde_json`. Construction and serialization cover every
//! report the toolkit writes; the parser exists for the one place the
//! toolkit reads JSON back — `cascade explore-merge` consuming the
//! self-describing shard manifests (`results/shard_K_of_N.json`) written by
//! `cascade explore --shard K/N`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Integers with magnitude below this bound are exactly representable in
/// f64, so they may travel as JSON numbers; anything at or beyond it must
/// use a string encoding (hex keys, decimal constants). The integer
/// accessors ([`Json::as_u64`], [`Json::as_i64`]) and the artifact
/// serializer's number-vs-string decision share this single constant so
/// encodability and decodability can never drift apart.
pub const EXACT_INT_BOUND: i64 = 9_000_000_000_000_000;

/// A JSON value. Object keys are ordered (BTreeMap) so serialized output is
/// deterministic — important for diffable experiment records.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert a key into an object value; panics on non-objects (programmer
    /// error, not data error).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(v) => v.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Parse a JSON document. Strict: one value, no trailing content, no
    /// comments. Errors carry the byte offset of the offending input.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object member lookup; `None` on non-objects and absent keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Remove and return an object member; `None` on non-objects and
    /// absent keys (mirrors [`Json::get`]'s leniency, unlike `set`).
    pub fn remove(&mut self, key: &str) -> Option<Json> {
        match self {
            Json::Obj(m) => m.remove(key),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integral numbers only; `None` for negatives, fractions, and values
    /// beyond f64's exact-integer range (large u64 keys travel as hex
    /// strings for exactly this reason).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.trunc() == *x && *x < EXACT_INT_BOUND as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// Integral numbers of either sign within f64's exact-integer range
    /// (the compiled-artifact serializer stores small signed values —
    /// constants, strides — directly; large u64 keys still travel as hex
    /// strings).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.trunc() == *x && x.abs() < EXACT_INT_BOUND as f64 => {
                Some(*x as i64)
            }
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    // JSON has no Inf/NaN; encode as null like serde_json.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

/// Recursive-descent JSON parser over the input bytes. Byte-oriented
/// scanning is safe because every structural delimiter is ASCII and the
/// input arrived as `&str` (multibyte UTF-8 runs are copied verbatim).
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Nesting bound: manifests are a few levels deep; anything beyond this is
/// garbage and must not recurse the stack away.
const MAX_DEPTH: usize = 128;

impl Parser<'_> {
    fn err(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {msg}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(self.peek(), Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii");
        match text.parse::<f64>() {
            Ok(x) if x.is_finite() => Ok(Json::Num(x)),
            _ => Err(self.err(&format!("bad number '{text}'"))),
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self.peek().and_then(|c| (c as char).to_digit(16));
            match d {
                Some(d) => {
                    v = v * 16 + d;
                    self.pos += 1;
                }
                None => return Err(self.err("bad \\u escape")),
            }
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xd800..0xdc00).contains(&hi) {
                                // High surrogate: a \uXXXX low half must follow.
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if !(0xdc00..0xe000).contains(&lo) {
                                        return Err(self.err("bad low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            match char::from_u32(code) {
                                Some(c) => s.push(c),
                                None => return Err(self.err("bad \\u codepoint")),
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(_) => {
                    // Raw run up to the next delimiter; splits only at ASCII
                    // bytes, so the slice stays valid UTF-8.
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\' && c >= 0x20) {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).expect("utf8"));
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string_compact(), "null");
        assert_eq!(Json::from(true).to_string_compact(), "true");
        assert_eq!(Json::from(3.0).to_string_compact(), "3");
        assert_eq!(Json::from(3.5).to_string_compact(), "3.5");
        assert_eq!(Json::from("hi").to_string_compact(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(
            Json::from("a\"b\\c\nd").to_string_compact(),
            "\"a\\\"b\\\\c\\nd\""
        );
    }

    #[test]
    fn object_deterministic_order() {
        let mut o = Json::obj();
        o.set("zeta", 1u64).set("alpha", 2u64);
        assert_eq!(o.to_string_compact(), "{\"alpha\":2,\"zeta\":1}");
    }

    #[test]
    fn nested_pretty() {
        let mut o = Json::obj();
        o.set("xs", vec![1u64, 2u64]);
        let s = o.to_string_pretty();
        assert!(s.contains("\"xs\": ["));
        assert!(s.lines().count() > 3);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3").unwrap(), Json::Num(3.0));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        assert_eq!(
            Json::parse("\"a\\\"b\\\\c\\nd\\u0041\"").unwrap(),
            Json::Str("a\"b\\c\ndA".into())
        );
        // Multibyte passthrough and a surrogate pair.
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
        assert_eq!(Json::parse("\"\\ud83d\\ude00\"").unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn parse_rejects_malformed_input() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
        assert!(Json::parse("\"\\ud83d\"").is_err(), "lone surrogate must be rejected");
        let deep = "[".repeat(500) + &"]".repeat(500);
        assert!(Json::parse(&deep).is_err(), "depth bound must hold");
    }

    #[test]
    fn parse_round_trips_manifest_shaped_documents() {
        let mut doc = Json::obj();
        doc.set("shard", 1u64)
            .set("of", 3u64)
            .set("fingerprint", "00ab34ffcd120099")
            .set("alphas", vec![1.0, 1.35])
            .set("power_cap_mw", Json::Null)
            .set("fast", true);
        let mut pts = Json::Arr(vec![]);
        let mut p = Json::obj();
        p.set("id", 0u64).set("key", "deadbeef12345678").set("error", Json::Null);
        pts.push(p);
        doc.set("points", pts);
        for text in [doc.to_string_compact(), doc.to_string_pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, doc);
            assert_eq!(back.get("shard").and_then(Json::as_u64), Some(1));
            assert_eq!(back.get("fingerprint").and_then(Json::as_str), Some("00ab34ffcd120099"));
            assert!(back.get("power_cap_mw").unwrap().is_null());
            assert_eq!(back.get("points").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        }
    }

    #[test]
    fn remove_takes_members_and_tolerates_non_objects() {
        let mut j = Json::obj();
        j.set("keep", 1u64).set("drop", "x");
        assert_eq!(j.remove("drop"), Some(Json::Str("x".into())));
        assert_eq!(j.remove("drop"), None, "second remove finds nothing");
        assert_eq!(j.to_string_compact(), "{\"keep\":1}");
        assert_eq!(Json::Null.remove("x"), None);
        assert_eq!(Json::Arr(vec![]).remove("x"), None);
    }

    #[test]
    fn accessors_reject_wrong_shapes() {
        assert_eq!(Json::Null.get("x"), None);
        assert_eq!(Json::from(1.5).as_u64(), None, "fractions are not integers");
        assert_eq!(Json::from(-1.0).as_u64(), None);
        assert_eq!(Json::from(true).as_f64(), None);
        assert_eq!(Json::from("s").as_arr(), None);
        assert_eq!(Json::from(3.0).as_usize(), Some(3));
        assert_eq!(Json::from(-3.0).as_i64(), Some(-3));
        assert_eq!(Json::from(1.5).as_i64(), None);
        assert_eq!(Json::from(true).as_i64(), None);
    }
}
