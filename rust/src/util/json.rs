//! Minimal JSON document model and serializer.
//!
//! Experiment regenerators emit machine-readable JSON next to the
//! human-readable markdown tables; this module is the (offline-environment)
//! replacement for `serde_json`. Only what the toolkit needs is implemented:
//! construction and pretty serialization. No parser is required because all
//! configuration lives in typed Rust (`arch::params`) — the toolkit never
//! reads JSON back.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so serialized output is
/// deterministic — important for diffable experiment records.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert a key into an object value; panics on non-objects (programmer
    /// error, not data error).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(v) => v.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    // JSON has no Inf/NaN; encode as null like serde_json.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string_compact(), "null");
        assert_eq!(Json::from(true).to_string_compact(), "true");
        assert_eq!(Json::from(3.0).to_string_compact(), "3");
        assert_eq!(Json::from(3.5).to_string_compact(), "3.5");
        assert_eq!(Json::from("hi").to_string_compact(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(
            Json::from("a\"b\\c\nd").to_string_compact(),
            "\"a\\\"b\\\\c\\nd\""
        );
    }

    #[test]
    fn object_deterministic_order() {
        let mut o = Json::obj();
        o.set("zeta", 1u64).set("alpha", 2u64);
        assert_eq!(o.to_string_compact(), "{\"alpha\":2,\"zeta\":1}");
    }

    #[test]
    fn nested_pretty() {
        let mut o = Json::obj();
        o.set("xs", vec![1u64, 2u64]);
        let s = o.to_string_pretty();
        assert!(s.contains("\"xs\": ["));
        assert!(s.lines().count() > 3);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string_compact(), "null");
    }
}
