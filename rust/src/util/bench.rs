//! Micro-benchmark harness (in-house `criterion` replacement).
//!
//! `cargo bench` targets use `harness = false` and drive this runner. Each
//! benchmark is warmed up, run for a target wall-clock budget, and reported
//! with median / mean / p10 / p90 per-iteration times. Results are also
//! appended as JSON for the §Perf record in EXPERIMENTS.md.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// One measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("median_ns", self.median_ns)
            .set("mean_ns", self.mean_ns)
            .set("p10_ns", self.p10_ns)
            .set("p90_ns", self.p90_ns);
        o
    }
}

/// Benchmark runner: collects results, prints a table, optionally writes
/// JSON to `results/bench_<suite>.json`.
pub struct Bencher {
    suite: String,
    warmup: Duration,
    budget: Duration,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(suite: &str) -> Bencher {
        // Environment knobs so `make bench-fast` can shrink budgets.
        let ms = |var: &str, default_ms: u64| {
            std::env::var(var)
                .ok()
                .and_then(|s| s.parse::<u64>().ok())
                .map(Duration::from_millis)
                .unwrap_or(Duration::from_millis(default_ms))
        };
        Bencher {
            suite: suite.to_string(),
            warmup: ms("CASCADE_BENCH_WARMUP_MS", 200),
            budget: ms("CASCADE_BENCH_BUDGET_MS", 1500),
            results: Vec::new(),
        }
    }

    /// Time `f`, which should perform one logical iteration and return a
    /// value (returned value is black-boxed to keep the optimizer honest).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.budget || samples_ns.len() < 5 {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() > 2_000_000 {
                break;
            }
        }
        let _ = warm_iters;
        let r = BenchResult {
            name: format!("{}/{}", self.suite, name),
            iters: samples_ns.len(),
            median_ns: stats::median(&samples_ns),
            mean_ns: stats::mean(&samples_ns),
            p10_ns: stats::percentile(&samples_ns, 10.0),
            p90_ns: stats::percentile(&samples_ns, 90.0),
        };
        println!(
            "{:<52} {:>10} iters  median {:>12}  mean {:>12}  p90 {:>12}",
            r.name,
            r.iters,
            fmt_ns(r.median_ns),
            fmt_ns(r.mean_ns),
            fmt_ns(r.p90_ns)
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Everything measured so far, in run order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write the suite's results to `results/bench_<suite>.json`.
    pub fn finish(&self) {
        let mut arr = Json::Arr(vec![]);
        for r in &self.results {
            arr.push(r.to_json());
        }
        let _ = std::fs::create_dir_all("results");
        let path = format!("results/bench_{}.json", self.suite);
        if std::fs::write(&path, arr.to_string_pretty()).is_ok() {
            println!("wrote {path}");
        }
    }
}

/// Opaque value sink — prevents the optimizer from eliding benched work.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.0} ns", ns)
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("CASCADE_BENCH_WARMUP_MS", "1");
        std::env::set_var("CASCADE_BENCH_BUDGET_MS", "5");
        let mut b = Bencher::new("selftest");
        let r = b.bench("sum", || (0..1000u64).sum::<u64>()).clone();
        assert!(r.iters >= 5);
        assert!(r.median_ns > 0.0);
        std::env::remove_var("CASCADE_BENCH_WARMUP_MS");
        std::env::remove_var("CASCADE_BENCH_BUDGET_MS");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
