//! Small statistics helpers shared by the experiment harness, the STA
//! validation (Fig. 6 error statistics) and the micro-benchmark runner.

/// Arithmetic mean. Returns 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean of strictly positive values. Returns 0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min/max over a slice (returns (0,0) for empty input).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_basic() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn minmax() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
    }
}
