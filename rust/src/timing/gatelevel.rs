//! SDF-annotated gate-level-simulation surrogate (paper §VIII-A, Fig. 6).
//!
//! The paper validates its application STA model against SDF-annotated
//! gate-level simulation of the post-PnR netlist, searching for the fastest
//! working clock period at 0.1 ns granularity. We do not have the GF12
//! netlist or VCS, so this module reproduces the *relationship* those two
//! measurements have:
//!
//! * the STA model uses worst-case per-path-class delays and a global
//!   worst-case skew margin;
//! * the simulation sees concrete per-instance delays — at or below the
//!   worst-case corner — and actual (not worst-case) clock skews.
//!
//! We re-time the routed design with deterministic per-instance delay
//! factors (a bounded normal shrink below the worst-case corner) and the
//! delay library's actual per-tile skews, then round the resulting minimum
//! period up to the search granularity. The STA model therefore remains an
//! upper bound (pessimistic), with an average error in the ~10-15 % range
//! at high frequencies — the Fig. 6 behaviour.

use crate::arch::canal::InterconnectGraph;
use crate::arch::params::TileCoord;
use crate::pnr::RoutedDesign;

use super::sta::{analyze_instance, InstanceDelays};

/// Gate-level surrogate knobs.
#[derive(Debug, Clone)]
pub struct GateLevelParams {
    /// Seed for the per-instance delay draw.
    pub seed: u64,
    /// Mean fractional shrink below the worst-case corner (0.08 = -8 %).
    pub mean_shrink: f64,
    /// Std-dev of the shrink.
    pub sigma: f64,
    /// Clock-period search granularity in ps (paper: 0.1 ns).
    pub granularity_ps: f64,
}

impl Default for GateLevelParams {
    fn default() -> Self {
        GateLevelParams { seed: 0xFab, mean_shrink: 0.08, sigma: 0.05, granularity_ps: 100.0 }
    }
}

/// Deterministic per-tile instance delay factor in (0, 1].
fn instance_factor(tile: TileCoord, p: &GateLevelParams) -> f64 {
    let h = (tile.x as u64)
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((tile.y as u64).wrapping_mul(0xD1B54A32D192ED03))
        .wrapping_add(p.seed.wrapping_mul(0x94D049BB133111EB));
    let mut rng = crate::util::rng::Rng::new(h);
    let shrink = rng.gen_normal_ms(p.mean_shrink, p.sigma);
    (1.0 - shrink).clamp(0.75, 1.0)
}

/// "Simulate" the fastest working clock period (ps) of a routed design:
/// minimum per-instance-retimed period, rounded up to the search
/// granularity.
pub fn gate_level_period_ps(
    d: &RoutedDesign,
    graph: &InterconnectGraph,
    p: &GateLevelParams,
) -> f64 {
    let factor = |t: TileCoord| instance_factor(t, p);
    let lib = d.lib.clone();
    let skew = move |t: TileCoord| lib.skew_ps(t) as f64;
    let inst = InstanceDelays { factor: &factor, skew: &skew };
    let cp = analyze_instance(d, graph, &inst);
    (cp.period_ps / p.granularity_ps).ceil() * p.granularity_ps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::delay::{DelayLib, DelayModelParams};
    use crate::arch::params::ArchParams;
    use crate::pnr::{place_and_route, PlaceParams, RouteParams};
    use crate::timing::sta::analyze;

    fn build(app: &crate::apps::App) -> (RoutedDesign, InterconnectGraph) {
        let arch = ArchParams::paper();
        let lib = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut graph = InterconnectGraph::build(&arch);
        graph.annotate_delays(&lib);
        let d = place_and_route(
            &app.dfg,
            &arch,
            &graph,
            &lib,
            &PlaceParams::baseline(3),
            &RouteParams::default(),
        )
        .unwrap();
        (d, graph)
    }

    #[test]
    fn sta_is_pessimistic_bound() {
        for app in [
            crate::apps::dense::gaussian(64, 64, 1),
            crate::apps::dense::unsharp(64, 64, 1),
        ] {
            let (d, graph) = build(&app);
            let sta_period = analyze(&d, &graph).period_ps;
            let gl = gate_level_period_ps(&d, &graph, &GateLevelParams::default());
            // Rounded-up granularity can add at most one grid step.
            assert!(
                gl <= sta_period + 100.0,
                "{}: gate-level {gl} > STA {sta_period}",
                app.name
            );
        }
    }

    #[test]
    fn granularity_respected() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (d, graph) = build(&app);
        let gl = gate_level_period_ps(&d, &graph, &GateLevelParams::default());
        assert_eq!(gl % 100.0, 0.0, "period {gl} not on 0.1ns grid");
    }

    #[test]
    fn deterministic_given_seed() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (d, graph) = build(&app);
        let a = gate_level_period_ps(&d, &graph, &GateLevelParams::default());
        let b = gate_level_period_ps(&d, &graph, &GateLevelParams::default());
        assert_eq!(a, b);
        let c = gate_level_period_ps(
            &d,
            &graph,
            &GateLevelParams { seed: 99, ..GateLevelParams::default() },
        );
        // Different instance draw; usually different but always <= STA.
        let sta = analyze(&d, &graph).period_ps;
        assert!(c <= sta + 100.0);
    }

    #[test]
    fn error_in_expected_band() {
        // Average STA-vs-simulation error should sit in a plausible band
        // (paper: 13 % above 500 MHz) — here just check it is bounded and
        // positive on average.
        let mut errs = Vec::new();
        for (i, app) in crate::apps::small_dense_suite().into_iter().enumerate() {
            let (d, graph) = build(&app);
            let sta = analyze(&d, &graph).period_ps;
            let gl = gate_level_period_ps(
                &d,
                &graph,
                &GateLevelParams { seed: i as u64, ..Default::default() },
            );
            errs.push((sta - gl) / gl);
        }
        let mean = crate::util::stats::mean(&errs);
        assert!(mean > 0.0, "STA should be pessimistic on average: {mean}");
        assert!(mean < 0.5, "error too large: {mean}");
    }
}
