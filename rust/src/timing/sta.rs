//! Application static timing analysis (paper §IV-B).
//!
//! Register-bounded longest-path analysis over a routed design. Timing
//! segments start at a register output (PE input register, SB pipelining
//! register, MEM/accumulator output, IO launch, FIFO) and end at the next
//! register input. The maximum segment delay plus the worst-case clock-skew
//! margin sets the minimum clock period and hence the application's maximum
//! frequency.
//!
//! The analysis records full provenance of the critical segment (the RRG
//! nodes it traverses), which is exactly what post-PnR pipelining (§V-D)
//! needs to decide which switch-box register to enable.

#[allow(unused_imports)]
use crate::arch::canal::{InterconnectGraph, NodeId as RrgNode, NodeKind};
use crate::arch::delay::OpClass;
use crate::arch::params::TileCoord;
use crate::dfg::ir::{EdgeId, Op};
use crate::pnr::netlist::NetKind;
use crate::pnr::RoutedDesign;

/// What terminated a timing segment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SegmentEnd {
    /// Captured by a switch-box pipelining register.
    SbReg,
    /// Captured by a PE input register / register file / FIFO.
    NodeInput { node: u32 },
    /// Captured inside a memory / accumulator / IO tile.
    NodeCore { node: u32 },
}

/// One register-to-register timing segment.
#[derive(Debug, Clone)]
pub struct Segment {
    /// Path delay in ps, including launch clk-q and capture setup.
    pub delay_ps: f64,
    /// Launch tile (for skew) and capture tile.
    pub start_tile: TileCoord,
    pub end_tile: TileCoord,
    /// RRG nodes traversed since the segment's launch register (candidates
    /// for post-PnR register insertion are the unregistered SbOuts here).
    pub nodes: Vec<RrgNode>,
    pub end: SegmentEnd,
}

/// STA result.
#[derive(Debug, Clone)]
pub struct CritPath {
    /// Minimum clock period in ps (critical segment + skew margin).
    pub period_ps: f64,
    pub fmax_mhz: f64,
    /// The critical segment.
    pub segment: Segment,
    /// Number of timing segments analyzed.
    pub num_segments: usize,
}

/// Per-instance delay evaluation used by the gate-level-simulation
/// surrogate; `None` in plain STA mode (worst-case corners + global skew
/// margin).
pub struct InstanceDelays<'a> {
    /// Multiplicative factor on the delay of the RRG edge arriving at a
    /// node / the core delay of a tile.
    pub factor: &'a dyn Fn(TileCoord) -> f64,
    /// Actual clock skew at a tile (ps).
    pub skew: &'a dyn Fn(TileCoord) -> f64,
}

/// Run STA with worst-case corner delays and the global skew margin.
pub fn analyze(d: &RoutedDesign, graph: &InterconnectGraph) -> CritPath {
    analyze_impl(d, graph, None)
}

/// Run STA with per-instance delays (gate-level surrogate mode).
pub fn analyze_instance(
    d: &RoutedDesign,
    graph: &InterconnectGraph,
    inst: &InstanceDelays,
) -> CritPath {
    analyze_impl(d, graph, Some(inst))
}

#[derive(Clone)]
struct SegState {
    start_tile: TileCoord,
    nodes: Vec<RrgNode>,
}

/// Does this edge terminate in a register at the sink (before the sink's
/// combinational core)?
fn sink_registered(d: &RoutedDesign, e: EdgeId) -> bool {
    let edge = d.dfg.edge(e);
    let dst = d.dfg.node(edge.dst);
    if d.rf_delay.get(&e).copied().unwrap_or(0) > 0 {
        return true;
    }
    if edge.fifos > 0 {
        return true;
    }
    match &dst.op {
        Op::Alu { .. } => dst.input_regs,
        // Sparse compute units have FIFOs at every input by default
        // (§VIII-D: "sparse applications use FIFOs at the input of every
        // compute unit, so compute pipelining is applied by default").
        Op::Sparse(_) => true,
        // Memory writes, accumulator and IO capture are registered.
        Op::Delay { .. } | Op::Rom { .. } | Op::Accum { .. } | Op::Output { .. } => true,
        Op::Input { .. } | Op::FlushSrc | Op::Const { .. } => true,
    }
}

fn analyze_impl(
    d: &RoutedDesign,
    graph: &InterconnectGraph,
    inst: Option<&InstanceDelays>,
) -> CritPath {
    let lib = &d.lib;
    let clk_q = lib.clk_q_ps() as f64;
    let setup = lib.setup_ps() as f64;
    let nn = d.dfg.nodes.len();

    let factor = |tile: TileCoord| -> f64 {
        match inst {
            Some(i) => (i.factor)(tile),
            None => 1.0,
        }
    };

    let mut segments: Vec<Segment> = Vec::new();
    // Arrival time at each node output within its current segment.
    let mut out_time = vec![0f64; nn];
    let mut out_seg: Vec<SegState> =
        vec![SegState { start_tile: TileCoord::new(0, 0), nodes: Vec::new() }; nn];
    // Arrival time / segment at each edge's sink CbIn (combinational sinks).
    let ne = d.dfg.edges.len();
    let mut in_time = vec![0f64; ne];
    let mut in_seg: Vec<Option<SegState>> = vec![None; ne];

    let order = d.dfg.topo_order();

    // In-edges per node (B16 and B1 both matter for combinational joins).
    let mut in_edges: Vec<Vec<EdgeId>> = vec![Vec::new(); nn];
    for (ei, e) in d.dfg.edges.iter().enumerate() {
        in_edges[e.dst as usize].push(ei as EdgeId);
    }
    // Nets by source node (Data/Flush walked in topo order; Valid/Ready
    // sources are registered so they can be walked whenever).
    let mut nets_of_src: Vec<Vec<usize>> = vec![Vec::new(); nn];
    for net in &d.nets {
        nets_of_src[net.src as usize].push(net.id);
    }

    for &n in &order {
        let node = &d.dfg.nodes[n as usize];
        let tile = d.placement.pos[n as usize];
        let tfac = factor(tile);

        // --- Node output time within its segment.
        let (t_out, seg) = match &node.op {
            Op::Input { .. } | Op::FlushSrc => (
                clk_q + lib.io_core_ps() as f64 * tfac,
                SegState { start_tile: tile, nodes: Vec::new() },
            ),
            Op::Delay { .. } if node.tile_kind() == crate::arch::params::TileKind::Mem => (
                clk_q + lib.mem_core_ps() as f64 * tfac,
                SegState { start_tile: tile, nodes: Vec::new() },
            ),
            Op::Delay { .. } => (
                // PE register-file shift register: registered output.
                clk_q + lib.pe_core_ps(OpClass::Pass) as f64 * tfac,
                SegState { start_tile: tile, nodes: Vec::new() },
            ),
            Op::Rom { .. } => (
                clk_q + lib.mem_core_ps() as f64 * tfac,
                SegState { start_tile: tile, nodes: Vec::new() },
            ),
            Op::Accum { .. } => (
                clk_q,
                SegState { start_tile: tile, nodes: Vec::new() },
            ),
            Op::Sparse(s) => {
                let class = match s {
                    crate::dfg::ir::SparseOp::Intersect | crate::dfg::ir::SparseOp::Union => {
                        OpClass::Cmp
                    }
                    crate::dfg::ir::SparseOp::SpAlu(a) => a.op_class(),
                    crate::dfg::ir::SparseOp::Reduce => OpClass::Add,
                    crate::dfg::ir::SparseOp::Repeat => OpClass::Logic,
                    crate::dfg::ir::SparseOp::CrdScan { .. }
                    | crate::dfg::ir::SparseOp::ValRead { .. } => OpClass::Pass,
                };
                let core = if node.tile_kind() == crate::arch::params::TileKind::Mem {
                    lib.mem_core_ps() as f64
                } else {
                    lib.pe_core_ps(class) as f64
                };
                (clk_q + core * tfac, SegState { start_tile: tile, nodes: Vec::new() })
            }
            Op::Const { .. } => (clk_q, SegState { start_tile: tile, nodes: Vec::new() }),
            Op::Output { .. } => (clk_q, SegState { start_tile: tile, nodes: Vec::new() }),
            Op::Alu { op, .. } => {
                if node.input_regs {
                    (
                        clk_q + lib.pe_core_ps(op.op_class()) as f64 * tfac,
                        SegState { start_tile: tile, nodes: Vec::new() },
                    )
                } else {
                    // Combinational: continue from the worst input.
                    let mut worst = clk_q;
                    let mut seg = SegState { start_tile: tile, nodes: Vec::new() };
                    for &ei in &in_edges[n as usize] {
                        if sink_registered(d, ei) {
                            continue;
                        }
                        if let Some(s) = &in_seg[ei as usize] {
                            if in_time[ei as usize] > worst {
                                worst = in_time[ei as usize];
                                seg = s.clone();
                            }
                        }
                    }
                    (worst + lib.pe_core_ps(op.op_class()) as f64 * tfac, seg)
                }
            }
        };
        out_time[n as usize] = t_out;
        out_seg[n as usize] = seg;

        // --- Record capture endpoints for registered inputs of this node.
        for &ei in &in_edges[n as usize] {
            if !sink_registered(d, ei) {
                continue;
            }
            // The endpoint was computed during the driver's net walk and
            // stored in in_time/in_seg (we record it here so the capture
            // core delay of this node kind is included).
            if let Some(s) = in_seg[ei as usize].take() {
                let extra = match &node.op {
                    // The accumulator adds before its register.
                    Op::Accum { .. } => lib.pe_core_ps(OpClass::Mac) as f64 * tfac,
                    // IO capture flops after the pad path.
                    Op::Output { .. } => lib.io_core_ps() as f64 * tfac,
                    _ => 0.0,
                };
                segments.push(Segment {
                    delay_ps: in_time[ei as usize] + extra + setup,
                    start_tile: s.start_tile,
                    end_tile: tile,
                    nodes: s.nodes,
                    end: SegmentEnd::NodeInput { node: n },
                });
            }
        }

        // --- Walk this node's nets.
        for &ni in &nets_of_src[n as usize] {
            let net = &d.nets[ni];
            let (src_time, src_seg) = match net.kind {
                NetKind::Data | NetKind::Flush => (t_out, out_seg[n as usize].clone()),
                // Valid/ready are driven registered out of the FIFO logic.
                NetKind::Valid | NetKind::Ready => (
                    clk_q + lib.pe_core_ps(OpClass::Logic) as f64 * tfac,
                    SegState { start_tile: tile, nodes: Vec::new() },
                ),
            };
            for (k, path) in d.routes[ni].sink_paths.iter().enumerate() {
                let mut t = src_time;
                let mut seg = src_seg.clone();
                for w in path.windows(2) {
                    let (a, b) = (w[0], w[1]);
                    // Edge delay a -> b.
                    let e = graph
                        .fanout(a)
                        .iter()
                        .find(|e| e.dst == b)
                        .expect("routed step must exist in RRG");
                    let btile = graph.decode(b).tile;
                    t += e.delay_ps as f64 * factor(btile);
                    seg.nodes.push(b);
                    if d.sb_regs.contains(&b) {
                        segments.push(Segment {
                            delay_ps: t + setup,
                            start_tile: seg.start_tile,
                            end_tile: btile,
                            nodes: std::mem::take(&mut seg.nodes),
                            end: SegmentEnd::SbReg,
                        });
                        t = clk_q;
                        seg = SegState { start_tile: btile, nodes: vec![b] };
                    }
                }
                // Path end: CbIn of the sink.
                match net.kind {
                    NetKind::Data => {
                        let ei = net.edges[k];
                        if sink_registered(d, ei) {
                            in_time[ei as usize] = t;
                            in_seg[ei as usize] = Some(seg.clone());
                            // Endpoint recorded when the sink node is
                            // processed (adds capture core delay) — except
                            // the sink may already have been processed if
                            // it precedes `n` in topo order; that cannot
                            // happen for Data nets on a DAG.
                        } else {
                            in_time[ei as usize] = t;
                            in_seg[ei as usize] = Some(seg.clone());
                        }
                    }
                    NetKind::Valid | NetKind::Ready | NetKind::Flush => {
                        let (sink_node, _) = net.sinks[k];
                        segments.push(Segment {
                            delay_ps: t + setup,
                            start_tile: seg.start_tile,
                            end_tile: d.placement.pos[sink_node as usize],
                            nodes: seg.nodes.clone(),
                            end: SegmentEnd::NodeCore { node: sink_node },
                        });
                    }
                }
            }
        }
    }

    // Capture endpoints for registered sinks whose driver comes later in
    // topo order cannot exist on a DAG, but ready nets (reverse direction)
    // were handled inline above.

    // Internal tile paths also bound the clock: the MEM read path and the
    // PE MAC path are register-to-register inside one tile.
    for (i, node) in d.dfg.nodes.iter().enumerate() {
        let tile = d.placement.pos[i];
        let tfac = factor(tile);
        let internal = match &node.op {
            Op::Delay { .. } if node.tile_kind() == crate::arch::params::TileKind::Mem => {
                Some(lib.mem_core_ps() as f64)
            }
            Op::Rom { .. } => Some(lib.mem_core_ps() as f64),
            Op::Accum { .. } => Some(lib.pe_core_ps(OpClass::Mac) as f64),
            _ => None,
        };
        if let Some(c) = internal {
            segments.push(Segment {
                delay_ps: clk_q + c * tfac + setup,
                start_tile: tile,
                end_tile: tile,
                nodes: Vec::new(),
                end: SegmentEnd::NodeCore { node: i as u32 },
            });
        }
    }

    // Combine with skew.
    let mut best: Option<(f64, usize)> = None;
    for (i, s) in segments.iter().enumerate() {
        let skew_term = match inst {
            None => lib.max_skew_margin_ps() as f64,
            Some(id) => ((id.skew)(s.start_tile) - (id.skew)(s.end_tile)).max(0.0),
        };
        let period = s.delay_ps + skew_term;
        if best.map(|(p, _)| period > p).unwrap_or(true) {
            best = Some((period, i));
        }
    }
    let (period_ps, idx) = best.expect("design has at least one timing segment");
    CritPath {
        period_ps,
        fmax_mhz: 1e6 / period_ps,
        segment: segments[idx].clone(),
        num_segments: segments.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::delay::{DelayLib, DelayModelParams};
    use crate::arch::params::ArchParams;
    use crate::pnr::{place_and_route, PlaceParams, RouteParams};

    fn build(app: &crate::apps::App, seed: u64) -> (RoutedDesign, InterconnectGraph) {
        let arch = ArchParams::paper();
        let lib = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut graph = InterconnectGraph::build(&arch);
        graph.annotate_delays(&lib);
        let d = place_and_route(
            &app.dfg,
            &arch,
            &graph,
            &lib,
            &PlaceParams::baseline(seed),
            &RouteParams::default(),
        )
        .unwrap();
        (d, graph)
    }

    #[test]
    fn unpipelined_gaussian_is_slow() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (d, graph) = build(&app, 3);
        let cp = analyze(&d, &graph);
        // Unpipelined: long combinational chains through the adder tree
        // and interconnect. Expect well under 250 MHz (paper: 103 MHz).
        assert!(cp.fmax_mhz < 250.0, "fmax {}", cp.fmax_mhz);
        assert!(cp.period_ps > 4000.0);
        assert!(cp.num_segments > 10);
    }

    #[test]
    fn input_regs_raise_fmax() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (mut d, graph) = build(&app, 3);
        let before = analyze(&d, &graph).fmax_mhz;
        for n in 0..d.dfg.nodes.len() {
            if matches!(d.dfg.nodes[n].op, Op::Alu { .. }) {
                d.dfg.nodes[n].input_regs = true;
            }
        }
        let after = analyze(&d, &graph).fmax_mhz;
        assert!(after > before * 1.5, "before {before} after {after}");
    }

    #[test]
    fn sb_register_breaks_critical_path() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (mut d, graph) = build(&app, 3);
        // Pipeline the PEs first so interconnect dominates.
        for n in 0..d.dfg.nodes.len() {
            if matches!(d.dfg.nodes[n].op, Op::Alu { .. }) {
                d.dfg.nodes[n].input_regs = true;
            }
        }
        let cp0 = analyze(&d, &graph);
        // Enable a register in the middle of the critical segment.
        let sbouts: Vec<RrgNode> = cp0
            .segment
            .nodes
            .iter()
            .copied()
            .filter(|&n| matches!(graph.decode(n).kind, NodeKind::SbOut { .. }))
            .collect();
        if sbouts.is_empty() {
            // Critical segment is core-internal; nothing to break.
            return;
        }
        let mid = sbouts[sbouts.len() / 2];
        d.sb_regs.insert(mid);
        let cp1 = analyze(&d, &graph);
        assert!(
            cp1.segment.delay_ps <= cp0.segment.delay_ps,
            "critical segment should not grow: {} -> {}",
            cp0.segment.delay_ps,
            cp1.segment.delay_ps
        );
    }

    #[test]
    fn harris_slower_than_gaussian_unpipelined() {
        let g = crate::apps::dense::gaussian(64, 64, 1);
        let h = crate::apps::dense::harris(64, 64, 1);
        let (dg, gg) = build(&g, 5);
        let (dh, gh) = build(&h, 5);
        let fg = analyze(&dg, &gg).fmax_mhz;
        let fh = analyze(&dh, &gh).fmax_mhz;
        assert!(fh < fg, "harris {fh} should be slower than gaussian {fg}");
    }

    #[test]
    fn instance_mode_is_faster_than_sta() {
        // Per-instance delays are <= worst case, so the gate-level view
        // must never be slower than the STA model (STA is pessimistic,
        // Fig. 6).
        let app = crate::apps::dense::unsharp(64, 64, 1);
        let (d, graph) = build(&app, 7);
        let sta = analyze(&d, &graph);
        let f = |_t: TileCoord| 0.9;
        let lib = d.lib.clone();
        let sk = move |t: TileCoord| lib.skew_ps(t) as f64;
        let inst = InstanceDelays { factor: &f, skew: &sk };
        let gl = analyze_instance(&d, &graph, &inst);
        assert!(gl.period_ps <= sta.period_ps, "gl {} sta {}", gl.period_ps, sta.period_ps);
    }

    #[test]
    fn segments_have_provenance() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (d, graph) = build(&app, 3);
        let cp = analyze(&d, &graph);
        // The critical segment either crosses interconnect (has RRG nodes)
        // or is an internal core path.
        if cp.segment.nodes.is_empty() {
            assert!(matches!(cp.segment.end, SegmentEnd::NodeCore { .. }));
        } else {
            for &n in &cp.segment.nodes {
                let _ = graph.decode(n); // must be valid ids
            }
        }
    }
}
