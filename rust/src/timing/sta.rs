//! Application static timing analysis (paper §IV-B).
//!
//! Register-bounded longest-path analysis over a routed design. Timing
//! segments start at a register output (PE input register, SB pipelining
//! register, MEM/accumulator output, IO launch, FIFO) and end at the next
//! register input. The maximum segment delay plus the worst-case clock-skew
//! margin sets the minimum clock period and hence the application's maximum
//! frequency.
//!
//! The analysis records full provenance of the critical segment (the RRG
//! nodes it traverses), which is exactly what post-PnR pipelining (§V-D)
//! needs to decide which switch-box register to enable.
//!
//! # Incremental mode
//!
//! [`StaEngine`] memoizes a full [`analyze`] pass and, on each later call,
//! re-propagates arrival times only downstream of design state that
//! actually changed (a dirty-set walk over the topo-ordered graph): the
//! post-PnR pipelining loop runs one STA per candidate register, so this
//! replaces its repeated full-graph passes with work proportional to the
//! perturbed cone. Results are bit-identical to [`analyze`] both by
//! construction (the two share the per-node and per-net arithmetic
//! helpers) and by assertion (`debug_assertions` builds recompute from
//! scratch on every call and compare).
//!
//! ```no_run
//! use cascade::apps;
//! use cascade::arch::canal::InterconnectGraph;
//! use cascade::arch::delay::{DelayLib, DelayModelParams};
//! use cascade::arch::params::ArchParams;
//! use cascade::pnr::{place_and_route, PlaceParams, RouteParams};
//! use cascade::timing::sta::{analyze, StaEngine};
//!
//! let app = apps::dense::gaussian(64, 64, 1);
//! let arch = ArchParams::paper();
//! let lib = DelayLib::generate(&arch, &DelayModelParams::default());
//! let mut graph = InterconnectGraph::build(&arch);
//! graph.annotate_delays(&lib);
//! let mut d = place_and_route(&app.dfg, &arch, &graph, &lib,
//!     &PlaceParams::baseline(1), &RouteParams::default()).unwrap();
//! let mut engine = StaEngine::new(&d);
//! let first = engine.analyze(&d, &graph);   // full propagation
//! d.sb_regs.insert(first.segment.nodes[0]); // perturb one routed net
//! let second = engine.analyze(&d, &graph);  // re-walks the dirty cone only
//! assert_eq!(second.period_ps, analyze(&d, &graph).period_ps);
//! ```

use std::collections::{HashMap, HashSet};

#[allow(unused_imports)]
use crate::arch::canal::{InterconnectGraph, NodeId as RrgNode, NodeKind};
use crate::arch::delay::OpClass;
use crate::arch::params::TileCoord;
use crate::dfg::ir::{EdgeId, Op};
use crate::pnr::netlist::NetKind;
use crate::pnr::RoutedDesign;

/// What terminated a timing segment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SegmentEnd {
    /// Captured by a switch-box pipelining register.
    SbReg,
    /// Captured by a PE input register / register file / FIFO.
    NodeInput { node: u32 },
    /// Captured inside a memory / accumulator / IO tile.
    NodeCore { node: u32 },
}

/// One register-to-register timing segment.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    /// Path delay in ps, including launch clk-q and capture setup.
    pub delay_ps: f64,
    /// Launch tile (for skew) and capture tile.
    pub start_tile: TileCoord,
    pub end_tile: TileCoord,
    /// RRG nodes traversed since the segment's launch register (candidates
    /// for post-PnR register insertion are the unregistered SbOuts here).
    pub nodes: Vec<RrgNode>,
    pub end: SegmentEnd,
}

/// STA result.
#[derive(Debug, Clone)]
pub struct CritPath {
    /// Minimum clock period in ps (critical segment + skew margin).
    pub period_ps: f64,
    pub fmax_mhz: f64,
    /// The critical segment.
    pub segment: Segment,
    /// Number of timing segments analyzed.
    pub num_segments: usize,
}

/// Per-instance delay evaluation used by the gate-level-simulation
/// surrogate; `None` in plain STA mode (worst-case corners + global skew
/// margin).
pub struct InstanceDelays<'a> {
    /// Multiplicative factor on the delay of the RRG edge arriving at a
    /// node / the core delay of a tile.
    pub factor: &'a dyn Fn(TileCoord) -> f64,
    /// Actual clock skew at a tile (ps).
    pub skew: &'a dyn Fn(TileCoord) -> f64,
}

/// Run STA with worst-case corner delays and the global skew margin.
pub fn analyze(d: &RoutedDesign, graph: &InterconnectGraph) -> CritPath {
    analyze_impl(d, graph, None)
}

/// Run STA with per-instance delays (gate-level surrogate mode).
pub fn analyze_instance(
    d: &RoutedDesign,
    graph: &InterconnectGraph,
    inst: &InstanceDelays,
) -> CritPath {
    analyze_impl(d, graph, Some(inst))
}

#[derive(Clone, PartialEq)]
struct SegState {
    start_tile: TileCoord,
    nodes: Vec<RrgNode>,
}

/// Does this edge terminate in a register at the sink (before the sink's
/// combinational core)?
fn sink_registered(d: &RoutedDesign, e: EdgeId) -> bool {
    let edge = d.dfg.edge(e);
    let dst = d.dfg.node(edge.dst);
    if d.rf_delay.get(&e).copied().unwrap_or(0) > 0 {
        return true;
    }
    if edge.fifos > 0 {
        return true;
    }
    match &dst.op {
        Op::Alu { .. } | Op::Fused { .. } => dst.input_regs,
        // Sparse compute units have FIFOs at every input by default
        // (§VIII-D: "sparse applications use FIFOs at the input of every
        // compute unit, so compute pipelining is applied by default").
        Op::Sparse(_) => true,
        // Memory writes, accumulator and IO capture are registered.
        Op::Delay { .. } | Op::Rom { .. } | Op::Accum { .. } | Op::Output { .. } => true,
        Op::Input { .. } | Op::FlushSrc | Op::Const { .. } => true,
    }
}

// ---------------------------------------------------------------------------
// Shared propagation helpers. Both the from-scratch pass (`analyze_impl`)
// and the incremental engine (`StaEngine`) funnel through these, so equal
// inputs yield bit-identical arithmetic by construction.
// ---------------------------------------------------------------------------

/// Launch time and open segment at a node's output within its current
/// timing segment.
fn node_out(
    d: &RoutedDesign,
    n: u32,
    tfac: f64,
    in_edges: &[Vec<EdgeId>],
    in_time: &[f64],
    in_seg: &[Option<SegState>],
) -> (f64, SegState) {
    let lib = &d.lib;
    let clk_q = lib.clk_q_ps() as f64;
    let node = &d.dfg.nodes[n as usize];
    let tile = d.placement.pos[n as usize];
    match &node.op {
        Op::Input { .. } | Op::FlushSrc => (
            clk_q + lib.io_core_ps() as f64 * tfac,
            SegState { start_tile: tile, nodes: Vec::new() },
        ),
        Op::Delay { .. } if node.tile_kind() == crate::arch::params::TileKind::Mem => (
            clk_q + lib.mem_core_ps() as f64 * tfac,
            SegState { start_tile: tile, nodes: Vec::new() },
        ),
        Op::Delay { .. } => (
            // PE register-file shift register: registered output.
            clk_q + lib.pe_core_ps(OpClass::Pass) as f64 * tfac,
            SegState { start_tile: tile, nodes: Vec::new() },
        ),
        Op::Rom { .. } => (
            clk_q + lib.mem_core_ps() as f64 * tfac,
            SegState { start_tile: tile, nodes: Vec::new() },
        ),
        Op::Accum { .. } => (clk_q, SegState { start_tile: tile, nodes: Vec::new() }),
        Op::Sparse(s) => {
            let class = match s {
                crate::dfg::ir::SparseOp::Intersect | crate::dfg::ir::SparseOp::Union => {
                    OpClass::Cmp
                }
                crate::dfg::ir::SparseOp::SpAlu(a) => a.op_class(),
                crate::dfg::ir::SparseOp::Reduce => OpClass::Add,
                crate::dfg::ir::SparseOp::Repeat => OpClass::Logic,
                crate::dfg::ir::SparseOp::CrdScan { .. }
                | crate::dfg::ir::SparseOp::ValRead { .. } => OpClass::Pass,
            };
            let core = if node.tile_kind() == crate::arch::params::TileKind::Mem {
                lib.mem_core_ps() as f64
            } else {
                lib.pe_core_ps(class) as f64
            };
            (clk_q + core * tfac, SegState { start_tile: tile, nodes: Vec::new() })
        }
        Op::Const { .. } => (clk_q, SegState { start_tile: tile, nodes: Vec::new() }),
        Op::Output { .. } => (clk_q, SegState { start_tile: tile, nodes: Vec::new() }),
        Op::Alu { .. } | Op::Fused { .. } => {
            // Compound ops chain inside one PE core: their composed delay
            // comes from `DelayLib::fused_core_ps`; a plain ALU is the
            // single-step special case of the same lookup.
            let core = match &node.op {
                Op::Alu { op, .. } => lib.pe_core_ps(op.op_class()) as f64,
                Op::Fused { ops } => {
                    let classes: Vec<OpClass> =
                        ops.iter().map(|s| s.op.op_class()).collect();
                    lib.fused_core_ps(&classes) as f64
                }
                _ => unreachable!(),
            };
            if node.input_regs {
                (clk_q + core * tfac, SegState { start_tile: tile, nodes: Vec::new() })
            } else {
                // Combinational: continue from the worst input.
                let mut worst = clk_q;
                let mut seg = SegState { start_tile: tile, nodes: Vec::new() };
                for &ei in &in_edges[n as usize] {
                    if sink_registered(d, ei) {
                        continue;
                    }
                    if let Some(s) = &in_seg[ei as usize] {
                        if in_time[ei as usize] > worst {
                            worst = in_time[ei as usize];
                            seg = s.clone();
                        }
                    }
                }
                (worst + core * tfac, seg)
            }
        }
    }
}

/// Record capture endpoints for the registered inputs of node `n`. The
/// endpoint times were computed during the drivers' net walks and stored
/// in `in_time`/`in_seg`; recording happens at the sink so the capture
/// core delay of this node kind is included.
fn capture_segments(
    d: &RoutedDesign,
    n: u32,
    tfac: f64,
    in_edges: &[Vec<EdgeId>],
    in_time: &[f64],
    in_seg: &[Option<SegState>],
    out: &mut Vec<Segment>,
) {
    let lib = &d.lib;
    let setup = lib.setup_ps() as f64;
    let node = &d.dfg.nodes[n as usize];
    let tile = d.placement.pos[n as usize];
    for &ei in &in_edges[n as usize] {
        if !sink_registered(d, ei) {
            continue;
        }
        if let Some(s) = &in_seg[ei as usize] {
            let extra = match &node.op {
                // The accumulator adds before its register.
                Op::Accum { .. } => lib.pe_core_ps(OpClass::Mac) as f64 * tfac,
                // IO capture flops after the pad path.
                Op::Output { .. } => lib.io_core_ps() as f64 * tfac,
                _ => 0.0,
            };
            out.push(Segment {
                delay_ps: in_time[ei as usize] + extra + setup,
                start_tile: s.start_tile,
                end_tile: tile,
                nodes: s.nodes.clone(),
                end: SegmentEnd::NodeInput { node: n },
            });
        }
    }
}

/// Walk one net's route trees from its source: emit an `SbReg` segment at
/// every enabled switch-box register, a `NodeCore` segment at each
/// Valid/Ready/Flush sink, and report each Data sink's arrival
/// time/segment through `set_in`.
#[allow(clippy::too_many_arguments)]
fn walk_net(
    d: &RoutedDesign,
    graph: &InterconnectGraph,
    ni: usize,
    t_out: f64,
    out_seg_n: &SegState,
    factor: &dyn Fn(TileCoord) -> f64,
    segs: &mut Vec<Segment>,
    set_in: &mut dyn FnMut(EdgeId, f64, SegState),
) {
    let lib = &d.lib;
    let clk_q = lib.clk_q_ps() as f64;
    let setup = lib.setup_ps() as f64;
    let net = &d.nets[ni];
    let tile = d.placement.pos[net.src as usize];
    let tfac = factor(tile);
    let (src_time, src_seg) = match net.kind {
        NetKind::Data | NetKind::Flush => (t_out, out_seg_n.clone()),
        // Valid/ready are driven registered out of the FIFO logic.
        NetKind::Valid | NetKind::Ready => (
            clk_q + lib.pe_core_ps(OpClass::Logic) as f64 * tfac,
            SegState { start_tile: tile, nodes: Vec::new() },
        ),
    };
    for (k, path) in d.routes[ni].sink_paths.iter().enumerate() {
        let mut t = src_time;
        let mut seg = src_seg.clone();
        for w in path.windows(2) {
            let (a, b) = (w[0], w[1]);
            // Edge delay a -> b.
            let e = graph
                .fanout(a)
                .iter()
                .find(|e| e.dst == b)
                .expect("routed step must exist in RRG");
            let btile = graph.decode(b).tile;
            t += e.delay_ps as f64 * factor(btile);
            seg.nodes.push(b);
            if d.sb_regs.contains(&b) {
                segs.push(Segment {
                    delay_ps: t + setup,
                    start_tile: seg.start_tile,
                    end_tile: btile,
                    nodes: std::mem::take(&mut seg.nodes),
                    end: SegmentEnd::SbReg,
                });
                t = clk_q;
                seg = SegState { start_tile: btile, nodes: vec![b] };
            }
        }
        // Path end: CbIn of the sink.
        match net.kind {
            NetKind::Data => {
                // The capture endpoint (registered sinks) is recorded when
                // the sink node is processed, which on a DAG is always
                // after its driver in topo order.
                set_in(net.edges[k], t, seg);
            }
            NetKind::Valid | NetKind::Ready | NetKind::Flush => {
                let (sink_node, _) = net.sinks[k];
                segs.push(Segment {
                    delay_ps: t + setup,
                    start_tile: seg.start_tile,
                    end_tile: d.placement.pos[sink_node as usize],
                    nodes: seg.nodes.clone(),
                    end: SegmentEnd::NodeCore { node: sink_node },
                });
            }
        }
    }
}

/// Internal tile paths also bound the clock: the MEM read path and the PE
/// MAC path are register-to-register inside one tile. Static while
/// placement and node ops are fixed.
fn internal_segments(d: &RoutedDesign, factor: &dyn Fn(TileCoord) -> f64) -> Vec<Segment> {
    let lib = &d.lib;
    let clk_q = lib.clk_q_ps() as f64;
    let setup = lib.setup_ps() as f64;
    let mut segs = Vec::new();
    for (i, node) in d.dfg.nodes.iter().enumerate() {
        let tile = d.placement.pos[i];
        let tfac = factor(tile);
        let internal = match &node.op {
            Op::Delay { .. } if node.tile_kind() == crate::arch::params::TileKind::Mem => {
                Some(lib.mem_core_ps() as f64)
            }
            Op::Rom { .. } => Some(lib.mem_core_ps() as f64),
            Op::Accum { .. } => Some(lib.pe_core_ps(OpClass::Mac) as f64),
            _ => None,
        };
        if let Some(c) = internal {
            segs.push(Segment {
                delay_ps: clk_q + c * tfac + setup,
                start_tile: tile,
                end_tile: tile,
                nodes: Vec::new(),
                end: SegmentEnd::NodeCore { node: i as u32 },
            });
        }
    }
    segs
}

fn analyze_impl(
    d: &RoutedDesign,
    graph: &InterconnectGraph,
    inst: Option<&InstanceDelays>,
) -> CritPath {
    let lib = &d.lib;
    let nn = d.dfg.nodes.len();

    let factor = |tile: TileCoord| -> f64 {
        match inst {
            Some(i) => (i.factor)(tile),
            None => 1.0,
        }
    };

    let mut segments: Vec<Segment> = Vec::new();
    // Open segment at each node output.
    let mut out_seg: Vec<SegState> =
        vec![SegState { start_tile: TileCoord::new(0, 0), nodes: Vec::new() }; nn];
    // Arrival time / segment at each edge's sink CbIn.
    let ne = d.dfg.edges.len();
    let mut in_time = vec![0f64; ne];
    let mut in_seg: Vec<Option<SegState>> = vec![None; ne];

    let order = d.dfg.topo_order();

    // In-edges per node (B16 and B1 both matter for combinational joins).
    let mut in_edges: Vec<Vec<EdgeId>> = vec![Vec::new(); nn];
    for (ei, e) in d.dfg.edges.iter().enumerate() {
        in_edges[e.dst as usize].push(ei as EdgeId);
    }
    // Nets by source node (Data/Flush walked in topo order; Valid/Ready
    // sources are registered so they can be walked whenever).
    let mut nets_of_src: Vec<Vec<usize>> = vec![Vec::new(); nn];
    for net in &d.nets {
        nets_of_src[net.src as usize].push(net.id);
    }

    for &n in &order {
        let nu = n as usize;
        let tfac = factor(d.placement.pos[nu]);
        let (t_out, seg) = node_out(d, n, tfac, &in_edges, &in_time, &in_seg);
        out_seg[nu] = seg;
        capture_segments(d, n, tfac, &in_edges, &in_time, &in_seg, &mut segments);
        for &ni in &nets_of_src[nu] {
            let mut set_in = |ei: EdgeId, t: f64, sgs: SegState| {
                in_time[ei as usize] = t;
                in_seg[ei as usize] = Some(sgs);
            };
            walk_net(d, graph, ni, t_out, &out_seg[nu], &factor, &mut segments, &mut set_in);
        }
    }

    segments.extend(internal_segments(d, &factor));

    // Combine with skew.
    let mut best: Option<(f64, usize)> = None;
    for (i, s) in segments.iter().enumerate() {
        let skew_term = match inst {
            None => lib.max_skew_margin_ps() as f64,
            Some(id) => ((id.skew)(s.start_tile) - (id.skew)(s.end_tile)).max(0.0),
        };
        let period = s.delay_ps + skew_term;
        if best.map(|(p, _)| period > p).unwrap_or(true) {
            best = Some((period, i));
        }
    }
    let (period_ps, idx) = best.expect("design has at least one timing segment");
    CritPath {
        period_ps,
        fmax_mhz: 1e6 / period_ps,
        segment: segments[idx].clone(),
        num_segments: segments.len(),
    }
}

/// Incremental STA engine for the post-PnR pipelining loop.
///
/// Memoizes every per-node and per-net intermediate of a full [`analyze`]
/// pass (the levelized topo order, arrival times at node outputs and edge
/// sinks, and the timing segments each node/net contributes). On each
/// call it diffs the design's mutable state — switch-box registers, FIFO
/// stages, register-file delays, input registers — against a snapshot
/// from the previous call, then re-propagates only downstream of the
/// dirtied state: a changed SB register dirties exactly the nets whose
/// routes cross it; a registration flip dirties the sink node; everything
/// downstream re-runs only while recomputed values actually change.
///
/// Placement, routing and DFG topology must stay fixed between calls
/// (they do across post-PnR iterations). Worst-case corners + global skew
/// margin only — the gate-level surrogate's per-instance mode remains on
/// [`analyze_instance`]. Results are bit-identical to [`analyze`];
/// `debug_assertions` builds verify that on every call.
pub struct StaEngine {
    // Static caches (valid while placement/routes/topology are fixed).
    order: Vec<u32>,
    in_edges: Vec<Vec<EdgeId>>,
    nets_of_src: Vec<Vec<usize>>,
    nets_by_rrg: HashMap<RrgNode, Vec<usize>>,
    internal_segs: Vec<Segment>,
    // Memoized propagation state.
    out_time: Vec<f64>,
    out_seg: Vec<SegState>,
    in_time: Vec<f64>,
    in_seg: Vec<Option<SegState>>,
    cap_segs: Vec<Vec<Segment>>,
    net_segs: Vec<Vec<Segment>>,
    // Snapshot of the design's mutable state, for diffing.
    prev_sb_regs: HashSet<RrgNode>,
    prev_sink_reg: Vec<bool>,
    prev_input_regs: Vec<bool>,
    first: bool,
}

impl StaEngine {
    /// Build an engine over a routed design. The first `analyze` call is
    /// a full propagation; later calls re-walk only the dirty cone.
    pub fn new(d: &RoutedDesign) -> StaEngine {
        let nn = d.dfg.nodes.len();
        let ne = d.dfg.edges.len();
        let mut in_edges: Vec<Vec<EdgeId>> = vec![Vec::new(); nn];
        for (ei, e) in d.dfg.edges.iter().enumerate() {
            in_edges[e.dst as usize].push(ei as EdgeId);
        }
        let mut nets_of_src: Vec<Vec<usize>> = vec![Vec::new(); nn];
        for net in &d.nets {
            nets_of_src[net.src as usize].push(net.id);
        }
        let mut nets_by_rrg: HashMap<RrgNode, Vec<usize>> = HashMap::new();
        for (ni, r) in d.routes.iter().enumerate() {
            for nde in r.nodes() {
                nets_by_rrg.entry(nde).or_default().push(ni);
            }
        }
        StaEngine {
            order: d.dfg.topo_order(),
            in_edges,
            nets_of_src,
            nets_by_rrg,
            internal_segs: internal_segments(d, &|_| 1.0),
            out_time: vec![0f64; nn],
            out_seg: vec![SegState { start_tile: TileCoord::new(0, 0), nodes: Vec::new() }; nn],
            in_time: vec![0f64; ne],
            in_seg: vec![None; ne],
            cap_segs: vec![Vec::new(); nn],
            net_segs: vec![Vec::new(); d.nets.len()],
            prev_sb_regs: HashSet::new(),
            prev_sink_reg: vec![false; ne],
            prev_input_regs: vec![false; nn],
            first: true,
        }
    }

    /// Incremental [`analyze`]: bit-identical result, re-propagating only
    /// downstream of design state changed since the previous call.
    pub fn analyze(&mut self, d: &RoutedDesign, graph: &InterconnectGraph) -> CritPath {
        let nn = d.dfg.nodes.len();
        let ne = d.dfg.edges.len();
        assert_eq!(nn, self.out_time.len(), "DFG changed under StaEngine");
        assert_eq!(ne, self.in_time.len(), "DFG changed under StaEngine");

        // --- Diff the design's mutable state against the snapshot.
        let cur_sink_reg: Vec<bool> =
            (0..ne).map(|ei| sink_registered(d, ei as EdgeId)).collect();
        let cur_input_regs: Vec<bool> = d.dfg.nodes.iter().map(|nd| nd.input_regs).collect();
        let mut node_dirty = vec![self.first; nn];
        let mut net_dirty = vec![self.first; d.nets.len()];
        if !self.first {
            for (ei, (&cur, &prev)) in
                cur_sink_reg.iter().zip(&self.prev_sink_reg).enumerate()
            {
                if cur != prev {
                    node_dirty[d.dfg.edges[ei].dst as usize] = true;
                }
            }
            for (n, dirty) in node_dirty.iter_mut().enumerate() {
                if cur_input_regs[n] != self.prev_input_regs[n] {
                    *dirty = true;
                }
            }
            for r in d.sb_regs.symmetric_difference(&self.prev_sb_regs) {
                if let Some(nets) = self.nets_by_rrg.get(r) {
                    for &ni in nets {
                        net_dirty[ni] = true;
                    }
                }
            }
        }

        // --- Re-propagate in topo order, only where dirty.
        let factor = |_: TileCoord| -> f64 { 1.0 };
        let mut out_changed = vec![false; nn];
        let mut in_changed = vec![false; ne];
        // Kernel work tallies (docs/observability.md): how much of the
        // graph the dirty walk actually touched, and how often the
        // bitwise-equality early-stop cut propagation. Plain locals; the
        // analysis never depends on them.
        let mut nodes_repropagated = 0u64;
        let mut early_stops = 0u64;
        {
            let StaEngine {
                order,
                in_edges,
                nets_of_src,
                out_time,
                out_seg,
                in_time,
                in_seg,
                cap_segs,
                net_segs,
                ..
            } = self;
            for &n in order.iter() {
                let nu = n as usize;
                let any_in = in_edges[nu].iter().any(|&ei| in_changed[ei as usize]);
                if node_dirty[nu] || any_in {
                    nodes_repropagated += 1;
                    let tfac = factor(d.placement.pos[nu]);
                    let (t, sgs) = node_out(d, n, tfac, in_edges, in_time, in_seg);
                    out_changed[nu] =
                        t.to_bits() != out_time[nu].to_bits() || sgs != out_seg[nu];
                    if !out_changed[nu] {
                        early_stops += 1;
                    }
                    out_time[nu] = t;
                    out_seg[nu] = sgs;
                    cap_segs[nu].clear();
                    capture_segments(d, n, tfac, in_edges, in_time, in_seg, &mut cap_segs[nu]);
                }
                for &ni in &nets_of_src[nu] {
                    let feeds = matches!(d.nets[ni].kind, NetKind::Data | NetKind::Flush);
                    if !(net_dirty[ni] || (feeds && out_changed[nu])) {
                        continue;
                    }
                    net_segs[ni].clear();
                    walk_net(
                        d,
                        graph,
                        ni,
                        out_time[nu],
                        &out_seg[nu],
                        &factor,
                        &mut net_segs[ni],
                        &mut |ei, t, sgs| {
                            let eu = ei as usize;
                            if t.to_bits() != in_time[eu].to_bits()
                                || in_seg[eu].as_ref() != Some(&sgs)
                            {
                                in_changed[eu] = true;
                            }
                            in_time[eu] = t;
                            in_seg[eu] = Some(sgs);
                        },
                    );
                }
            }
        }

        // --- Snapshot for the next diff.
        self.prev_sb_regs = d.sb_regs.clone();
        self.prev_sink_reg = cur_sink_reg;
        self.prev_input_regs = cur_input_regs;
        self.first = false;
        crate::obs::counters::bump("sta_nodes_total", nn as u64);
        crate::obs::counters::bump("sta_nodes_repropagated", nodes_repropagated);
        crate::obs::counters::bump("sta_early_stops", early_stops);

        // --- Fold segments in the exact emission order of `analyze` so
        // first-maximum tie-breaking picks the identical critical segment.
        let skew = d.lib.max_skew_margin_ps() as f64;
        let ordered = self
            .order
            .iter()
            .flat_map(|&n| {
                self.cap_segs[n as usize].iter().chain(
                    self.nets_of_src[n as usize]
                        .iter()
                        .flat_map(|&ni| self.net_segs[ni].iter()),
                )
            })
            .chain(self.internal_segs.iter());
        let mut best: Option<(f64, &Segment)> = None;
        let mut count = 0usize;
        for s in ordered {
            count += 1;
            let period = s.delay_ps + skew;
            if best.map(|(p, _)| period > p).unwrap_or(true) {
                best = Some((period, s));
            }
        }
        let (period_ps, seg) = best.expect("design has at least one timing segment");
        let cp = CritPath {
            period_ps,
            fmax_mhz: 1e6 / period_ps,
            segment: seg.clone(),
            num_segments: count,
        };

        // Every incremental result is checked against a from-scratch
        // propagation in debug builds — the equality-with-full-recompute
        // contract of docs/performance.md.
        #[cfg(debug_assertions)]
        {
            let full = analyze(d, graph);
            debug_assert_eq!(
                cp.period_ps.to_bits(),
                full.period_ps.to_bits(),
                "incremental STA period diverged"
            );
            debug_assert_eq!(cp.num_segments, full.num_segments, "segment count diverged");
            debug_assert_eq!(cp.segment, full.segment, "critical segment diverged");
        }
        cp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::delay::{DelayLib, DelayModelParams};
    use crate::arch::params::ArchParams;
    use crate::pnr::{place_and_route, PlaceParams, RouteParams};

    fn build(app: &crate::apps::App, seed: u64) -> (RoutedDesign, InterconnectGraph) {
        let arch = ArchParams::paper();
        let lib = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut graph = InterconnectGraph::build(&arch);
        graph.annotate_delays(&lib);
        let d = place_and_route(
            &app.dfg,
            &arch,
            &graph,
            &lib,
            &PlaceParams::baseline(seed),
            &RouteParams::default(),
        )
        .unwrap();
        (d, graph)
    }

    #[test]
    fn unpipelined_gaussian_is_slow() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (d, graph) = build(&app, 3);
        let cp = analyze(&d, &graph);
        // Unpipelined: long combinational chains through the adder tree
        // and interconnect. Expect well under 250 MHz (paper: 103 MHz).
        assert!(cp.fmax_mhz < 250.0, "fmax {}", cp.fmax_mhz);
        assert!(cp.period_ps > 4000.0);
        assert!(cp.num_segments > 10);
    }

    #[test]
    fn input_regs_raise_fmax() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (mut d, graph) = build(&app, 3);
        let before = analyze(&d, &graph).fmax_mhz;
        for n in 0..d.dfg.nodes.len() {
            if matches!(d.dfg.nodes[n].op, Op::Alu { .. }) {
                d.dfg.nodes[n].input_regs = true;
            }
        }
        let after = analyze(&d, &graph).fmax_mhz;
        assert!(after > before * 1.5, "before {before} after {after}");
    }

    #[test]
    fn sb_register_breaks_critical_path() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (mut d, graph) = build(&app, 3);
        // Pipeline the PEs first so interconnect dominates.
        for n in 0..d.dfg.nodes.len() {
            if matches!(d.dfg.nodes[n].op, Op::Alu { .. }) {
                d.dfg.nodes[n].input_regs = true;
            }
        }
        let cp0 = analyze(&d, &graph);
        // Enable a register in the middle of the critical segment.
        let sbouts: Vec<RrgNode> = cp0
            .segment
            .nodes
            .iter()
            .copied()
            .filter(|&n| matches!(graph.decode(n).kind, NodeKind::SbOut { .. }))
            .collect();
        if sbouts.is_empty() {
            // Critical segment is core-internal; nothing to break.
            return;
        }
        let mid = sbouts[sbouts.len() / 2];
        d.sb_regs.insert(mid);
        let cp1 = analyze(&d, &graph);
        assert!(
            cp1.segment.delay_ps <= cp0.segment.delay_ps,
            "critical segment should not grow: {} -> {}",
            cp0.segment.delay_ps,
            cp1.segment.delay_ps
        );
    }

    #[test]
    fn harris_slower_than_gaussian_unpipelined() {
        let g = crate::apps::dense::gaussian(64, 64, 1);
        let h = crate::apps::dense::harris(64, 64, 1);
        let (dg, gg) = build(&g, 5);
        let (dh, gh) = build(&h, 5);
        let fg = analyze(&dg, &gg).fmax_mhz;
        let fh = analyze(&dh, &gh).fmax_mhz;
        assert!(fh < fg, "harris {fh} should be slower than gaussian {fg}");
    }

    #[test]
    fn instance_mode_is_faster_than_sta() {
        // Per-instance delays are <= worst case, so the gate-level view
        // must never be slower than the STA model (STA is pessimistic,
        // Fig. 6).
        let app = crate::apps::dense::unsharp(64, 64, 1);
        let (d, graph) = build(&app, 7);
        let sta = analyze(&d, &graph);
        let f = |_t: TileCoord| 0.9;
        let lib = d.lib.clone();
        let sk = move |t: TileCoord| lib.skew_ps(t) as f64;
        let inst = InstanceDelays { factor: &f, skew: &sk };
        let gl = analyze_instance(&d, &graph, &inst);
        assert!(gl.period_ps <= sta.period_ps, "gl {} sta {}", gl.period_ps, sta.period_ps);
    }

    #[test]
    fn incremental_sta_matches_full_propagation() {
        // Dirty-set re-propagation must reproduce full-propagation arrival
        // times bitwise through a sequence of perturbations: input-register
        // flips, SB register insert + remove (the post-PnR accept/rollback
        // shape), FIFO bumps and register-file delays.
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (mut d, graph) = build(&app, 3);
        let mut engine = StaEngine::new(&d);
        let check = |engine: &mut StaEngine, d: &RoutedDesign| {
            let inc = engine.analyze(d, &graph);
            let full = analyze(d, &graph);
            assert_eq!(inc.period_ps.to_bits(), full.period_ps.to_bits());
            assert_eq!(inc.num_segments, full.num_segments);
            assert_eq!(inc.segment, full.segment);
        };
        check(&mut engine, &d);
        // Pipeline the ALUs (input-register flips).
        for n in 0..d.dfg.nodes.len() {
            if matches!(d.dfg.nodes[n].op, Op::Alu { .. }) {
                d.dfg.nodes[n].input_regs = true;
            }
        }
        check(&mut engine, &d);
        // Insert an SB register mid-way through the critical segment, then
        // remove it again (the rollback shape of post-PnR pipelining).
        let cp = engine.analyze(&d, &graph);
        let sbouts: Vec<RrgNode> = cp
            .segment
            .nodes
            .iter()
            .copied()
            .filter(|&n| matches!(graph.decode(n).kind, NodeKind::SbOut { .. }))
            .collect();
        if let Some(&mid) = sbouts.get(sbouts.len() / 2) {
            d.sb_regs.insert(mid);
            check(&mut engine, &d);
            d.sb_regs.remove(&mid);
            check(&mut engine, &d);
        }
        // FIFO and register-file perturbations on one edge.
        d.dfg.edge_mut(0).fifos += 1;
        check(&mut engine, &d);
        d.dfg.edge_mut(0).fifos -= 1;
        d.rf_delay.insert(0, 2);
        check(&mut engine, &d);
        d.rf_delay.remove(&0);
        check(&mut engine, &d);
    }

    #[test]
    fn segments_have_provenance() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (d, graph) = build(&app, 3);
        let cp = analyze(&d, &graph);
        // The critical segment either crosses interconnect (has RRG nodes)
        // or is an internal core path.
        if cp.segment.nodes.is_empty() {
            assert!(matches!(cp.segment.end, SegmentEnd::NodeCore { .. }));
        } else {
            for &n in &cp.segment.nodes {
                let _ = graph.decode(n); // must be valid ids
            }
        }
    }
}
