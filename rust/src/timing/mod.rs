//! Timing analysis of mapped applications.
//!
//! * [`sta`] — the application static timing analysis tool (paper §IV-B):
//!   register-bounded longest-path analysis over the routed design, using
//!   the generated component timing model (`arch::delay`). Reports the
//!   critical path (with full provenance, so the post-PnR pipelining pass
//!   can break it) and the maximum clock frequency.
//! * [`gatelevel`] — the SDF-annotated gate-level-simulation surrogate used
//!   to validate the STA model (paper Fig. 6): re-times the design with
//!   per-instance delays (worst-case corner shrunk by deterministic
//!   instance variation) and actual — rather than worst-case-margin —
//!   clock skews, then searches the fastest working clock period at 0.1 ns
//!   granularity.

pub mod sta;
pub mod gatelevel;

pub use sta::{analyze, CritPath, Segment, SegmentEnd};
pub use gatelevel::{gate_level_period_ps, GateLevelParams};
