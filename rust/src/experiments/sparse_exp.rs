//! Sparse-application experiments: Fig. 10 (incremental techniques),
//! Table II (freq/runtime/power), Fig. 11 (EDP).
//!
//! Sparse apps use FIFOs at every compute-unit input, so compute
//! pipelining is on by default and cannot be turned off; broadcast
//! pipelining and duplication had no effect in the paper, so the sweep is
//! placement optimization then post-PnR pipelining (§VIII-D).

use crate::pipeline::{CompileCtx, PipelineConfig};
use crate::util::json::Json;

use super::common::{emit, md_table, measure_sparse, SparseRow};

fn sparse_apps() -> Vec<crate::apps::App> {
    crate::apps::paper_sparse_suite()
}

fn measure_ladder(
    ctx: &CompileCtx,
    fast: bool,
    seed: u64,
) -> Result<Vec<(String, Vec<SparseRow>)>, String> {
    let ladder = PipelineConfig::sparse_ladder();
    let mut out = Vec::new();
    for app in sparse_apps() {
        let mut rows = Vec::new();
        for (cname, cfg) in &ladder {
            let mut r = measure_sparse(&app, cfg, ctx, fast, seed)?;
            r.config = cname.to_string();
            rows.push(r);
        }
        out.push((app.name.to_string(), rows));
    }
    Ok(out)
}

/// Fig. 10: incremental application of the sparse pipelining techniques.
pub fn fig10(ctx: &CompileCtx, fast: bool, seed: u64) -> Result<(), String> {
    let data = measure_ladder(ctx, fast, seed)?;
    let mut rows = Vec::new();
    let mut j_apps = Json::Arr(vec![]);
    for (app, steps) in &data {
        let base = steps[0].runtime_us;
        let mut cells = vec![app.clone()];
        let mut j_steps = Json::Arr(vec![]);
        for s in steps {
            cells.push(format!("{:.2}us ({:.2}x)", s.runtime_us, base / s.runtime_us));
            j_steps.push(s.to_json());
        }
        rows.push(cells);
        let mut ja = Json::obj();
        ja.set("app", app.as_str()).set("steps", j_steps);
        j_apps.push(ja);
    }
    let ladder = PipelineConfig::sparse_ladder();
    let headers: Vec<&str> =
        std::iter::once("app").chain(ladder.iter().map(|(n, _)| *n)).collect();
    let mut md = md_table(&headers, &rows);
    md.push_str("\n(paper Fig. 10: runtime decreases significantly when placement optimization is applied)\n");
    let mut j = Json::obj();
    j.set("apps", j_apps);
    emit("fig10", "Fig. 10 — incremental sparse pipelining", &md, &j);
    Ok(())
}

/// Table II: compute-pipelined vs fully pipelined sparse apps.
pub fn table2(ctx: &CompileCtx, fast: bool, seed: u64) -> Result<(), String> {
    let data = measure_ladder(ctx, fast, seed)?;
    let mut rows = Vec::new();
    let mut j_rows = Json::Arr(vec![]);
    let mut notes = String::new();
    for (app, steps) in &data {
        let first = &steps[0];
        let last = steps.last().unwrap();
        for (label, r) in [("compute pipelining", first), ("all software pipelining", last)] {
            rows.push(vec![
                label.to_string(),
                app.clone(),
                format!("{:.0}", r.fmax_mhz),
                format!("{:.2}", r.runtime_us),
                format!("{:.0}", r.power.total_mw()),
            ]);
            let mut jr = r.to_json();
            jr.set("label", label);
            j_rows.push(jr);
        }
        notes.push_str(&format!(
            "- {}: critical path {:.2}x lower, runtime -{:.0}%\n",
            app,
            first.crit_ns / last.crit_ns,
            100.0 * (1.0 - last.runtime_us / first.runtime_us)
        ));
    }
    let mut md = md_table(
        &["", "application", "Frequency (MHz)", "Runtime (us)", "Power (mW)"],
        &rows,
    );
    md.push('\n');
    md.push_str(&notes);
    md.push_str("(paper: 2-4.4x lower critical paths; 29-65% runtime decrease)\n");
    let mut j = Json::obj();
    j.set("rows", j_rows);
    emit("table2", "Table II — sparse frequency / runtime / power", &md, &j);
    Ok(())
}

/// Fig. 11: sparse EDP, compute-only vs all pipelining.
pub fn fig11(ctx: &CompileCtx, fast: bool, seed: u64) -> Result<(), String> {
    let data = measure_ladder(ctx, fast, seed)?;
    let mut rows = Vec::new();
    let mut j_rows = Json::Arr(vec![]);
    for (app, steps) in &data {
        let e0 = steps[0].edp();
        let e1 = steps.last().unwrap().edp();
        rows.push(vec![
            app.clone(),
            format!("{:.2}", e0),
            format!("{:.2}", e1),
            format!("{:.1}%", 100.0 * (1.0 - e1 / e0)),
            format!("{:.2}x", e0 / e1),
        ]);
        let mut jr = Json::obj();
        jr.set("app", app.as_str())
            .set("edp_compute_only", e0)
            .set("edp_all", e1)
            .set("ratio", e0 / e1);
        j_rows.push(jr);
    }
    let mut md = md_table(
        &["app", "EDP compute-only", "EDP all pipelining", "reduction", "ratio"],
        &rows,
    );
    md.push_str("\n(paper: EDP reduces 35-76%, i.e. 1.5-4.2x)\n");
    let mut j = Json::obj();
    j.set("rows", j_rows);
    emit("fig11", "Fig. 11 — sparse EDP comparison", &md, &j);
    Ok(())
}
