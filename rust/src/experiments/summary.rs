//! Headline summary: the abstract's claims, regenerated.
//!
//! "Cascade enables 7-34x lower critical path delays and 7-190x lower EDP
//! across ... dense ... workloads, and 2-4.4x lower critical path delays
//! and 1.5-4.2x lower EDP on sparse workloads, compared to a compiler
//! without pipelining."

use crate::pipeline::{CompileCtx, PipelineConfig};
use crate::util::json::Json;

use super::common::{dense_crit_edp, emit, md_table, measure_sparse_cached};

pub fn run(ctx: &CompileCtx, fast: bool, seed: u64, use_cache: bool) -> Result<(), String> {
    let mut rows = Vec::new();
    let mut j_rows = Json::Arr(vec![]);
    let mut dense_cp = Vec::new();
    let mut dense_edp = Vec::new();
    for app in ["gaussian", "unsharp", "camera", "harris", "resnet"] {
        // Served from results/explore_cache when a prior `cascade explore`
        // (or summary run) already compiled the point; `--no-cache`
        // forces fresh compiles.
        let (crit0, edp0) =
            dense_crit_edp(app, &PipelineConfig::none(), ctx, fast, seed, use_cache)?;
        let (crit1, edp1) =
            dense_crit_edp(app, &PipelineConfig::full(), ctx, fast, seed, use_cache)?;
        let cp = crit0 / crit1;
        let edp = edp0 / edp1;
        dense_cp.push(cp);
        dense_edp.push(edp);
        rows.push(vec![
            format!("dense/{app}"),
            format!("{:.1}x", cp),
            format!("{:.1}x", edp),
        ]);
        let mut jr = Json::obj();
        jr.set("app", app).set("crit_ratio", cp).set("edp_ratio", edp);
        j_rows.push(jr);
    }
    let mut sparse_cp = Vec::new();
    let mut sparse_edp = Vec::new();
    for app in crate::apps::paper_sparse_suite() {
        // Like the dense rows, served from the explore cache when a prior
        // run already compiled the point: the persisted artifact (and its
        // recorded cycle count) replaces both the compile and the
        // functional simulation.
        let ladder = PipelineConfig::sparse_ladder();
        let first = measure_sparse_cached(&app, &ladder[0].1, ctx, fast, seed, use_cache)?;
        let last =
            measure_sparse_cached(&app, &ladder.last().unwrap().1, ctx, fast, seed, use_cache)?;
        let cp = first.crit_ns / last.crit_ns;
        let edp = first.edp() / last.edp();
        sparse_cp.push(cp);
        sparse_edp.push(edp);
        rows.push(vec![
            format!("sparse/{}", app.name),
            format!("{:.2}x", cp),
            format!("{:.2}x", edp),
        ]);
        let mut jr = Json::obj();
        jr.set("app", app.name).set("crit_ratio", cp).set("edp_ratio", edp);
        j_rows.push(jr);
    }
    let (dcp_lo, dcp_hi) = crate::util::stats::min_max(&dense_cp);
    let (dedp_lo, dedp_hi) = crate::util::stats::min_max(&dense_edp);
    let (scp_lo, scp_hi) = crate::util::stats::min_max(&sparse_cp);
    let (sedp_lo, sedp_hi) = crate::util::stats::min_max(&sparse_edp);
    let mut md = md_table(&["workload", "critical path ratio", "EDP ratio"], &rows);
    md.push_str(&format!(
        "\nMeasured: dense {dcp_lo:.1}-{dcp_hi:.1}x critical path, {dedp_lo:.1}-{dedp_hi:.1}x EDP; \
         sparse {scp_lo:.2}-{scp_hi:.2}x critical path, {sedp_lo:.2}-{sedp_hi:.2}x EDP.\n\
         Paper:    dense 7-34x critical path, 7-190x EDP; sparse 2-4.4x critical path, 1.5-4.2x EDP.\n"
    ));
    let mut j = Json::obj();
    j.set("rows", j_rows)
        .set("dense_crit_lo", dcp_lo)
        .set("dense_crit_hi", dcp_hi)
        .set("dense_edp_lo", dedp_lo)
        .set("dense_edp_hi", dedp_hi)
        .set("sparse_crit_lo", scp_lo)
        .set("sparse_crit_hi", scp_hi)
        .set("sparse_edp_lo", sedp_lo)
        .set("sparse_edp_hi", sedp_hi);
    emit("summary", "Headline summary (abstract claims)", &md, &j);
    Ok(())
}
