//! Fig. 6: validation of the application STA model against the
//! SDF-annotated gate-level-simulation surrogate.
//!
//! Each point is one application at one pipelining level: x = STA-modeled
//! minimum clock period, y = "simulated" fastest working period (0.1 ns
//! search granularity). The STA model must be a pessimistic bound, with
//! small average error at high frequencies (paper: 13 % above 500 MHz).

use crate::pipeline::{CompileCtx, PipelineConfig};
use crate::timing::gatelevel::{gate_level_period_ps, GateLevelParams};
use crate::util::json::Json;
use crate::util::stats;

use super::common::{compile_dense, emit, md_table};

pub fn run(ctx: &CompileCtx, fast: bool, seed: u64) -> Result<(), String> {
    let configs: Vec<(&str, PipelineConfig)> = vec![
        ("unpipelined", PipelineConfig::none()),
        ("compute", PipelineConfig::compute_only()),
        ("all-sw", PipelineConfig::with_postpnr()),
    ];
    let apps = ["gaussian", "unsharp", "camera", "harris", "resnet"];

    let mut rows = Vec::new();
    let mut points = Json::Arr(vec![]);
    let mut errs_all = Vec::new();
    let mut errs_fast = Vec::new();
    for (ci, (cname, cfg)) in configs.iter().enumerate() {
        for app in apps {
            let c = compile_dense(app, cfg, ctx, fast, seed)?;
            let sta_ps = c.sta.period_ps;
            let gl_ps = gate_level_period_ps(
                &c.design,
                &ctx.graph,
                &GateLevelParams { seed: seed + ci as u64, ..Default::default() },
            );
            let err = (sta_ps - gl_ps) / gl_ps;
            errs_all.push(err);
            if 1e6 / gl_ps > 500.0 {
                errs_fast.push(err);
            }
            rows.push(vec![
                app.to_string(),
                cname.to_string(),
                format!("{:.2}", sta_ps / 1000.0),
                format!("{:.2}", gl_ps / 1000.0),
                format!("{:.1}%", err * 100.0),
            ]);
            let mut p = Json::obj();
            p.set("app", app)
                .set("config", *cname)
                .set("sta_period_ns", sta_ps / 1000.0)
                .set("sim_period_ns", gl_ps / 1000.0)
                .set("error", err);
            points.push(p);
        }
    }
    let mean_all = stats::mean(&errs_all);
    let mean_fast = if errs_fast.is_empty() { mean_all } else { stats::mean(&errs_fast) };

    let mut md = md_table(
        &["app", "pipelining", "STA period (ns)", "sim period (ns)", "STA error"],
        &rows,
    );
    md.push_str(&format!(
        "\nSTA is pessimistic for every point (sim <= STA). Mean error: {:.1}% overall, {:.1}% above 500 MHz (paper: 13%).\n",
        mean_all * 100.0,
        mean_fast * 100.0
    ));

    let mut j = Json::obj();
    j.set("points", points)
        .set("mean_error", mean_all)
        .set("mean_error_above_500mhz", mean_fast);
    emit("fig6", "Fig. 6 — STA model vs gate-level simulation", &md, &j);

    // Invariant of the figure: pessimism.
    if errs_all.iter().any(|&e| e < -1e-9) {
        return Err("STA was optimistic for some point".into());
    }
    Ok(())
}
