//! Dense-application experiments: Fig. 7 (incremental techniques),
//! Table I (freq/runtime/power), Fig. 8 (EDP), Fig. 9 (flush hardening).

use crate::pipeline::{CompileCtx, PipelineConfig};
use crate::util::json::Json;

use super::common::{compile_dense, emit, md_table, DenseRow};

const APPS: [&str; 5] = ["gaussian", "unsharp", "camera", "harris", "resnet"];

/// Fig. 7: runtime after each incremental software pipelining technique,
/// derived from the STA model (as in the paper).
pub fn fig7(ctx: &CompileCtx, fast: bool, seed: u64) -> Result<(), String> {
    // §VIII-B: "In these experiments, we have applied the hardware
    // technique described in Section VI" — the flush network is hardened
    // at every ladder step (Fig. 9 isolates its effect separately).
    let ladder: Vec<(&str, PipelineConfig)> = PipelineConfig::ladder()
        .into_iter()
        .map(|(n, c)| (n, PipelineConfig { hardened_flush: true, ..c }))
        .collect();
    let mut rows = Vec::new();
    let mut j_apps = Json::Arr(vec![]);
    for app in APPS {
        let mut cells = vec![app.to_string()];
        let mut base_runtime = None;
        let mut j_steps = Json::Arr(vec![]);
        for (cname, cfg) in &ladder {
            let c = compile_dense(app, cfg, ctx, fast, seed)?;
            let row = DenseRow::from_compiled(app, cname, &c);
            let base = *base_runtime.get_or_insert(row.runtime_ms);
            cells.push(format!("{:.3} ({:.2}x)", row.runtime_ms, base / row.runtime_ms));
            let mut js = row.to_json();
            js.set("speedup_vs_unpipelined", base / row.runtime_ms);
            j_steps.push(js);
        }
        rows.push(cells);
        let mut ja = Json::obj();
        ja.set("app", app).set("steps", j_steps);
        j_apps.push(ja);
    }
    let headers: Vec<&str> = std::iter::once("app (runtime ms, speedup)")
        .chain(ladder.iter().map(|(n, _)| *n))
        .collect();
    let md = md_table(&headers, &rows);
    let mut j = Json::obj();
    j.set("apps", j_apps);
    emit("fig7", "Fig. 7 — incremental software pipelining (dense)", &md, &j);
    Ok(())
}

/// Table I: unpipelined vs fully pipelined frequency, runtime, power.
pub fn table1(ctx: &CompileCtx, fast: bool, seed: u64) -> Result<(), String> {
    let mut rows = Vec::new();
    let mut j_rows = Json::Arr(vec![]);
    let mut pairs = Vec::new();
    for app in APPS {
        let un = compile_dense(app, &PipelineConfig::none(), ctx, fast, seed)?;
        let pi = compile_dense(app, &PipelineConfig::full(), ctx, fast, seed)?;
        let run = DenseRow::from_compiled(app, "unpipelined", &un);
        let rpi = DenseRow::from_compiled(app, "pipelined", &pi);
        for r in [&run, &rpi] {
            rows.push(vec![
                r.config.clone(),
                r.app.clone(),
                format!("{:.0}", r.fmax_mhz),
                format!("{:.3}", r.runtime_ms),
                format!("{:.0}", r.power.total_mw()),
            ]);
            j_rows.push(r.to_json());
        }
        pairs.push((run, rpi));
    }
    let mut md = md_table(
        &["", "application", "Frequency (MHz)", "Runtime (ms/frame)", "Power (mW)"],
        &rows,
    );
    // Shape checks the paper reports in §VIII-B.
    let mut notes = String::new();
    for (un, pi) in &pairs {
        let rt_red = 100.0 * (1.0 - pi.runtime_ms / un.runtime_ms);
        let cp_ratio = un.crit_ns / pi.crit_ns;
        notes.push_str(&format!(
            "- {}: critical path {:.1}x lower, runtime -{:.0}%\n",
            un.app, cp_ratio, rt_red
        ));
    }
    md.push_str("\n");
    md.push_str(&notes);
    md.push_str("(paper: 84-97% runtime decrease; 7-34x lower critical path)\n");
    let mut j = Json::obj();
    j.set("rows", j_rows);
    emit("table1", "Table I — dense frequency / runtime / power", &md, &j);
    Ok(())
}

/// Fig. 8: EDP of unpipelined vs fully software-pipelined dense apps.
pub fn fig8(ctx: &CompileCtx, fast: bool, seed: u64) -> Result<(), String> {
    let mut rows = Vec::new();
    let mut j_rows = Json::Arr(vec![]);
    let mut reductions = Vec::new();
    for app in APPS {
        let un = compile_dense(app, &PipelineConfig::none(), ctx, fast, seed)?;
        let pi = compile_dense(app, &PipelineConfig::full(), ctx, fast, seed)?;
        let e0 = DenseRow::from_compiled(app, "unpipelined", &un).edp();
        let e1 = DenseRow::from_compiled(app, "pipelined", &pi).edp();
        let red = 100.0 * (1.0 - e1 / e0);
        reductions.push(1.0 - e1 / e0);
        rows.push(vec![
            app.to_string(),
            format!("{:.3}", e0),
            format!("{:.4}", e1),
            format!("{:.1}%", red),
            format!("{:.1}x", e0 / e1),
        ]);
        let mut jr = Json::obj();
        jr.set("app", app)
            .set("edp_unpipelined", e0)
            .set("edp_pipelined", e1)
            .set("reduction", 1.0 - e1 / e0);
        j_rows.push(jr);
    }
    let avg = crate::util::stats::mean(&reductions) * 100.0;
    let mut md = md_table(
        &["app", "EDP unpipelined (mJ*ms)", "EDP pipelined", "reduction", "ratio"],
        &rows,
    );
    md.push_str(&format!("\nAverage EDP reduction: {avg:.1}% (paper: 95% average, 7-190x).\n"));
    let mut j = Json::obj();
    j.set("rows", j_rows).set("avg_reduction_pct", avg);
    emit("fig8", "Fig. 8 — dense EDP, unpipelined vs pipelined", &md, &j);
    Ok(())
}

/// Fig. 9: impact of hardening the flush broadcast (all software
/// pipelining applied in both arms, §VIII-C).
pub fn fig9(ctx: &CompileCtx, fast: bool, seed: u64) -> Result<(), String> {
    let mut rows = Vec::new();
    let mut j_rows = Json::Arr(vec![]);
    for app in APPS {
        let routed = compile_dense(app, &PipelineConfig::all_software(), ctx, fast, seed)?;
        let hardened = compile_dense(app, &PipelineConfig::full(), ctx, fast, seed)?;
        let r0 = DenseRow::from_compiled(app, "routed flush", &routed);
        let r1 = DenseRow::from_compiled(app, "hardened flush", &hardened);
        let red = 100.0 * (1.0 - r1.runtime_ms / r0.runtime_ms);
        rows.push(vec![
            app.to_string(),
            format!("{:.3}", r0.runtime_ms),
            format!("{:.3}", r1.runtime_ms),
            format!("{:.1}%", red),
        ]);
        let mut jr = Json::obj();
        jr.set("app", app)
            .set("runtime_routed_ms", r0.runtime_ms)
            .set("runtime_hardened_ms", r1.runtime_ms)
            .set("reduction_pct", red);
        j_rows.push(jr);
    }
    let mut md = md_table(
        &["app", "runtime, routed flush (ms)", "runtime, hardened flush (ms)", "reduction"],
        &rows,
    );
    md.push_str("\n(paper: hardening reduces runtime by 31-56%)\n");
    let mut j = Json::obj();
    j.set("rows", j_rows);
    emit("fig9", "Fig. 9 — flush broadcast hardening (hardware technique)", &md, &j);
    Ok(())
}
