//! Shared experiment infrastructure: the dense benchmark registry, compile
//! dispatch (with/without low unrolling duplication), sparse runtime
//! measurement, and report emission.

use crate::apps::App;
use crate::pipeline::{
    compile, compile_with_dup, CompileCtx, Compiled, PipelineConfig, PostPnrParams,
};
use crate::sim::power::{estimate, EnergyModel, PowerEstimate};
use crate::sparse::sim::simulate_app;
use crate::util::json::Json;

/// Paper-scale dense benchmark registry: (name, builder, w, h, unroll).
pub type DenseBuilder = fn(u64, u64, u64) -> App;

pub fn dense_specs() -> Vec<(&'static str, DenseBuilder, u64, u64, u64)> {
    vec![
        ("gaussian", crate::apps::dense::gaussian as DenseBuilder, 6400, 4800, 16),
        ("unsharp", crate::apps::dense::unsharp as DenseBuilder, 1536, 2560, 4),
        ("camera", crate::apps::dense::camera as DenseBuilder, 2560, 1920, 4),
        ("harris", crate::apps::dense::harris as DenseBuilder, 1530, 2554, 4),
    ]
}

/// Post-PnR iteration cap applied by `--fast` runs (and respected by the
/// halving search when sizing its top rung, so promoted budgets never
/// exceed what `tune` would collapse them to anyway).
pub const FAST_MAX_POSTPNR_ITERS: usize = 25;

/// Scale down annealing/iteration effort for `--fast` runs. Idempotent:
/// `tune(tune(c, true), true) == tune(c, true)`, so the explore engine can
/// fold it into effective configs before content-hashing them.
pub fn tune(cfg: &PipelineConfig, fast: bool) -> PipelineConfig {
    let mut c = cfg.clone();
    if fast {
        if let Some(p) = &mut c.postpnr {
            *p = PostPnrParams { max_iters: p.max_iters.min(FAST_MAX_POSTPNR_ITERS), ..p.clone() };
        }
        c.place_effort = c.place_effort.min(0.35);
    }
    c
}

/// Compile a dense benchmark by name under a pipeline config, honouring
/// the config's `unroll_dup` flag (ResNet is not duplicable — its lanes
/// share broadcast inputs — so it always compiles directly, as in the
/// paper where duplication applies to the image pipelines).
pub fn compile_dense(
    name: &str,
    cfg: &PipelineConfig,
    ctx: &CompileCtx,
    fast: bool,
    seed: u64,
) -> Result<Compiled, String> {
    let cfg = tune(cfg, fast);
    if name == "resnet" {
        let app = crate::apps::dense::resnet_conv5x();
        return compile(&app, ctx, &cfg, seed).map_err(|e| format!("{name}: {e}"));
    }
    let (_, builder, w, h, u) = dense_specs()
        .into_iter()
        .find(|(n, ..)| *n == name)
        .ok_or_else(|| format!("unknown dense app {name}"))?;
    if cfg.unroll_dup {
        compile_with_dup(&builder, w, h, u, ctx, &cfg, seed).map_err(|e| format!("{name}: {e}"))
    } else {
        let app = builder(w, h, u);
        compile(&app, ctx, &cfg, seed).map_err(|e| format!("{name}: {e}"))
    }
}

/// Critical-path delay (ns) and EDP (mJ*ms) for a dense benchmark under a
/// config, reusing a cached `cascade explore` result when one exists.
///
/// The explore engine keys its persistent metrics cache by the *effective*
/// configuration (after `tune`), the app, the seed and the architecture,
/// so any summary point that a prior exploration already compiled is
/// served from `results/explore_cache/` without recompiling. Freshly
/// computed points are stored back, so `cascade exp summary` also warms
/// the cache for later explorations. `use_cache = false` skips the lookup
/// (but still stores) — the records have no notion of compiler version,
/// so force a recompute after changing any compiler pass.
pub fn dense_crit_edp(
    name: &str,
    cfg: &PipelineConfig,
    ctx: &CompileCtx,
    fast: bool,
    seed: u64,
    use_cache: bool,
) -> Result<(f64, f64), String> {
    use crate::explore::cache::{point_key, DiskCache, PointMetrics};
    let effective = tune(cfg, fast);
    let key = point_key(name, &effective, seed, "paper", &ctx.arch);
    let disk = DiskCache::open_default();
    if use_cache {
        if let Some(m) = disk.load(key) {
            disk.artifacts().note_use(key);
            return Ok((m.crit_ns, m.edp));
        }
    }
    let c = compile_dense(name, cfg, ctx, fast, seed)?;
    let m = PointMetrics::from_compiled(&c);
    disk.store(key, &m);
    // Persist the compiled artifact too: a later `cascade encode
    // --from-cache` or sparse/simulation re-run rehydrates it instead of
    // recompiling.
    disk.artifacts().store(key, &c);
    Ok((m.crit_ns, m.edp))
}

/// One dense measurement row.
#[derive(Debug, Clone)]
pub struct DenseRow {
    pub app: String,
    pub config: String,
    pub crit_ns: f64,
    pub fmax_mhz: f64,
    pub runtime_ms: f64,
    pub power: PowerEstimate,
}

impl DenseRow {
    pub fn from_compiled(app: &str, config: &str, c: &Compiled) -> DenseRow {
        // A duplicated design was compiled as one region; the full array
        // runs `copies` electrically identical regions.
        let copies = c.dup.as_ref().map(|p| p.copies).unwrap_or(1);
        let power = crate::sim::power::estimate_scaled(
            &c.design,
            c.fmax_mhz(),
            copies,
            &EnergyModel::default(),
        );
        DenseRow {
            app: app.to_string(),
            config: config.to_string(),
            crit_ns: c.sta.period_ps / 1000.0,
            fmax_mhz: c.fmax_mhz(),
            runtime_ms: c.runtime_ms(),
            power,
        }
    }

    pub fn edp(&self) -> f64 {
        self.power.edp(self.runtime_ms)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("app", self.app.as_str())
            .set("config", self.config.as_str())
            .set("crit_ns", self.crit_ns)
            .set("fmax_mhz", self.fmax_mhz)
            .set("runtime_ms", self.runtime_ms)
            .set("power_mw", self.power.total_mw())
            .set("edp_mj_ms", self.edp());
        o
    }
}

/// Sparse measurement row: functional sim supplies the cycle count.
#[derive(Debug, Clone)]
pub struct SparseRow {
    pub app: String,
    pub config: String,
    pub crit_ns: f64,
    pub fmax_mhz: f64,
    pub cycles: u64,
    pub runtime_us: f64,
    pub power: PowerEstimate,
}

impl SparseRow {
    pub fn edp(&self) -> f64 {
        // mW * us^2 -> nJ*us; keep consistent units across rows.
        self.power.total_mw() * self.runtime_us * self.runtime_us * 1e-3
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("app", self.app.as_str())
            .set("config", self.config.as_str())
            .set("crit_ns", self.crit_ns)
            .set("fmax_mhz", self.fmax_mhz)
            .set("cycles", self.cycles)
            .set("runtime_us", self.runtime_us)
            .set("power_mw", self.power.total_mw())
            .set("edp", self.edp());
        o
    }
}

/// Compile + measure one sparse benchmark under a config (no cache
/// consultation — see [`measure_sparse_cached`]).
pub fn measure_sparse(
    app: &App,
    cfg: &PipelineConfig,
    ctx: &CompileCtx,
    fast: bool,
    seed: u64,
) -> Result<SparseRow, String> {
    measure_sparse_cached(app, cfg, ctx, fast, seed, false)
}

/// [`measure_sparse`] backed by the explore artifact store: with
/// `use_cache`, a previously compiled artifact for the same effective
/// point is rehydrated (fingerprint-verified against the metrics record
/// when one exists) instead of recompiled, and a cached cycle count skips
/// the functional simulation too. Fresh compiles store both the artifact
/// and the metrics record back, so `cascade exp summary` both consumes
/// and warms the cache `cascade explore` uses.
pub fn measure_sparse_cached(
    app: &App,
    cfg: &PipelineConfig,
    ctx: &CompileCtx,
    fast: bool,
    seed: u64,
    use_cache: bool,
) -> Result<SparseRow, String> {
    use crate::explore::cache::{point_key, DiskCache, PointMetrics};
    let cfg = tune(cfg, fast);
    let key = point_key(app.name, &cfg, seed, "paper", &ctx.arch);
    let disk = DiskCache::open_default();
    let record = disk.load(key);
    let warm = if use_cache {
        disk.artifacts().load(key, record.as_ref().map(|m| m.artifact_fp))
    } else {
        None
    };
    let cached = warm.is_some();
    let c = match warm {
        Some(c) => c,
        None => compile(app, ctx, &cfg, seed).map_err(|e| format!("{}: {e}", app.name))?,
    };
    // A warm metrics record supplies the cycle count; otherwise run the
    // ready-valid functional simulation of the (possibly rehydrated) DFG.
    let cycles = match (&record, cached) {
        (Some(m), true) if m.cycles > 0 => m.cycles,
        _ => {
            let data = crate::apps::sparse::data_for(app.name, 42);
            simulate_app(app.name, &c.design.dfg, &data).cycles
        }
    };
    if !cached {
        // A recompute (cache miss or forced with `use_cache = false`)
        // refreshes the record unconditionally: the new artifact's
        // fingerprint must replace a stale record's `artifact_fp`, or the
        // pair would disagree forever and every later cached run would
        // reject the artifact.
        disk.artifacts().store(key, &c);
        disk.store(key, &PointMetrics::from_sparse(&c, cycles));
    } else if record.is_none() {
        // Rehydrated artifact without a record (records lost, artifacts
        // kept): back-fill it so the next run skips the simulation too.
        disk.store(key, &PointMetrics::from_sparse(&c, cycles));
    }
    let power = estimate(&c.design, c.fmax_mhz(), &EnergyModel::default());
    Ok(SparseRow {
        app: app.name.to_string(),
        config: String::new(),
        crit_ns: c.sta.period_ps / 1000.0,
        fmax_mhz: c.fmax_mhz(),
        cycles,
        runtime_us: cycles as f64 / c.fmax_mhz(),
        power,
    })
}

/// Emit a report: print markdown, write `results/<id>.md` and
/// `results/<id>.json`.
pub fn emit(id: &str, title: &str, markdown: &str, json: &Json) {
    println!("\n## {title}\n");
    println!("{markdown}");
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write(format!("results/{id}.md"), format!("# {title}\n\n{markdown}\n"));
    let _ = std::fs::write(format!("results/{id}.json"), json.to_string_pretty());
    println!("(wrote results/{id}.md, results/{id}.json)");
}

/// Markdown table helper.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str(&format!("| {} |\n", headers.join(" | ")));
    s.push_str(&format!("|{}\n", "---|".repeat(headers.len())));
    for r in rows {
        s.push_str(&format!("| {} |\n", r.join(" | ")));
    }
    s
}
