//! Regenerators for every table and figure in the paper's evaluation
//! (§VIII). Each writes a markdown table to stdout and machine-readable
//! JSON + markdown into `results/`.
//!
//! | id      | paper artefact | module fn            |
//! |---------|----------------|----------------------|
//! | fig6    | STA vs gate-level sim scatter | [`fig6::run`] |
//! | fig7    | incremental dense techniques  | [`dense_exp::fig7`] |
//! | table1  | dense freq/runtime/power      | [`dense_exp::table1`] |
//! | fig8    | dense EDP                     | [`dense_exp::fig8`] |
//! | fig9    | flush hardening               | [`dense_exp::fig9`] |
//! | fig10   | incremental sparse techniques | [`sparse_exp::fig10`] |
//! | table2  | sparse freq/runtime/power     | [`sparse_exp::table2`] |
//! | fig11   | sparse EDP                    | [`sparse_exp::fig11`] |
//! | summary | headline ratios (abstract)    | [`summary::run`] |

pub mod common;
pub mod fig6;
pub mod dense_exp;
pub mod sparse_exp;
pub mod summary;

use crate::pipeline::CompileCtx;

/// Run an experiment by id. `fast` shrinks annealing effort and iteration
/// caps (CI mode); results keep their shape but are noisier. `use_cache`
/// lets `summary` reuse persistent `cascade explore` results
/// (`results/explore_cache/`); pass `false` (CLI `--no-cache`) to force
/// recompilation, e.g. after changing a compiler pass.
pub fn run(
    id: &str,
    ctx: &CompileCtx,
    fast: bool,
    seed: u64,
    use_cache: bool,
) -> Result<(), String> {
    match id {
        "fig6" => fig6::run(ctx, fast, seed),
        "fig7" => dense_exp::fig7(ctx, fast, seed),
        "table1" => dense_exp::table1(ctx, fast, seed),
        "fig8" => dense_exp::fig8(ctx, fast, seed),
        "fig9" => dense_exp::fig9(ctx, fast, seed),
        "fig10" => sparse_exp::fig10(ctx, fast, seed),
        "table2" => sparse_exp::table2(ctx, fast, seed),
        "fig11" => sparse_exp::fig11(ctx, fast, seed),
        "summary" => summary::run(ctx, fast, seed, use_cache),
        "all" => {
            for id in ALL_IDS {
                run(id, ctx, fast, seed, use_cache)?;
            }
            Ok(())
        }
        other => Err(format!("unknown experiment '{other}'")),
    }
}

/// Every experiment id, in paper order.
pub const ALL_IDS: [&str; 9] =
    ["fig6", "fig7", "table1", "fig8", "fig9", "fig10", "table2", "fig11", "summary"];
