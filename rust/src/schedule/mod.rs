//! Static cycle-accurate scheduling (paper §III-C and §V-F).
//!
//! Dense image-processing / ML applications on this class of CGRA are
//! statically scheduled: all memory accesses are resolved at compile time,
//! and every MEM tile runs an affine address generator programmed from the
//! schedule. The compiler we build on [16] assigns every statement in the
//! application's iteration domain a one-dimensional timestamp; here that
//! manifests as:
//!
//! * a [`WorkloadShape`] describing the iteration domain (frame geometry,
//!   spatial unrolling, and the time-multiplexing factor for reductions);
//! * a [`Schedule`] carrying the cycle totals and per-MEM-node address
//!   generator parameters;
//! * [`reschedule`] — the paper's §V-F two-round flow: the first
//!   compilation round treats all compute latencies as zero; after
//!   place-and-route and pipelining, the real latencies are known and the
//!   schedule is regenerated so data still arrives on the cycles the
//!   memory controllers expect.

use std::collections::BTreeMap;

use crate::dfg::ir::{Dfg, NodeId, Op};

/// Iteration-domain description of one application run.
#[derive(Debug, Clone)]
pub struct WorkloadShape {
    /// Frame width in pixels (row length seen by line buffers).
    pub frame_w: u64,
    /// Frame height.
    pub frame_h: u64,
    /// Spatial unrolling: output pixels produced per cycle.
    pub unroll: u64,
    /// Time multiplexing factor: cycles of accumulation per output (1 for
    /// pure stencils; >1 for channel-reduced convolutions like ResNet).
    pub time_mult: u64,
}

impl WorkloadShape {
    pub fn stencil(frame_w: u64, frame_h: u64, unroll: u64) -> WorkloadShape {
        WorkloadShape { frame_w, frame_h, unroll, time_mult: 1 }
    }

    /// Steady-state compute cycles (excluding fill latency).
    pub fn steady_cycles(&self) -> u64 {
        (self.frame_w * self.frame_h).div_ceil(self.unroll) * self.time_mult
    }
}

/// Address-generator configuration for one MEM node (encoded into
/// `MemParam` bitstream words).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemSchedule {
    /// Loop extents, innermost first.
    pub extents: Vec<u32>,
    /// Strides per loop level (address delta per iteration).
    pub strides: Vec<i32>,
    /// Cycle offset at which this generator starts (set by scheduling;
    /// updated by `reschedule` after pipelining).
    pub start_offset: u32,
}

/// A complete static schedule for an application.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Cycles to process one frame, including fill latency and the fixed
    /// controller startup overhead.
    pub total_cycles: u64,
    /// Pipeline + algorithmic fill latency (cycles before the first valid
    /// output).
    pub fill_latency: u64,
    /// Per-MEM-node address generator configs.
    pub mem_params: BTreeMap<NodeId, MemSchedule>,
    /// The shape this schedule was generated for.
    pub shape: WorkloadShape,
}

/// Fixed controller startup overhead (configuration settle + flush
/// distribution), in cycles.
pub const STARTUP_OVERHEAD: u64 = 32;

/// Generate the static schedule for a mapped DFG.
///
/// `fill_latency` is the maximum arrival cycle across output nodes — the
/// BDM arrival analysis — which includes both algorithmic delays (line
/// buffers / window taps) and any pipelining registers currently on edges.
/// In the first compilation round the graph carries no pipelining, so this
/// reproduces the paper's "set all computation latencies to 0" round.
pub fn schedule(g: &Dfg, shape: &WorkloadShape) -> Schedule {
    let arrivals = g.arrival_cycles();
    let fill_latency = g
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| matches!(n.op, Op::Output { .. }))
        .map(|(i, _)| arrivals[i])
        .max()
        .unwrap_or(0);

    let mut mem_params = BTreeMap::new();
    for (i, node) in g.nodes.iter().enumerate() {
        let id = i as NodeId;
        match &node.op {
            Op::Delay { cycles, .. } if node.tile_kind() == crate::arch::params::TileKind::Mem => {
                // Line buffer: circular buffer of `cycles` words, one
                // read + one write per cycle.
                mem_params.insert(
                    id,
                    MemSchedule {
                        extents: vec![*cycles],
                        strides: vec![1],
                        start_offset: arrivals[i].saturating_sub(node.latency() as u64) as u32,
                    },
                );
            }
            Op::Rom { values } => {
                mem_params.insert(
                    id,
                    MemSchedule {
                        extents: vec![values.len() as u32, shape.time_mult as u32],
                        strides: vec![1, 0],
                        start_offset: arrivals[i].saturating_sub(1) as u32,
                    },
                );
            }
            _ => {}
        }
    }

    Schedule {
        total_cycles: shape.steady_cycles() + fill_latency + STARTUP_OVERHEAD,
        fill_latency,
        mem_params,
        shape: shape.clone(),
    }
}

/// §V-F: regenerate the schedule after pipelining changed compute
/// latencies. The mapped application graph topology is unchanged, so only
/// offsets and totals move; extents and strides must be identical.
pub fn reschedule(g: &Dfg, old: &Schedule) -> Schedule {
    let new = schedule(g, &old.shape);
    debug_assert_eq!(new.mem_params.len(), old.mem_params.len());
    for (id, ms) in &new.mem_params {
        if let Some(prev) = old.mem_params.get(id) {
            debug_assert_eq!(ms.extents, prev.extents, "topology changed during pipelining");
            debug_assert_eq!(ms.strides, prev.strides);
        }
    }
    new
}

/// Runtime of one frame at a clock frequency, in milliseconds.
pub fn runtime_ms(sched: &Schedule, freq_mhz: f64) -> f64 {
    sched.total_cycles as f64 / (freq_mhz * 1e6) * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::build::stencil;
    use crate::dfg::ir::{Dfg, Op};

    fn gaussian_like() -> Dfg {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let w = vec![vec![1, 2, 1], vec![2, 4, 2], vec![1, 2, 1]];
        let s = stencil(&mut g, i, 64, &w, "g");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(s, o, 0);
        g
    }

    #[test]
    fn steady_cycles_scale_with_unroll() {
        let s1 = WorkloadShape::stencil(640, 480, 1);
        let s4 = WorkloadShape::stencil(640, 480, 4);
        assert_eq!(s1.steady_cycles(), 640 * 480);
        assert_eq!(s4.steady_cycles(), 640 * 480 / 4);
    }

    #[test]
    fn fill_latency_includes_window() {
        let g = gaussian_like();
        let shape = WorkloadShape::stencil(64, 64, 1);
        let s = schedule(&g, &shape);
        // 3x3 window on width 64: 2*64+2 = 130 cycles of algorithmic delay.
        assert_eq!(s.fill_latency, 130);
        assert_eq!(s.total_cycles, 64 * 64 + 130 + STARTUP_OVERHEAD);
    }

    #[test]
    fn mem_params_cover_line_buffers() {
        let g = gaussian_like();
        let s = schedule(&g, &WorkloadShape::stencil(64, 64, 1));
        // 3x3 stencil on width 64: row taps produce Delay{62} MEM nodes
        // (after the two column taps) — exactly 2 line buffers.
        let lb: Vec<_> = s.mem_params.values().collect();
        assert_eq!(lb.len(), 2);
        for ms in lb {
            assert_eq!(ms.strides, vec![1]);
        }
    }

    #[test]
    fn reschedule_updates_latency_only() {
        let mut g = gaussian_like();
        let shape = WorkloadShape::stencil(64, 64, 1);
        let round1 = schedule(&g, &shape);
        // Pipelining: enable input regs on every ALU (adds latency).
        for n in 0..g.nodes.len() {
            if matches!(g.nodes[n].op, Op::Alu { .. }) {
                g.nodes[n].input_regs = true;
            }
        }
        let round2 = reschedule(&g, &round1);
        assert!(round2.fill_latency > round1.fill_latency);
        assert_eq!(
            round2.total_cycles - round2.fill_latency,
            round1.total_cycles - round1.fill_latency,
            "steady-state throughput unchanged by pipelining"
        );
        // Offsets moved with arrivals; extents identical.
        for (id, ms) in &round2.mem_params {
            assert_eq!(ms.extents, round1.mem_params[id].extents);
        }
    }

    #[test]
    fn runtime_math() {
        let g = gaussian_like();
        let s = schedule(&g, &WorkloadShape::stencil(64, 64, 1));
        let r = runtime_ms(&s, 100.0);
        let expected = s.total_cycles as f64 / 1e8 * 1e3;
        assert!((r - expected).abs() < 1e-12);
    }

    #[test]
    fn accum_apps_use_time_mult() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let r = g.add_node(Op::Rom { values: vec![1, 2, 3, 4] }, "w");
        let acc = g.add_node(Op::Accum { period: 4 }, "acc");
        let o = g.add_node(Op::Output { lane: 0, decimate: 4 }, "o");
        g.connect(i, acc, 0);
        g.connect(r, acc, 1);
        g.connect(acc, o, 0);
        let shape = WorkloadShape { frame_w: 8, frame_h: 8, unroll: 1, time_mult: 4 };
        let s = schedule(&g, &shape);
        assert_eq!(s.total_cycles - s.fill_latency - STARTUP_OVERHEAD, 8 * 8 * 4);
        assert!(s.mem_params.contains_key(&r));
    }
}
