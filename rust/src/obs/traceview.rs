//! `cascade trace` — render the span trees a serve request log records.
//!
//! Every successful `compile`/`encode` request writes a `"trace"` object
//! (protocol v3, [`crate::serve::proto::trace_json`]) into the daemon's
//! JSONL request log. This viewer turns those records back into
//! something a human can read:
//!
//! * a **flame table** per trace — the span tree indented by depth, each
//!   span with its wall time, its share of the root, and the kernel work
//!   counters of its own lap (`docs/observability.md`);
//! * the **critical path** — the greedy max-child walk from the root,
//!   with each hop's *self* time (what its own children do not explain),
//!   so "where did the milliseconds go" has a one-line answer even when
//!   the trace spans several nodes;
//! * a **per-hop attribution** line — front vs each `backend:<addr>`
//!   subtree — for routed topologies.
//!
//! ```text
//! cascade trace serve_requests.jsonl            # every trace, log order
//! cascade trace serve_requests.jsonl --top 3    # the 3 slowest
//! cascade trace serve_requests.jsonl --id HEX   # one trace by id
//! ```
//!
//! The viewer is a pure consumer: it never writes, and a log with no
//! traces (pre-v3, or `--log none`) just says so.

use crate::serve::proto::{trace_from_json, TraceSpan};
use crate::util::cli::Args;
use crate::util::json::Json;

/// One traced request out of the log.
struct Rec {
    ts: u64,
    op: String,
    id: u64,
    spans: Vec<TraceSpan>,
}

impl Rec {
    /// The root span: the one whose parent is not itself a recorded span
    /// (the wire contract numbers it `base + 1` with parent `base`).
    fn root(&self) -> Option<&TraceSpan> {
        self.spans
            .iter()
            .find(|s| !self.spans.iter().any(|t| t.id == s.parent))
    }

    fn children(&self, of: u64) -> Vec<&TraceSpan> {
        let mut c: Vec<&TraceSpan> = self.spans.iter().filter(|s| s.parent == of).collect();
        c.sort_by_key(|s| s.id);
        c
    }
}

/// Parse a request log's traced records, skipping everything else
/// (lifecycle events, untraced ops, unparseable lines — a rotated or
/// truncated log must not kill the viewer).
fn parse_log(text: &str) -> Vec<Rec> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(line) else { continue };
        let Some(t) = j.get("trace") else { continue };
        let Ok((id, spans)) = trace_from_json(t) else { continue };
        if spans.is_empty() {
            continue;
        }
        out.push(Rec {
            ts: j.get("ts").and_then(Json::as_u64).unwrap_or(0),
            op: j.get("op").and_then(Json::as_str).unwrap_or("?").to_string(),
            id,
            spans,
        });
    }
    out
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

fn pct(part: u64, whole: u64) -> f64 {
    if whole == 0 {
        100.0
    } else {
        part as f64 * 100.0 / whole as f64
    }
}

fn counters_inline(s: &TraceSpan) -> String {
    s.counters
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Inclusive time minus the children's inclusive time — the span's own
/// work (clamped: clock skew across hops can make children sum past the
/// parent by a hair).
fn self_ns(rec: &Rec, s: &TraceSpan) -> u64 {
    let kids: u64 = rec.children(s.id).iter().map(|c| c.ns).sum();
    s.ns.saturating_sub(kids)
}

fn render_flame(rec: &Rec, out: &mut String) {
    let Some(root) = rec.root() else { return };
    let total = root.ns;
    out.push_str(&format!(
        "{:<42} {:>10} {:>6}  counters\n",
        "span", "ms", "%"
    ));
    let mut stack: Vec<(u64, usize)> = vec![(root.id, 0)];
    while let Some((id, depth)) = stack.pop() {
        let Some(s) = rec.spans.iter().find(|s| s.id == id) else { continue };
        let label = format!("{}{}", "  ".repeat(depth), s.name);
        out.push_str(&format!(
            "{:<42} {:>10.3} {:>6.1}  {}\n",
            label,
            ms(s.ns),
            pct(s.ns, total),
            counters_inline(s)
        ));
        // Depth-first, children in id order (push reversed so the
        // smallest id pops first).
        for c in rec.children(s.id).into_iter().rev() {
            stack.push((c.id, depth + 1));
        }
    }
}

/// The greedy max-child walk: at every span, descend into the child that
/// consumed the most wall time. Each hop is attributed its self time.
fn render_critical_path(rec: &Rec, out: &mut String) {
    let Some(root) = rec.root() else { return };
    let total = root.ns;
    let mut path = Vec::new();
    let mut cur = root;
    loop {
        path.push(cur);
        match rec.children(cur.id).into_iter().max_by_key(|c| c.ns) {
            Some(next) => cur = next,
            None => break,
        }
    }
    let names: Vec<&str> = path.iter().map(|s| s.name.as_str()).collect();
    out.push_str(&format!("critical path: {}\n", names.join(" > ")));
    let attributed: Vec<String> = path
        .iter()
        .map(|s| {
            let own = self_ns(rec, s);
            format!("{} {:.3} ms ({:.1}%)", s.name, ms(own), pct(own, total))
        })
        .collect();
    out.push_str(&format!("  self time:   {}\n", attributed.join(" | ")));
}

/// Front-vs-backend attribution for routed traces: every `backend:<addr>`
/// span roots one remote hop; whatever the root's time they do not cover
/// is this daemon's own hop.
fn render_hops(rec: &Rec, out: &mut String) {
    let Some(root) = rec.root() else { return };
    let backends: Vec<&TraceSpan> =
        rec.spans.iter().filter(|s| s.name.starts_with("backend:")).collect();
    if backends.is_empty() {
        return;
    }
    let remote: u64 = backends.iter().map(|s| s.ns).sum();
    let mut parts =
        vec![format!("front {:.3} ms ({:.1}%)", ms(root.ns.saturating_sub(remote)), pct(root.ns.saturating_sub(remote), root.ns))];
    for b in backends {
        parts.push(format!("{} {:.3} ms ({:.1}%)", b.name, ms(b.ns), pct(b.ns, root.ns)));
    }
    out.push_str(&format!("hops:          {}\n", parts.join(" | ")));
}

/// Render one trace block (header + flame table + attribution lines).
fn render(rec: &Rec) -> String {
    let mut out = String::new();
    let total = rec.root().map(|r| r.ns).unwrap_or(0);
    out.push_str(&format!(
        "trace {:016x}  op={} ts={} total={:.3} ms spans={}\n",
        rec.id,
        rec.op,
        rec.ts,
        ms(total),
        rec.spans.len()
    ));
    render_flame(rec, &mut out);
    render_critical_path(rec, &mut out);
    render_hops(rec, &mut out);
    out
}

/// `cascade trace <requests.jsonl> [--id HEX] [--top N]`.
pub fn trace_cli(args: &Args) -> Result<(), String> {
    let path = args
        .positionals
        .get(1)
        .ok_or("trace: expected a request-log path (serve --log writes one)")?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("trace: cannot read {path}: {e}"))?;
    let mut recs = parse_log(&text);
    if recs.is_empty() {
        println!("trace: no traced requests in {path}");
        return Ok(());
    }
    if let Some(hex) = args.opt("id") {
        let want = u64::from_str_radix(hex, 16)
            .map_err(|_| format!("trace: bad --id '{hex}' (hex)"))?;
        recs.retain(|r| r.id == want);
        if recs.is_empty() {
            return Err(format!("trace: no trace {hex} in {path}"));
        }
    } else if let Some(s) = args.opt("top") {
        let n: usize = s
            .parse()
            .ok()
            .filter(|&n| n >= 1)
            .ok_or_else(|| format!("trace: bad --top '{s}' (positive integer)"))?;
        recs.sort_by_key(|r| std::cmp::Reverse(r.root().map(|s| s.ns).unwrap_or(0)));
        recs.truncate(n);
    }
    for (i, rec) in recs.iter().enumerate() {
        if i > 0 {
            println!();
        }
        print!("{}", render(rec));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, parent: u64, name: &str, ns: u64) -> TraceSpan {
        TraceSpan { id, parent, name: name.into(), ns, counters: Vec::new() }
    }

    /// A routed compile's tree: front spans 1..3, backend spans 4..8
    /// grafted under the forward span.
    fn routed_rec() -> Rec {
        let mut stage = span(7, 6, "stage:place", 60_000_000);
        stage.counters = vec![("place_moves_proposed".into(), 1200)];
        Rec {
            ts: 1,
            op: "compile".into(),
            id: 0xabcd,
            spans: vec![
                span(1, 0, "request", 100_000_000),
                span(2, 1, "queue", 1_000_000),
                span(3, 1, "forward", 99_000_000),
                span(4, 3, "backend:127.0.0.1:7871", 95_000_000),
                span(5, 4, "queue", 2_000_000),
                span(6, 4, "exec", 93_000_000),
                stage,
                span(8, 6, "stage:route", 20_000_000),
            ],
        }
    }

    #[test]
    fn log_parsing_skips_untraced_and_garbage_lines() {
        let log = concat!(
            "{\"event\":\"start\",\"ts\":1}\n",
            "not json\n",
            "{\"event\":\"request\",\"op\":\"ping\",\"ts\":2}\n",
            "{\"event\":\"request\",\"op\":\"compile\",\"ts\":3,\"trace\":{\"id\":\"00000000000000ff\",\
             \"spans\":[{\"id\":1,\"parent\":0,\"name\":\"request\",\"ns\":5000}]}}\n",
        );
        let recs = parse_log(log);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].id, 0xff);
        assert_eq!(recs[0].op, "compile");
        assert_eq!(recs[0].root().unwrap().name, "request");
    }

    #[test]
    fn flame_table_indents_by_depth_and_shows_counters() {
        let r = render(&routed_rec());
        assert!(r.contains("trace 000000000000abcd"), "{r}");
        // Depth-ordered rows: request at depth 0, the backend hop under
        // the forward span, stages under the backend's exec span.
        let req_at = r.find("\nrequest").expect("root row");
        let fwd_at = r.find("\n  forward").expect("forward row");
        let hop_at = r.find("\n    backend:127.0.0.1:7871").expect("hop row");
        let stage_at = r.find("\n        stage:place").expect("stage row");
        assert!(req_at < fwd_at && fwd_at < hop_at && hop_at < stage_at, "{r}");
        assert!(r.contains("place_moves_proposed=1200"), "{r}");
        // Shares are of the root.
        assert!(r.contains("100.0"), "{r}");
    }

    #[test]
    fn critical_path_is_the_greedy_max_child_walk() {
        let r = render(&routed_rec());
        assert!(
            r.contains(
                "critical path: request > forward > backend:127.0.0.1:7871 > exec > stage:place"
            ),
            "{r}"
        );
        // stage:place's self time is its whole 60 ms (no children);
        // exec's self time is 93 - (60 + 20) = 13 ms.
        assert!(r.contains("exec 13.000 ms"), "{r}");
        assert!(r.contains("stage:place 60.000 ms (60.0%)"), "{r}");
    }

    #[test]
    fn hop_attribution_splits_front_from_backends() {
        let r = render(&routed_rec());
        assert!(r.contains("hops:"), "{r}");
        assert!(r.contains("front 5.000 ms (5.0%)"), "{r}");
        assert!(r.contains("backend:127.0.0.1:7871 95.000 ms (95.0%)"), "{r}");
        // A single-daemon trace has no hop line.
        let solo = Rec {
            ts: 0,
            op: "compile".into(),
            id: 1,
            spans: vec![span(1, 0, "request", 10), span(2, 1, "exec", 8)],
        };
        assert!(!render(&solo).contains("hops:"));
    }
}
