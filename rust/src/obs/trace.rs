//! Lightweight per-thread stage tracing for the compile pipeline.
//!
//! The pipeline's entry points ([`crate::pipeline::compile`] and
//! friends) are hot, widely called, and must stay byte-deterministic —
//! so tracing is **pull-based and thread-local**: nothing is measured
//! unless the caller installs a sink with [`with_spans`], and a
//! [`mark`] with no sink installed is a single TLS load (no
//! `Instant::now()`, no allocation). Installing a sink can never change
//! what the pipeline computes, only record when it happened.
//!
//! The timing model is a *lap clock*, not bracketed regions: the sink
//! remembers one `Instant`, and each `mark(stage)` attributes the whole
//! interval since the previous mark (or since installation) to `stage`.
//! Laps are contiguous by construction, so the spans of one traced call
//! sum to the wall-clock time from installation to the final mark —
//! which is what lets the e2e test assert "per-stage spans sum to within
//! 5% of the wall-clock compile time" without chasing unattributed gaps.
//!
//! A compile runs on a single thread (parallelism in this toolkit is
//! across points, never within one compile), so thread-local state is
//! exactly the right scope: concurrent sweep workers trace independently
//! without synchronization.

use std::cell::RefCell;
use std::time::Instant;

/// One timed stage interval, in wall-clock nanoseconds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    pub stage: &'static str,
    pub nanos: u64,
}

/// Canonical stage order for reports (histograms sort alphabetically on
/// the wire; human tables read better in pipeline order).
pub const STAGE_ORDER: &[&str] = &[
    "fuse",
    "map",
    "pipeline",
    "schedule",
    "place",
    "route",
    "realize",
    "postpnr",
    "reschedule",
    "sta",
    "measure",
    "encode",
];

struct Sink {
    last: Instant,
    spans: Vec<SpanRecord>,
}

thread_local! {
    static SINK: RefCell<Option<Sink>> = const { RefCell::new(None) };
}

/// Whether a sink is installed on this thread (cheap; for callers that
/// want to skip building span metadata entirely).
pub fn enabled() -> bool {
    SINK.with(|s| s.borrow().is_some())
}

/// Close the current lap and attribute it to `stage`. No-op (and no
/// clock read) when no sink is installed on this thread.
pub fn mark(stage: &'static str) {
    SINK.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            let now = Instant::now();
            let nanos = now.duration_since(sink.last).as_nanos().min(u64::MAX as u128) as u64;
            sink.spans.push(SpanRecord { stage, nanos });
            sink.last = now;
        }
    });
}

/// Restores the previously installed sink even if `f` panics, so a
/// failing compile in a test harness cannot leak a sink into the
/// thread's next unrelated compile.
struct Restore {
    prev: Option<Sink>,
    taken: bool,
}

impl Restore {
    fn finish(&mut self) -> Vec<SpanRecord> {
        self.taken = true;
        SINK.with(|s| {
            let mut slot = s.borrow_mut();
            let done = slot.take();
            *slot = self.prev.take();
            done.map(|d| d.spans).unwrap_or_default()
        })
    }
}

impl Drop for Restore {
    fn drop(&mut self) {
        if !self.taken {
            let _ = self.finish();
        }
    }
}

/// Run `f` with a fresh lap clock installed on this thread, returning
/// its result plus every span [`mark`]ed during the call. Nests: an
/// outer trace is suspended, not corrupted, while an inner one runs.
pub fn with_spans<T>(f: impl FnOnce() -> T) -> (T, Vec<SpanRecord>) {
    let prev = SINK.with(|s| {
        s.borrow_mut().replace(Sink { last: Instant::now(), spans: Vec::new() })
    });
    let mut guard = Restore { prev, taken: false };
    let out = f();
    let spans = guard.finish();
    (out, spans)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marks_without_a_sink_are_noops() {
        assert!(!enabled());
        mark("map"); // must not panic or record anywhere
        let (_, spans) = with_spans(|| ());
        assert!(spans.is_empty(), "no marks -> no spans");
    }

    #[test]
    fn laps_are_contiguous_and_ordered() {
        let t0 = Instant::now();
        let ((), spans) = with_spans(|| {
            std::hint::black_box((0..20_000u64).sum::<u64>());
            mark("map");
            std::hint::black_box((0..20_000u64).sum::<u64>());
            mark("place");
            mark("route"); // zero-work lap is fine
        });
        let wall = t0.elapsed().as_nanos() as u64;
        assert_eq!(
            spans.iter().map(|s| s.stage).collect::<Vec<_>>(),
            vec!["map", "place", "route"]
        );
        let sum: u64 = spans.iter().map(|s| s.nanos).sum();
        assert!(sum <= wall, "laps cannot exceed the enclosing wall clock");
        assert!(!enabled(), "sink uninstalled after with_spans");
    }

    #[test]
    fn traces_nest_without_corruption() {
        let ((), outer) = with_spans(|| {
            mark("map");
            let ((), inner) = with_spans(|| {
                mark("place");
            });
            assert_eq!(inner.len(), 1);
            assert_eq!(inner[0].stage, "place");
            mark("sta");
        });
        let stages: Vec<_> = outer.iter().map(|s| s.stage).collect();
        assert_eq!(stages, vec!["map", "sta"], "inner trace spans stay out of the outer sink");
    }

    #[test]
    fn panicking_trace_restores_the_previous_sink() {
        let ((), spans) = with_spans(|| {
            let r = std::panic::catch_unwind(|| {
                let (_, _s) = with_spans(|| -> () { panic!("boom") });
            });
            assert!(r.is_err());
            mark("after");
        });
        assert_eq!(spans.len(), 1, "outer sink survives an inner panic");
        assert_eq!(spans[0].stage, "after");
        assert!(!enabled());
    }
}
