//! Lightweight per-thread stage tracing for the compile pipeline.
//!
//! The pipeline's entry points ([`crate::pipeline::compile`] and
//! friends) are hot, widely called, and must stay byte-deterministic —
//! so tracing is **pull-based and thread-local**: nothing is measured
//! unless the caller installs a sink with [`with_spans`], and a
//! [`mark`] with no sink installed is a single TLS load (no
//! `Instant::now()`, no allocation). Installing a sink can never change
//! what the pipeline computes, only record when it happened.
//!
//! The timing model is a *lap clock*, not bracketed regions: the sink
//! remembers one `Instant`, and each `mark(stage)` attributes the whole
//! interval since the previous mark (or since installation) to `stage`.
//! Laps are contiguous by construction, so the spans of one traced call
//! sum to the wall-clock time from installation to the final mark —
//! which is what lets the e2e test assert "per-stage spans sum to within
//! 5% of the wall-clock compile time" without chasing unattributed gaps.
//!
//! A compile runs on a single thread (parallelism in this toolkit is
//! across points, never within one compile), so thread-local state is
//! exactly the right scope: concurrent sweep workers trace independently
//! without synchronization.

use std::cell::RefCell;
use std::time::Instant;

/// One timed stage interval, in wall-clock nanoseconds, plus the kernel
/// counters ([`crate::obs::counters`]) bumped during that lap — empty
/// unless the traced code bumped any (only the hot kernels do).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    pub stage: &'static str,
    pub nanos: u64,
    pub counters: Vec<(&'static str, u64)>,
}

/// Canonical stage order for reports (histograms sort alphabetically on
/// the wire; human tables read better in pipeline order).
pub const STAGE_ORDER: &[&str] = &[
    "fuse",
    "map",
    "pipeline",
    "schedule",
    "place",
    "route",
    "realize",
    "postpnr",
    "reschedule",
    "sta",
    "measure",
    "encode",
];

struct Sink {
    last: Instant,
    spans: Vec<SpanRecord>,
}

thread_local! {
    static SINK: RefCell<Option<Sink>> = const { RefCell::new(None) };
}

/// Whether a sink is installed on this thread (cheap; for callers that
/// want to skip building span metadata entirely).
pub fn enabled() -> bool {
    SINK.with(|s| s.borrow().is_some())
}

/// Close the current lap and attribute it to `stage`. No-op (and no
/// clock read) when no sink is installed on this thread.
pub fn mark(stage: &'static str) {
    // The counter drain happens *outside* the sink borrow: `drain` takes
    // its own TLS slot and returns the lap's kernel counters (empty when
    // the counter sink is off or nothing bumped).
    let installed = SINK.with(|s| s.borrow().is_some());
    if !installed {
        return;
    }
    let counters = super::counters::drain();
    SINK.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            let now = Instant::now();
            let nanos = now.duration_since(sink.last).as_nanos().min(u64::MAX as u128) as u64;
            sink.spans.push(SpanRecord { stage, nanos, counters });
            sink.last = now;
        }
    });
}

/// Restores the previously installed sink even if `f` panics, so a
/// failing compile in a test harness cannot leak a sink into the
/// thread's next unrelated compile.
struct Restore {
    prev: Option<Sink>,
    taken: bool,
}

impl Restore {
    fn finish(&mut self) -> Vec<SpanRecord> {
        self.taken = true;
        SINK.with(|s| {
            let mut slot = s.borrow_mut();
            let done = slot.take();
            *slot = self.prev.take();
            done.map(|d| d.spans).unwrap_or_default()
        })
    }
}

impl Drop for Restore {
    fn drop(&mut self) {
        if !self.taken {
            let _ = self.finish();
        }
    }
}

/// Run `f` with a fresh lap clock installed on this thread, returning
/// its result plus every span [`mark`]ed during the call. Nests: an
/// outer trace is suspended, not corrupted, while an inner one runs.
///
/// A kernel-counter sink ([`crate::obs::counters`]) is installed for the
/// same scope, so each span comes back with the counters its lap bumped
/// — `with_spans` is the one switch that turns the whole instrumentation
/// layer on.
pub fn with_spans<T>(f: impl FnOnce() -> T) -> (T, Vec<SpanRecord>) {
    let prev = SINK.with(|s| {
        s.borrow_mut().replace(Sink { last: Instant::now(), spans: Vec::new() })
    });
    let mut guard = Restore { prev, taken: false };
    // Counters bumped after the final mark have no owning lap and are
    // dropped with the inner sink (the pipeline always marks last).
    let (out, _) = super::counters::with_counters(f);
    let spans = guard.finish();
    (out, spans)
}

// ---------------------------------------------------------------------
// Publish relay + trace ids (distributed tracing, ISSUE 10)
// ---------------------------------------------------------------------

thread_local! {
    static PUBLISH: RefCell<Option<Vec<SpanRecord>>> = const { RefCell::new(None) };
}

/// Hand a finished compile's spans to whoever installed [`with_publish`]
/// further up this thread's stack — the serve worker does, around each
/// request, so the stage spans recorded deep inside the dedup slot reach
/// the request's span tree. No-op (one TLS load) without a collector;
/// dedup *waiters* publish nothing, which is correct — they compiled
/// nothing.
pub fn publish(spans: &[SpanRecord]) {
    PUBLISH.with(|p| {
        if let Some(sink) = p.borrow_mut().as_mut() {
            sink.extend_from_slice(spans);
        }
    });
}

/// Run `f` with a span collector installed on this thread, returning its
/// result plus everything [`publish`]ed during the call. The previous
/// collector (if any) is restored afterwards, panic included.
pub fn with_publish<T>(f: impl FnOnce() -> T) -> (T, Vec<SpanRecord>) {
    struct Guard {
        prev: Option<Vec<SpanRecord>>,
    }
    impl Drop for Guard {
        fn drop(&mut self) {
            PUBLISH.with(|p| *p.borrow_mut() = self.prev.take());
        }
    }
    let prev = PUBLISH.with(|p| p.borrow_mut().replace(Vec::new()));
    let guard = Guard { prev };
    let out = f();
    let spans = PUBLISH.with(|p| {
        p.borrow_mut().replace(Vec::new()).unwrap_or_default()
    });
    drop(guard);
    (out, spans)
}

/// A fresh 64-bit trace id: a splitmix64 step over the wall clock mixed
/// with a process-wide counter, so concurrent requests in one daemon and
/// across daemons practically never collide. Never zero (zero reads as
/// "absent" on the wire).
pub fn gen_trace_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SALT: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut z = nanos ^ SALT.fetch_add(0x9e37_79b9_7f4a_7c15, Ordering::Relaxed);
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    if z == 0 {
        1
    } else {
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marks_without_a_sink_are_noops() {
        assert!(!enabled());
        mark("map"); // must not panic or record anywhere
        let (_, spans) = with_spans(|| ());
        assert!(spans.is_empty(), "no marks -> no spans");
    }

    #[test]
    fn laps_are_contiguous_and_ordered() {
        let t0 = Instant::now();
        let ((), spans) = with_spans(|| {
            std::hint::black_box((0..20_000u64).sum::<u64>());
            mark("map");
            std::hint::black_box((0..20_000u64).sum::<u64>());
            mark("place");
            mark("route"); // zero-work lap is fine
        });
        let wall = t0.elapsed().as_nanos() as u64;
        assert_eq!(
            spans.iter().map(|s| s.stage).collect::<Vec<_>>(),
            vec!["map", "place", "route"]
        );
        let sum: u64 = spans.iter().map(|s| s.nanos).sum();
        assert!(sum <= wall, "laps cannot exceed the enclosing wall clock");
        assert!(!enabled(), "sink uninstalled after with_spans");
    }

    #[test]
    fn traces_nest_without_corruption() {
        let ((), outer) = with_spans(|| {
            mark("map");
            let ((), inner) = with_spans(|| {
                mark("place");
            });
            assert_eq!(inner.len(), 1);
            assert_eq!(inner[0].stage, "place");
            mark("sta");
        });
        let stages: Vec<_> = outer.iter().map(|s| s.stage).collect();
        assert_eq!(stages, vec!["map", "sta"], "inner trace spans stay out of the outer sink");
    }

    #[test]
    fn spans_carry_the_counters_of_their_own_lap() {
        let ((), spans) = with_spans(|| {
            super::super::counters::bump("place_moves_proposed", 4);
            mark("place");
            super::super::counters::bump("route_dijkstra_pops", 9);
            mark("route");
            mark("sta");
        });
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].counters, vec![("place_moves_proposed", 4)]);
        assert_eq!(spans[1].counters, vec![("route_dijkstra_pops", 9)]);
        assert!(spans[2].counters.is_empty(), "zero-work lap carries no counters");
    }

    #[test]
    fn publish_reaches_the_installed_collector_and_only_it() {
        publish(&[SpanRecord { stage: "orphan", nanos: 1, counters: Vec::new() }]);
        let ((), published) = with_publish(|| {
            let (_, spans) = with_spans(|| mark("map"));
            publish(&spans);
        });
        assert_eq!(published.len(), 1);
        assert_eq!(published[0].stage, "map");
        let ((), outer) = with_publish(|| {
            let ((), inner) = with_publish(|| {
                publish(&[SpanRecord { stage: "in", nanos: 2, counters: Vec::new() }]);
            });
            assert_eq!(inner.len(), 1);
        });
        assert!(outer.is_empty(), "inner publishes stay out of the outer collector");
    }

    #[test]
    fn trace_ids_are_nonzero_and_distinct() {
        let a = gen_trace_id();
        let b = gen_trace_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn panicking_trace_restores_the_previous_sink() {
        let ((), spans) = with_spans(|| {
            let r = std::panic::catch_unwind(|| {
                let (_, _s) = with_spans(|| -> () { panic!("boom") });
            });
            assert!(r.is_err());
            mark("after");
        });
        assert_eq!(spans.len(), 1, "outer sink survives an inner panic");
        assert_eq!(spans[0].stage, "after");
        assert!(!enabled());
    }
}
