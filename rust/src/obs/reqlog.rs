//! Size-bounded structured JSONL event/request log.
//!
//! One JSON object per line, appended under a mutex with a single
//! `write_all` per record (same torn-line policy as the partial-results
//! and LRU journals). When appending a record would push the file past
//! its byte cap, the file rotates first: the current log is renamed to
//! `<path>.1` (replacing any previous `.1`) and a fresh file starts —
//! so disk usage is bounded by roughly twice the cap, and the newest
//! records are always in `<path>`.
//!
//! Like the cache stores, an unopenable path degrades to a no-op handle
//! rather than failing the daemon: observability must never take the
//! service down.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::json::Json;

/// Default rotation bound: 16 MiB per file (×2 files on disk).
pub const DEFAULT_LOG_CAP: u64 = 16 << 20;

struct Sink {
    file: Option<File>,
    written: u64,
}

/// Append-only JSONL log with size-bounded rotation.
pub struct RequestLog {
    path: PathBuf,
    cap: u64,
    inner: Mutex<Sink>,
}

impl RequestLog {
    /// Open (appending) the log at `path`, rotating when a record would
    /// push the file past `cap_bytes` (clamped to at least 1 KiB). An
    /// existing file's size counts against the cap immediately, so a
    /// restarted daemon respects the same bound.
    pub fn open(path: impl AsRef<Path>, cap_bytes: u64) -> RequestLog {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(parent);
            }
        }
        let written = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let file = OpenOptions::new().append(true).create(true).open(&path).ok();
        RequestLog {
            path,
            cap: cap_bytes.max(1 << 10),
            inner: Mutex::new(Sink { file, written }),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Path of the rotated-out predecessor file.
    pub fn rotated_path(&self) -> PathBuf {
        let mut name = self.path.as_os_str().to_os_string();
        name.push(".1");
        PathBuf::from(name)
    }

    /// Append one record as a single compact JSON line. Rotates first
    /// when the line would overflow the cap (a single record larger
    /// than the whole cap still lands, alone, in a fresh file).
    pub fn append(&self, record: &Json) {
        let mut line = record.to_string_compact();
        line.push('\n');
        let mut sink = self.inner.lock().unwrap();
        if sink.written > 0 && sink.written + line.len() as u64 > self.cap {
            // Rotate: close, rename current -> .1, start fresh.
            sink.file = None;
            let _ = std::fs::rename(&self.path, self.rotated_path());
            sink.file = OpenOptions::new().append(true).create(true).open(&self.path).ok();
            sink.written = 0;
        }
        if let Some(f) = sink.file.as_mut() {
            if f.write_all(line.as_bytes()).is_ok() {
                sink.written += line.len() as u64;
            }
        }
    }

    /// Bytes written to the current (post-rotation) file.
    pub fn written(&self) -> u64 {
        self.inner.lock().unwrap().written
    }
}

/// Milliseconds since the Unix epoch — the `ts` member of log records.
/// (The log is operational telemetry; nothing deterministic reads it.)
pub fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cascade-reqlog-{tag}-{}.jsonl", std::process::id()))
    }

    fn rec(i: usize) -> Json {
        let mut o = Json::obj();
        o.set("op", "ping").set("i", i as u64);
        o
    }

    #[test]
    fn appends_one_parseable_line_per_record() {
        let path = tmp("basic");
        let _ = std::fs::remove_file(&path);
        let log = RequestLog::open(&path, 1 << 20);
        for i in 0..10 {
            log.append(&rec(i));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 10);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).expect("every line parses");
            assert_eq!(j.get("i").and_then(Json::as_u64), Some(i as u64));
        }
        assert_eq!(log.written(), text.len() as u64);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rotates_at_the_size_bound() {
        let path = tmp("rotate");
        let _ = std::fs::remove_file(&path);
        let log = RequestLog::open(&path, 1); // clamped to 1 KiB
        let line_len = {
            let mut l = rec(0).to_string_compact();
            l.push('\n');
            l.len() as u64
        };
        let per_file = (1u64 << 10) / line_len;
        // Enough records to force at least two rotations.
        let total = (per_file * 2 + 3) as usize;
        for i in 0..total {
            log.append(&rec(i));
        }
        let cur = std::fs::read_to_string(&path).unwrap();
        let old = std::fs::read_to_string(log.rotated_path()).unwrap();
        assert!(cur.len() as u64 <= 1 << 10, "current file respects the cap");
        assert!(old.len() as u64 <= 1 << 10, "rotated file respects the cap");
        // The newest record is in the current file; no record is torn.
        let last = cur.lines().last().unwrap();
        assert_eq!(
            Json::parse(last).unwrap().get("i").and_then(Json::as_u64),
            Some((total - 1) as u64)
        );
        for line in cur.lines().chain(old.lines()) {
            assert!(Json::parse(line).is_ok(), "torn line: {line:?}");
        }
        // Exactly two files ever exist: current + one predecessor.
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(log.rotated_path());
    }

    #[test]
    fn reopen_counts_existing_bytes_against_the_cap() {
        let path = tmp("reopen");
        let _ = std::fs::remove_file(&path);
        {
            let log = RequestLog::open(&path, 1 << 10);
            for i in 0..5 {
                log.append(&rec(i));
            }
        }
        let before = std::fs::metadata(&path).unwrap().len();
        let log = RequestLog::open(&path, 1 << 10);
        assert_eq!(log.written(), before, "restart resumes the byte account");
        // An oversized single record rotates and lands alone.
        let mut big = Json::obj();
        big.set("pad", "x".repeat(2 << 10));
        log.append(&big);
        assert!(log.rotated_path().exists());
        let cur = std::fs::read_to_string(&path).unwrap();
        assert_eq!(cur.lines().count(), 1, "oversized record lands alone in a fresh file");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(log.rotated_path());
    }

    #[test]
    fn unopenable_path_degrades_to_noop() {
        let log = RequestLog::open("/dev/null/not-a-dir/x.jsonl", 1 << 20);
        log.append(&rec(0)); // must not panic
        assert_eq!(log.written(), 0);
    }

    #[test]
    fn now_ms_is_sane() {
        let t = now_ms();
        assert!(t > 1_600_000_000_000, "epoch millis after 2020");
    }
}
