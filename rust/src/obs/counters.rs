//! Lightweight per-thread kernel counters for the compile pipeline.
//!
//! Same contract as the stage tracer ([`crate::obs::trace`]): nothing is
//! counted unless the caller installs a sink — [`with_counters`], or
//! implicitly [`crate::obs::with_spans`], which installs both sinks so
//! stage spans come back with the counters of their lap attached — and a
//! [`bump`] with no sink installed is a single TLS load. The hot kernels
//! (`pnr/place`, `pnr/route`, `timing/sta`, `dfg/fuse`) accumulate their
//! tallies in plain local integers either way and bump the sink **once**
//! per kernel call, so the disabled path costs one TLS load per call and
//! the enabled path can never perturb what the kernel computes — only
//! report how hard it worked.
//!
//! Counter names are `&'static str` by design: the vocabulary is the
//! fixed set of kernel counters documented in `docs/observability.md`
//! (`place_moves_proposed`, `route_dijkstra_pops`, ...), surfaced as
//! `compile_kernel_<name>` metrics series and as per-span `counters`
//! objects in request-log traces.

use std::cell::RefCell;

/// One thread's accumulating sink: a small association list. The
/// vocabulary is ~a dozen names bumped a handful of times per compile,
/// so linear scan beats any map.
struct Sink {
    counts: Vec<(&'static str, u64)>,
}

thread_local! {
    static SINK: RefCell<Option<Sink>> = const { RefCell::new(None) };
}

/// Whether a counter sink is installed on this thread.
pub fn enabled() -> bool {
    SINK.with(|s| s.borrow().is_some())
}

/// Add `n` to counter `name`. No-op (one TLS load) without a sink.
pub fn bump(name: &'static str, n: u64) {
    SINK.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            match sink.counts.iter_mut().find(|(k, _)| *k == name) {
                Some(e) => e.1 = e.1.saturating_add(n),
                None => sink.counts.push((name, n)),
            }
        }
    });
}

/// Take everything accumulated since installation (or the previous
/// drain), leaving the sink installed and empty — the stage tracer calls
/// this at each lap boundary so every span carries exactly the counters
/// of its own lap. Returns sorted by name (deterministic output order
/// regardless of bump order). No-op `vec![]` without a sink.
pub fn drain() -> Vec<(&'static str, u64)> {
    SINK.with(|s| match s.borrow_mut().as_mut() {
        Some(sink) => {
            let mut out = std::mem::take(&mut sink.counts);
            out.sort_by_key(|(k, _)| *k);
            out
        }
        None => Vec::new(),
    })
}

/// Restores the previously installed sink even if `f` panics (same
/// pattern as the tracer's guard).
struct Restore {
    prev: Option<Sink>,
    taken: bool,
}

impl Restore {
    fn finish(&mut self) -> Vec<(&'static str, u64)> {
        self.taken = true;
        SINK.with(|s| {
            let mut slot = s.borrow_mut();
            let done = slot.take();
            *slot = self.prev.take();
            let mut out = done.map(|d| d.counts).unwrap_or_default();
            out.sort_by_key(|(k, _)| *k);
            out
        })
    }
}

impl Drop for Restore {
    fn drop(&mut self) {
        if !self.taken {
            let _ = self.finish();
        }
    }
}

/// Run `f` with a fresh counter sink on this thread, returning its
/// result plus every counter bumped during the call, sorted by name.
/// Nests like [`crate::obs::with_spans`]: an outer sink is suspended,
/// not corrupted, while the inner one runs.
pub fn with_counters<T>(f: impl FnOnce() -> T) -> (T, Vec<(&'static str, u64)>) {
    let prev = SINK.with(|s| s.borrow_mut().replace(Sink { counts: Vec::new() }));
    let mut guard = Restore { prev, taken: false };
    let out = f();
    let counts = guard.finish();
    (out, counts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bumps_without_a_sink_are_noops() {
        assert!(!enabled());
        bump("place_moves_proposed", 7); // must not panic or record anywhere
        let (_, counts) = with_counters(|| ());
        assert!(counts.is_empty(), "no bumps -> no counts");
    }

    #[test]
    fn counts_accumulate_and_come_back_sorted() {
        let ((), counts) = with_counters(|| {
            bump("route_dijkstra_pops", 5);
            bump("place_moves_proposed", 2);
            bump("route_dijkstra_pops", 3);
        });
        assert_eq!(
            counts,
            vec![("place_moves_proposed", 2), ("route_dijkstra_pops", 8)]
        );
        assert!(!enabled(), "sink uninstalled after with_counters");
    }

    #[test]
    fn drain_empties_but_keeps_the_sink() {
        let ((), counts) = with_counters(|| {
            bump("a", 1);
            assert_eq!(drain(), vec![("a", 1)]);
            assert!(enabled(), "drain keeps the sink installed");
            bump("b", 2);
        });
        assert_eq!(counts, vec![("b", 2)], "drained counts never double-report");
        assert!(drain().is_empty(), "drain without a sink is a no-op");
    }

    #[test]
    fn sinks_nest_without_corruption() {
        let ((), outer) = with_counters(|| {
            bump("outer", 1);
            let ((), inner) = with_counters(|| bump("inner", 9));
            assert_eq!(inner, vec![("inner", 9)]);
            bump("outer", 1);
        });
        assert_eq!(outer, vec![("outer", 2)], "inner counts stay out of the outer sink");
    }

    #[test]
    fn panicking_scope_restores_the_previous_sink() {
        let ((), counts) = with_counters(|| {
            let r = std::panic::catch_unwind(|| {
                let _ = with_counters(|| -> () { panic!("boom") });
            });
            assert!(r.is_err());
            bump("after", 1);
        });
        assert_eq!(counts, vec![("after", 1)], "outer sink survives an inner panic");
        assert!(!enabled());
    }
}
