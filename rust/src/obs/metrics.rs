//! Process-wide metrics: atomic counters, gauges and log₂-bucketed
//! latency histograms behind a [`Registry`] with a **deterministic**
//! Prometheus-style text exposition.
//!
//! Everything here is std-only and lock-light: a series handle is an
//! `Arc` around atomics, so the hot path (bumping a counter, observing a
//! latency) is a single `fetch_add` with no registry lock. The registry
//! lock is taken only to *register* a series (get-or-create) and to
//! render an exposition — both cold paths.
//!
//! Determinism is a contract, not an accident: series are stored in
//! `BTreeMap`s (stable iteration order), every exposed number derives
//! from an integer (bucket bounds are exact powers of two in
//! microseconds, sums are integer nanoseconds), and float formatting is
//! never involved — so two expositions of the same counter state are
//! byte-identical, which the unit tests and the CI serve-smoke job both
//! assert.
//!
//! Series names carry their labels inline, Prometheus-style:
//! `compile_stage_seconds{stage="place"}`. The *family* (the part before
//! `{`) gets one `# HELP` / `# TYPE` header; [`labeled`] builds such
//! names without format-string escapes at every call site.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A value that can go up or down (bytes resident, entries, queue depth).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of finite histogram buckets. Bucket `i` covers values up to
/// `2^i` microseconds inclusive; `2^39` µs ≈ 6.4 days, beyond which the
/// overflow (`+Inf`) bucket counts.
pub const BUCKETS: usize = 40;

/// Log₂-bucketed latency histogram over integer microseconds.
///
/// Observations are exact integers, so quantile readout is exact *per
/// bucket*: [`Histogram::quantile`] returns the upper bound of the
/// bucket containing the requested rank — a deterministic value that
/// over-reports by at most 2× (the bucket width), never under-reports.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    overflow: AtomicU64,
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            overflow: AtomicU64::new(0),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }
}

/// Bucket index for a value in microseconds: the smallest `i` with
/// `v <= 2^i`, or `BUCKETS` for the overflow bucket.
pub fn bucket_index(us: u64) -> usize {
    if us <= 1 {
        return 0;
    }
    let i = (64 - (us - 1).leading_zeros()) as usize;
    i.min(BUCKETS)
}

/// Upper bound of finite bucket `i`, in microseconds.
pub fn bucket_bound_us(i: usize) -> u64 {
    1u64 << i
}

/// Point-in-time copy of a histogram's state (for profile reports and
/// tests; the exposition reads the live atomics itself).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistoSnapshot {
    pub counts: Vec<u64>,
    pub overflow: u64,
    pub count: u64,
    pub sum_nanos: u64,
}

impl Histogram {
    /// Record one observation of `us` microseconds.
    pub fn observe_us(&self, us: u64) {
        let i = bucket_index(us);
        if i < BUCKETS {
            self.counts[i].fetch_add(1, Ordering::Relaxed);
        } else {
            self.overflow.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(us.saturating_mul(1000), Ordering::Relaxed);
    }

    /// Record one observation given in nanoseconds (bucketed at
    /// microsecond resolution, rounded up so nothing becomes "free";
    /// the sum keeps full nanosecond precision).
    pub fn observe_nanos(&self, ns: u64) {
        let us = ns.div_ceil(1000);
        let i = bucket_index(us);
        if i < BUCKETS {
            self.counts[i].fetch_add(1, Ordering::Relaxed);
        } else {
            self.overflow.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn observe_duration(&self, d: std::time::Duration) {
        self.observe_nanos(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_nanos(&self) -> u64 {
        self.sum_nanos.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistoSnapshot {
        HistoSnapshot {
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            overflow: self.overflow.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
            sum_nanos: self.sum_nanos.load(Ordering::Relaxed),
        }
    }

    /// The `q`-quantile (`0.0 < q <= 1.0`) as the upper bound, in
    /// microseconds, of the bucket containing that rank. `None` for an
    /// empty histogram; `u64::MAX` when the rank lands in the overflow
    /// bucket.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        let snap = self.snapshot();
        quantile_of(&snap, q)
    }

    pub fn p50(&self) -> Option<u64> {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> Option<u64> {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> Option<u64> {
        self.quantile(0.999)
    }
}

/// Quantile readout over a snapshot (shared by [`Histogram::quantile`]
/// and report code that already holds a snapshot).
pub fn quantile_of(snap: &HistoSnapshot, q: f64) -> Option<u64> {
    if snap.count == 0 {
        return None;
    }
    let rank = ((q * snap.count as f64).ceil() as u64).clamp(1, snap.count);
    let mut seen = 0u64;
    for (i, &c) in snap.counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return Some(bucket_bound_us(i));
        }
    }
    Some(u64::MAX)
}

/// `family{key="value"}` without format-escape noise at call sites.
pub fn labeled(family: &str, key: &str, value: &str) -> String {
    format!("{family}{{{key}=\"{value}\"}}")
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn word(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
    /// family -> (kind, help). First registration of a family wins.
    families: BTreeMap<String, (Kind, String)>,
}

/// A set of named series with a deterministic text exposition.
///
/// Each daemon / sweep owns its own registry (so tests and co-resident
/// servers never share counts); [`global`] offers one process-wide
/// instance for embedders that want exactly that sharing.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

fn family_of(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register(&self, name: &str, kind: Kind, help: &str) {
        let mut inner = self.inner.lock().unwrap();
        let fam = family_of(name).to_string();
        inner.families.entry(fam).or_insert_with(|| (kind, help.to_string()));
    }

    /// Get-or-create a counter series. `name` may carry inline labels.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.register(name, Kind::Counter, help);
        let mut inner = self.inner.lock().unwrap();
        inner.counters.entry(name.to_string()).or_default().clone()
    }

    /// Get-or-create a gauge series.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.register(name, Kind::Gauge, help);
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.entry(name.to_string()).or_default().clone()
    }

    /// Get-or-create a histogram series.
    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        self.register(name, Kind::Histogram, help);
        let mut inner = self.inner.lock().unwrap();
        inner.histograms.entry(name.to_string()).or_default().clone()
    }

    /// All counter series whose name starts with `prefix`, in name
    /// order, with current values (the kernel-counter profile table
    /// consumes this).
    pub fn counter_series(&self, prefix: &str) -> Vec<(String, u64)> {
        let inner = self.inner.lock().unwrap();
        inner
            .counters
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(name, c)| (name.clone(), c.get()))
            .collect()
    }

    /// All histogram series whose name starts with `prefix`, in name
    /// order, with snapshots (profile reports consume this).
    pub fn histogram_series(&self, prefix: &str) -> Vec<(String, HistoSnapshot)> {
        let inner = self.inner.lock().unwrap();
        inner
            .histograms
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(name, h)| (name.clone(), h.snapshot()))
            .collect()
    }

    /// Render the Prometheus-style text exposition. Byte-deterministic
    /// for a given counter state: series in name order within families
    /// in name order, all numbers integer-derived.
    pub fn expose(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for (family, (kind, help)) in &inner.families {
            out.push_str(&format!("# HELP {family} {help}\n"));
            out.push_str(&format!("# TYPE {family} {}\n", kind.word()));
            // All series of a family share it as a name prefix, and
            // prefix-sharing strings are contiguous under BTreeMap
            // order — but *other* families can interleave ("x_total"
            // sorts between "x" and "x{op=..}"), so skip those rather
            // than stopping at them.
            match kind {
                Kind::Counter => {
                    for (name, c) in inner.counters.range(family.clone()..) {
                        if !name.starts_with(family.as_str()) {
                            break;
                        }
                        if family_of(name) != family {
                            continue;
                        }
                        out.push_str(&format!("{name} {}\n", c.get()));
                    }
                }
                Kind::Gauge => {
                    for (name, g) in inner.gauges.range(family.clone()..) {
                        if !name.starts_with(family.as_str()) {
                            break;
                        }
                        if family_of(name) != family {
                            continue;
                        }
                        out.push_str(&format!("{name} {}\n", g.get()));
                    }
                }
                Kind::Histogram => {
                    for (name, h) in inner.histograms.range(family.clone()..) {
                        if !name.starts_with(family.as_str()) {
                            break;
                        }
                        if family_of(name) != family {
                            continue;
                        }
                        expose_histogram(&mut out, name, &h.snapshot());
                    }
                }
            }
        }
        out
    }
}

/// Split `compile_stage_seconds{stage="map"}` into
/// (`compile_stage_seconds`, `stage="map"`); the label part is empty for
/// unlabeled series.
fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        None => (name, ""),
        Some(i) => (&name[..i], name[i + 1..].trim_end_matches('}')),
    }
}

/// `name_bucket{labels,le="..."}`-style sub-series name.
fn sub_series(base: &str, labels: &str, suffix: &str, extra: Option<&str>) -> String {
    let mut all = String::new();
    if !labels.is_empty() {
        all.push_str(labels);
    }
    if let Some(e) = extra {
        if !all.is_empty() {
            all.push(',');
        }
        all.push_str(e);
    }
    if all.is_empty() {
        format!("{base}{suffix}")
    } else {
        format!("{base}{suffix}{{{all}}}")
    }
}

/// Exact decimal seconds from an integer count of `unit_per_sec`-ths —
/// no float formatting, so the output is byte-stable. `unit_per_sec`
/// must be 1e6 (microseconds) or 1e9 (nanoseconds).
pub fn secs_str(v: u64, unit_per_sec: u64) -> String {
    let digits = match unit_per_sec {
        1_000_000 => 6,
        1_000_000_000 => 9,
        _ => unreachable!("unsupported unit"),
    };
    let whole = v / unit_per_sec;
    let frac = v % unit_per_sec;
    if frac == 0 {
        format!("{whole}")
    } else {
        let s = format!("{frac:0width$}", width = digits);
        format!("{whole}.{}", s.trim_end_matches('0'))
    }
}

fn expose_histogram(out: &mut String, name: &str, snap: &HistoSnapshot) {
    let (base, labels) = split_labels(name);
    // Cumulative buckets up to the last non-empty finite bucket, then
    // +Inf — compact, and still fully determined by the counter state.
    let last = snap.counts.iter().rposition(|&c| c > 0);
    let mut cum = 0u64;
    if let Some(last) = last {
        for i in 0..=last {
            cum += snap.counts[i];
            let le = secs_str(bucket_bound_us(i), 1_000_000);
            let le = format!("le=\"{le}\"");
            out.push_str(&format!(
                "{} {cum}\n",
                sub_series(base, labels, "_bucket", Some(&le))
            ));
        }
    }
    out.push_str(&format!(
        "{} {}\n",
        sub_series(base, labels, "_bucket", Some("le=\"+Inf\"")),
        snap.count
    ));
    out.push_str(&format!(
        "{} {}\n",
        sub_series(base, labels, "_sum", None),
        secs_str(snap.sum_nanos, 1_000_000_000)
    ));
    out.push_str(&format!("{} {}\n", sub_series(base, labels, "_count", None), snap.count));
}

/// The process-wide registry, for embedders that want every subsystem
/// reporting into one exposition. The CLI's daemon and sweeps use their
/// own instances instead, so co-resident servers (tests!) never share
/// counts.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two_inclusive() {
        // v <= 2^i picks bucket i; boundaries are inclusive above.
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(1025), 11);
        assert_eq!(bucket_index(1 << 39), 39);
        assert_eq!(bucket_index((1 << 39) + 1), BUCKETS, "beyond the last bound -> overflow");
        assert_eq!(bucket_index(u64::MAX), BUCKETS);
    }

    #[test]
    fn quantiles_match_a_known_distribution() {
        let h = Histogram::default();
        for us in 1..=1000u64 {
            h.observe_us(us);
        }
        assert_eq!(h.count(), 1000);
        // Rank 500 is value 500, which lives in the le=512 bucket.
        assert_eq!(h.p50(), Some(512));
        // Rank 990 is value 990 -> le=1024; rank 1000 likewise.
        assert_eq!(h.p99(), Some(1024));
        assert_eq!(h.p999(), Some(1024));
        assert_eq!(h.quantile(1.0), Some(1024));
        // A tiny quantile still returns the first occupied bucket.
        assert_eq!(h.quantile(0.001), Some(1));
        assert_eq!(Histogram::default().p50(), None, "empty histogram has no quantiles");
        // Overflow observations push high quantiles to +Inf (u64::MAX).
        let h2 = Histogram::default();
        h2.observe_us(1);
        h2.observe_us(u64::MAX);
        assert_eq!(h2.p50(), Some(1));
        assert_eq!(h2.quantile(1.0), Some(u64::MAX));
    }

    #[test]
    fn nanos_round_up_to_a_microsecond() {
        let h = Histogram::default();
        h.observe_nanos(1); // 1 ns -> 1 µs bucket, never "free"
        h.observe_nanos(1000);
        h.observe_nanos(1001); // -> 2 µs
        let snap = h.snapshot();
        assert_eq!(snap.counts[0], 2);
        assert_eq!(snap.counts[1], 1);
        assert_eq!(snap.sum_nanos, 2002, "the sum keeps nanosecond precision");
    }

    #[test]
    fn exposition_is_byte_deterministic_and_exact() {
        let make = || {
            let r = Registry::new();
            r.counter("serve_requests_total{op=\"compile\"}", "requests by op").add(3);
            r.counter("serve_requests_total{op=\"ping\"}", "requests by op").inc();
            r.gauge("cache_store_bytes", "artifact store size").set(4096);
            let h = r.histogram("compile_stage_seconds{stage=\"map\"}", "per-stage time");
            h.observe_us(1); // le=0.000001
            h.observe_us(3); // le=0.000004
            h.observe_us(3);
            r
        };
        let a = make().expose();
        let b = make().expose();
        assert_eq!(a, b, "same counter state must expose identical bytes");
        let want = "\
# HELP cache_store_bytes artifact store size
# TYPE cache_store_bytes gauge
cache_store_bytes 4096
# HELP compile_stage_seconds per-stage time
# TYPE compile_stage_seconds histogram
compile_stage_seconds_bucket{stage=\"map\",le=\"0.000001\"} 1
compile_stage_seconds_bucket{stage=\"map\",le=\"0.000002\"} 1
compile_stage_seconds_bucket{stage=\"map\",le=\"0.000004\"} 3
compile_stage_seconds_bucket{stage=\"map\",le=\"+Inf\"} 3
compile_stage_seconds_sum{stage=\"map\"} 0.000007
compile_stage_seconds_count{stage=\"map\"} 3
# HELP serve_requests_total requests by op
# TYPE serve_requests_total counter
serve_requests_total{op=\"compile\"} 3
serve_requests_total{op=\"ping\"} 1
";
        assert_eq!(a, want);
    }

    #[test]
    fn empty_histogram_exposes_only_inf_bucket() {
        let r = Registry::new();
        r.histogram("idle_seconds", "never observed");
        let got = r.expose();
        assert!(got.contains("idle_seconds_bucket{le=\"+Inf\"} 0\n"), "{got}");
        assert!(got.contains("idle_seconds_sum 0\n"), "{got}");
        assert!(got.contains("idle_seconds_count 0\n"), "{got}");
    }

    #[test]
    fn secs_str_is_exact_decimal() {
        assert_eq!(secs_str(0, 1_000_000), "0");
        assert_eq!(secs_str(1, 1_000_000), "0.000001");
        assert_eq!(secs_str(1_048_576, 1_000_000), "1.048576");
        assert_eq!(secs_str(2_000_000, 1_000_000), "2");
        assert_eq!(secs_str(1_500_000_000, 1_000_000_000), "1.5");
        assert_eq!(secs_str(7, 1_000_000_000), "0.000000007");
    }

    #[test]
    fn concurrent_counter_increments_are_exact() {
        const THREADS: usize = 8;
        const BUMPS: usize = 10_000;
        let r = Registry::new();
        let c = r.counter("concurrency_total", "threaded bump test");
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..BUMPS {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), (THREADS * BUMPS) as u64);
        // A re-registration under the same name is the same series.
        assert_eq!(r.counter("concurrency_total", "ignored duplicate help").get(), c.get());
    }

    #[test]
    fn labeled_builds_series_names() {
        assert_eq!(labeled("x_total", "op", "ping"), "x_total{op=\"ping\"}");
        assert_eq!(family_of("x_total{op=\"ping\"}"), "x_total");
        assert_eq!(family_of("x_total"), "x_total");
    }
}
