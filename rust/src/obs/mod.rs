//! Zero-dependency observability: metrics, stage tracing, request log.
//!
//! Three substrates, all std-only, shared by the explorer and the serve
//! daemon (ISSUE 6):
//!
//! * [`metrics`] — a [`Registry`] of atomic [`Counter`]s, [`Gauge`]s
//!   and log₂-bucketed [`Histogram`]s (exact p50/p99/p999 readout) with
//!   a **byte-deterministic** Prometheus-style text exposition. The
//!   daemon's `metrics` wire op and `cascade explore --profile` both
//!   read from here.
//! * [`trace`] — a thread-local lap clock: the compile pipeline
//!   [`trace::mark`]s each stage boundary (map → pipeline → schedule →
//!   place → route → postpnr → reschedule → sta), and a caller that
//!   installed [`trace::with_spans`] gets contiguous per-stage spans
//!   whose sum equals the traced wall clock. With no sink installed a
//!   mark is a TLS load — the pipeline's outputs and (untraced) speed
//!   are untouched.
//! * [`counters`] — a thread-local kernel-counter sink (ISSUE 10): the
//!   PnR/STA/fusion hot kernels tally their work in local integers and
//!   [`counters::bump`] the totals once per call; [`with_spans`] installs
//!   the sink alongside the lap clock so every stage span carries the
//!   counters of its own lap, surfaced as `compile_kernel_*` series and
//!   in request-log span trees.
//! * [`reqlog`] — a size-bounded JSONL [`RequestLog`] (rotate to `.1`
//!   at the cap) for the daemon's per-request records and structured
//!   gc/drain/startup events.
//! * [`traceview`] — the `cascade trace` viewer: renders the request
//!   log's distributed span trees as flame tables with critical-path
//!   and per-hop attribution.
//!
//! The cardinal rule, enforced by the byte-identity tests: observability
//! **never** perturbs outputs. Metrics are write-only side channels,
//! spans are opt-in per thread, and nothing in a report or bitstream
//! ever derives from a clock unless the user asked for a profile.
//!
//! See `docs/observability.md` for series names, the exposition format
//! and the request-log schema.

pub mod counters;
pub mod metrics;
pub mod reqlog;
pub mod trace;
pub mod traceview;

pub use counters::{bump, with_counters};
pub use metrics::{labeled, Counter, Gauge, HistoSnapshot, Histogram, Registry};
pub use reqlog::{now_ms, RequestLog, DEFAULT_LOG_CAP};
pub use trace::{mark, with_spans, SpanRecord, STAGE_ORDER};

/// Help strings for the series families several modules share (one
/// constant each, so explorer and daemon register identical metadata).
pub mod help {
    pub const COMPILE_STAGE: &str = "per-stage compile pipeline time in seconds";
    pub const COMPILE_TOTAL: &str = "whole-compile wall time in seconds";
    pub const MEASURE: &str = "post-compile measurement (simulation) time in seconds";
    pub const ENCODE: &str = "bitstream encode time in seconds";
    pub const KERNEL: &str = "kernel work counters summed over fresh compiles";
}

/// Record a compile's stage spans into `compile_stage_seconds{stage=..}`
/// histograms plus the `compile_seconds` total, and each span's kernel
/// counters into the `compile_kernel_<name>` counter series. Shared by
/// the sweep session and the serve daemon so both expose the same
/// families.
pub fn record_compile_spans(reg: &Registry, spans: &[SpanRecord]) {
    let mut total_ns = 0u64;
    for s in spans {
        total_ns = total_ns.saturating_add(s.nanos);
        reg.histogram(&labeled("compile_stage_seconds", "stage", s.stage), help::COMPILE_STAGE)
            .observe_nanos(s.nanos);
        for (name, n) in &s.counters {
            reg.counter(&format!("compile_kernel_{name}"), help::KERNEL).add(*n);
        }
    }
    if !spans.is_empty() {
        reg.histogram("compile_seconds", help::COMPILE_TOTAL).observe_nanos(total_ns);
    }
}
