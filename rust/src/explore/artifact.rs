//! Persistent compiled-artifact store with bounded, pinning-aware eviction.
//!
//! The metrics cache ([`super::cache::DiskCache`]) remembers what a point
//! *measured*; this module remembers what a point *compiled*. Every
//! [`Compiled`] artifact — placement, route trees, enabled pipelining
//! registers, register-file delays, schedule, STA result: everything
//! [`super::cache::fingerprint`] hashes — serializes to one JSON document
//! (`results/explore_cache/artifacts/<key>.art`) and round-trips exactly:
//! [`from_bytes`]`(`[`to_bytes`]`(c))` rebuilds a `Compiled` whose
//! fingerprint is bit-identical to the original's. That turns the explorer
//! into a build system: bitstream encoding (`cascade encode --from-cache`),
//! simulation re-runs (`cascade exp summary`) and resumed or sharded
//! sweeps all rehydrate the stored artifact instead of recompiling.
//!
//! Reconstruction re-derives what is cheap and deterministic rather than
//! storing it, always from the *stored design architecture* — the same
//! (possibly flush-hardened) arch the compile flow itself used. That
//! matters for [`build_nets`], which omits the flush net when
//! `hardened_flush` is set: deriving nets from the compile context's base
//! arch instead would shift net ids under the stored routes. The delay
//! library comes back through [`DelayLib::generate`], which genuinely
//! depends only on the structural parameters. Everything else — DFG,
//! placement, routes, register state, schedule, STA, reports — is stored
//! verbatim.
//!
//! Integrity is checked twice, not trusted: [`from_bytes`] first verifies
//! a whole-document checksum (`check`, FNV-1a over the canonical bytes —
//! covers every field, including ones the artifact fingerprint does not
//! hash, like ALU opcodes, constants and architecture parameters), then
//! recomputes the artifact fingerprint of the rebuilt `Compiled` against
//! the embedded `fp`. A torn write, stale format or hand-edited content
//! fails one of the two. Callers that know the expected fingerprint (from
//! the metrics record) pass it to [`ArtifactStore::load`] for an
//! end-to-end check; a rejected file is simply recompiled.
//!
//! The store is *bounded*: an append-only access journal (`atime.log`)
//! gives LRU order, a `pins` file marks artifacts that survive any GC
//! (Pareto-frontier and knee points get pinned after every report), and
//! [`ArtifactStore::gc`] evicts unpinned artifacts oldest-first until the
//! store fits a [`CacheCap`] (`--cache-cap` on the CLI, `cascade cache
//! gc|stat` standalone). See `docs/cache.md` for the on-disk formats.
//!
//! ```
//! use cascade::explore::artifact::CacheCap;
//!
//! // Byte budgets take K/M/G suffixes; `<N>n` caps the entry count.
//! assert_eq!(CacheCap::parse("8M").unwrap(), CacheCap::bytes(8 << 20));
//! assert_eq!(CacheCap::parse("200n").unwrap(), CacheCap::entries(200));
//! assert!(!CacheCap::entries(4).admits(5, 0));
//! ```

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::arch::canal::Layer;
use crate::arch::delay::{DelayLib, DelayModelParams};
use crate::arch::params::{ArchParams, TileCoord};
use crate::dfg::ir::{AluOp, Dfg, Edge, Node, Op, SparseOp};
use crate::map::MapReport;
use crate::pipeline::{Compiled, DupPlan, PostPnrReport};
use crate::pnr::route::NetRoute;
use crate::pnr::{build_nets, Placement, RoutedDesign};
use crate::schedule::{MemSchedule, Schedule, WorkloadShape};
use crate::timing::{CritPath, Segment, SegmentEnd};
use crate::util::json::Json;

use super::cache::{fingerprint, fnv1a};

/// On-disk artifact format version ([`to_bytes`] writes it, [`from_bytes`]
/// requires it).
pub const ART_FORMAT: u64 = 1;

/// How old an orphaned `.tmp` file must be before [`ArtifactStore::gc`]
/// sweeps it. Generous relative to any single compile, so a concurrent
/// writer's in-flight temp file is never mistaken for a leftover.
pub const TMP_GRACE: std::time::Duration = std::time::Duration::from_secs(600);

// ---------------------------------------------------------------------------
// Serialization: Compiled -> JSON
// ---------------------------------------------------------------------------

fn tile_json(t: TileCoord) -> Json {
    Json::Arr(vec![Json::from(t.x as u64), Json::from(t.y as u64)])
}

/// Exact-integer bound shared with [`Json::as_i64`] (one constant,
/// [`crate::util::json::EXACT_INT_BOUND`], decides both encodability and
/// decodability): JSON numbers are f64, so signed values beyond it travel
/// as decimal strings instead of being silently truncated (the 16-bit
/// target never produces such constants, but lossy serialization is not
/// an acceptable failure mode).
const I64_EXACT: i64 = crate::util::json::EXACT_INT_BOUND;

fn i64_json(v: i64) -> Json {
    if v > -I64_EXACT && v < I64_EXACT {
        Json::from(v)
    } else {
        Json::Str(v.to_string())
    }
}

fn i64_from(j: &Json, what: &str) -> Result<i64, String> {
    if let Some(v) = j.as_i64() {
        return Ok(v);
    }
    j.as_str()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("artifact: bad {what}"))
}

fn arch_json(a: &ArchParams) -> Json {
    let mut j = Json::obj();
    j.set("rows", a.rows)
        .set("cols", a.cols)
        .set("mem_col_period", a.mem_col_period)
        .set("tracks", a.tracks)
        .set("data_in_ports", a.data_in_ports)
        .set("data_out_ports", a.data_out_ports)
        .set("bit_in_ports", a.bit_in_ports)
        .set("bit_out_ports", a.bit_out_ports)
        .set("regfile_words", a.regfile_words)
        .set("fifo_depth", a.fifo_depth)
        .set("hardened_flush", a.hardened_flush);
    j
}

fn node_json(n: &Node) -> Json {
    let mut j = Json::obj();
    j.set("name", n.name.as_str());
    if n.input_regs {
        j.set("ir", true);
    }
    match &n.op {
        Op::Input { lane } => {
            j.set("op", "input").set("lane", *lane as u64);
        }
        Op::Output { lane, decimate } => {
            j.set("op", "output").set("lane", *lane as u64).set("dec", *decimate);
        }
        Op::Const { value } => {
            j.set("op", "const").set("value", i64_json(*value));
        }
        Op::Alu { op, const_b } => {
            j.set("op", "alu").set("alu", op.encode());
            if let Some(c) = const_b {
                j.set("cb", i64_json(*c));
            }
        }
        Op::Fused { ops } => {
            // One [opcode, const_b-or-null] pair per member step.
            j.set("op", "fused").set(
                "steps",
                ops.iter()
                    .map(|s| {
                        Json::Arr(vec![
                            Json::from(s.op.encode() as u64),
                            s.const_b.map_or(Json::Null, i64_json),
                        ])
                    })
                    .collect::<Vec<Json>>(),
            );
        }
        Op::Delay { cycles, pipelined } => {
            j.set("op", "delay").set("cycles", *cycles).set("pipelined", *pipelined);
        }
        Op::Rom { values } => {
            j.set("op", "rom")
                .set("values", values.iter().map(|&v| i64_json(v)).collect::<Vec<Json>>());
        }
        Op::Accum { period } => {
            j.set("op", "accum").set("period", *period);
        }
        Op::FlushSrc => {
            j.set("op", "flush");
        }
        Op::Sparse(s) => {
            j.set("op", "sparse");
            match s {
                SparseOp::CrdScan { tensor, mode } => {
                    j.set("kind", "crdscan")
                        .set("tensor", *tensor as u64)
                        .set("mode", *mode as u64);
                }
                SparseOp::ValRead { tensor } => {
                    j.set("kind", "valread").set("tensor", *tensor as u64);
                }
                SparseOp::Intersect => {
                    j.set("kind", "intersect");
                }
                SparseOp::Union => {
                    j.set("kind", "union");
                }
                SparseOp::SpAlu(a) => {
                    j.set("kind", "spalu").set("alu", a.encode());
                }
                SparseOp::Reduce => {
                    j.set("kind", "reduce");
                }
                SparseOp::Repeat => {
                    j.set("kind", "repeat");
                }
            }
        }
    }
    j
}

fn segment_json(s: &Segment) -> Json {
    let mut j = Json::obj();
    j.set("delay_ps", s.delay_ps)
        .set("start", tile_json(s.start_tile))
        .set("end", tile_json(s.end_tile))
        .set("nodes", s.nodes.iter().map(|&n| Json::from(n as u64)).collect::<Vec<Json>>());
    let mut end = Json::obj();
    match &s.end {
        SegmentEnd::SbReg => {
            end.set("t", "sbreg");
        }
        SegmentEnd::NodeInput { node } => {
            end.set("t", "in").set("node", *node);
        }
        SegmentEnd::NodeCore { node } => {
            end.set("t", "core").set("node", *node);
        }
    }
    j.set("end_kind", end);
    j
}

/// Serialize a compiled artifact to its canonical JSON document. The
/// embedded `fp` is the artifact fingerprint at serialization time;
/// [`from_json`] recomputes it on the rebuilt artifact and rejects any
/// mismatch.
pub fn to_json(c: &Compiled) -> Json {
    let d = &c.design;
    let mut j = Json::obj();
    j.set("format", ART_FORMAT)
        .set("fp", format!("{:016x}", fingerprint(c)))
        .set("arch", arch_json(&d.arch));

    let mut nodes = Json::Arr(vec![]);
    for n in &d.dfg.nodes {
        nodes.push(node_json(n));
    }
    let mut edges = Json::Arr(vec![]);
    for e in &d.dfg.edges {
        edges.push(Json::Arr(vec![
            Json::from(e.src as u64),
            Json::from(e.dst as u64),
            Json::from(e.dst_port as u64),
            Json::from(e.layer.index() as u64),
            Json::from(e.regs),
            Json::from(e.fifos),
        ]));
    }
    let mut dfg = Json::obj();
    dfg.set("nodes", nodes).set("edges", edges);
    j.set("dfg", dfg);

    let mut placement = Json::obj();
    placement
        .set("pos", d.placement.pos.iter().map(|&t| tile_json(t)).collect::<Vec<Json>>())
        .set("slot", d.placement.slot.iter().map(|&s| Json::from(s as u64)).collect::<Vec<Json>>())
        .set("cost", d.placement.cost);
    j.set("placement", placement);

    let mut routes = Json::Arr(vec![]);
    for r in &d.routes {
        let mut o = Json::obj();
        o.set("net", r.net);
        let mut paths = Json::Arr(vec![]);
        for p in &r.sink_paths {
            paths.push(p.iter().map(|&n| Json::from(n as u64)).collect::<Vec<Json>>());
        }
        o.set("paths", paths);
        routes.push(o);
    }
    j.set("routes", routes);

    let mut sb: Vec<u64> = d.sb_regs.iter().map(|&r| r as u64).collect();
    sb.sort_unstable();
    j.set("sb_regs", sb);
    let mut pinned: Vec<u64> = d.pinned_regs.iter().map(|&r| r as u64).collect();
    pinned.sort_unstable();
    j.set("pinned_regs", pinned);
    let mut rf: Vec<(u64, u64)> =
        d.rf_delay.iter().map(|(&e, &v)| (e as u64, v as u64)).collect();
    rf.sort_unstable();
    j.set(
        "rf_delay",
        rf.iter()
            .map(|&(e, v)| Json::Arr(vec![Json::from(e), Json::from(v)]))
            .collect::<Vec<Json>>(),
    );

    let mut sta = Json::obj();
    sta.set("period_ps", c.sta.period_ps)
        .set("fmax_mhz", c.sta.fmax_mhz)
        .set("num_segments", c.sta.num_segments)
        .set("segment", segment_json(&c.sta.segment));
    j.set("sta", sta);

    let mut shape = Json::obj();
    shape
        .set("frame_w", c.schedule.shape.frame_w)
        .set("frame_h", c.schedule.shape.frame_h)
        .set("unroll", c.schedule.shape.unroll)
        .set("time_mult", c.schedule.shape.time_mult);
    let mut mem = Json::Arr(vec![]);
    for (&node, ms) in &c.schedule.mem_params {
        let mut o = Json::obj();
        o.set("node", node)
            .set("extents", ms.extents.clone())
            .set("strides", ms.strides.iter().map(|&s| Json::from(s as i64)).collect::<Vec<Json>>())
            .set("off", ms.start_offset);
        mem.push(o);
    }
    let mut sched = Json::obj();
    sched
        .set("total_cycles", c.schedule.total_cycles)
        .set("fill_latency", c.schedule.fill_latency)
        .set("shape", shape)
        .set("mem", mem);
    j.set("schedule", sched);

    let mut map = Json::obj();
    map.set("consts_folded", c.map_report.consts_folded)
        .set("muls_reduced", c.map_report.muls_reduced)
        .set("pe_used", c.map_report.pe_used)
        .set("mem_used", c.map_report.mem_used)
        .set("io_used", c.map_report.io_used)
        .set("pe_capacity", c.map_report.pe_capacity)
        .set("mem_capacity", c.map_report.mem_capacity)
        .set("io_capacity", c.map_report.io_capacity);
    j.set("map_report", map);

    j.set("pes_pipelined", c.pes_pipelined)
        .set("bdm_regs", c.bdm_regs)
        .set("bcast_buffers", c.bcast_buffers);
    match &c.postpnr {
        None => {
            j.set("postpnr", Json::Null);
        }
        Some(p) => {
            let mut o = Json::obj();
            o.set("iters", p.iters)
                .set("regs_enabled", p.regs_enabled)
                .set("period_before_ps", p.period_before_ps)
                .set("period_after_ps", p.period_after_ps);
            j.set("postpnr", o);
        }
    }
    match &c.dup {
        None => {
            j.set("dup", Json::Null);
        }
        Some(p) => {
            let mut o = Json::obj();
            o.set("region_cols", p.region_cols)
                .set("copies", p.copies)
                .set("lanes_per_copy", p.lanes_per_copy);
            j.set("dup", o);
        }
    }
    j
}

/// Canonical on-disk bytes: compact JSON plus a trailing newline, with a
/// whole-document checksum (`check` = FNV-1a over the document serialized
/// *without* the `check` member). The artifact fingerprint only hashes
/// what downstream consumers observe structurally; the checksum covers
/// every byte — opcodes, constants, architecture parameters, schedule
/// data — so corruption anywhere is detected on load. The encoding is
/// deterministic (ordered keys, shortest-round-trip floats), so two
/// serializations of the same deterministic compile are byte-identical —
/// what lets `explore-merge` byte-compare conflicting store entries.
pub fn to_bytes(c: &Compiled) -> Vec<u8> {
    let mut j = to_json(c);
    let check = fnv1a(j.to_string_compact().as_bytes());
    j.set("check", format!("{check:016x}"));
    let mut s = j.to_string_compact();
    s.push('\n');
    s.into_bytes()
}

// ---------------------------------------------------------------------------
// Deserialization: JSON -> Compiled
// ---------------------------------------------------------------------------

fn get<'a>(j: &'a Json, k: &str) -> Result<&'a Json, String> {
    j.get(k).ok_or_else(|| format!("artifact: missing '{k}'"))
}

fn req_u64(j: &Json, k: &str) -> Result<u64, String> {
    get(j, k)?.as_u64().ok_or_else(|| format!("artifact: bad '{k}'"))
}

fn req_usize(j: &Json, k: &str) -> Result<usize, String> {
    get(j, k)?.as_usize().ok_or_else(|| format!("artifact: bad '{k}'"))
}

fn req_f64(j: &Json, k: &str) -> Result<f64, String> {
    get(j, k)?.as_f64().ok_or_else(|| format!("artifact: bad '{k}'"))
}

fn req_bool(j: &Json, k: &str) -> Result<bool, String> {
    get(j, k)?.as_bool().ok_or_else(|| format!("artifact: bad '{k}'"))
}

fn req_arr<'a>(j: &'a Json, k: &str) -> Result<&'a [Json], String> {
    get(j, k)?.as_arr().ok_or_else(|| format!("artifact: bad '{k}'"))
}

fn req_str<'a>(j: &'a Json, k: &str) -> Result<&'a str, String> {
    get(j, k)?.as_str().ok_or_else(|| format!("artifact: bad '{k}'"))
}

fn u32s(arr: &[Json], what: &str) -> Result<Vec<u32>, String> {
    arr.iter()
        .map(|v| {
            v.as_u64()
                .filter(|&x| x <= u32::MAX as u64)
                .map(|x| x as u32)
                .ok_or_else(|| format!("artifact: bad {what} entry"))
        })
        .collect()
}

fn tile_from(j: &Json, what: &str) -> Result<TileCoord, String> {
    let a = j.as_arr().filter(|a| a.len() == 2).ok_or_else(|| format!("artifact: bad {what}"))?;
    let x = a[0].as_usize().ok_or_else(|| format!("artifact: bad {what} x"))?;
    let y = a[1].as_usize().ok_or_else(|| format!("artifact: bad {what} y"))?;
    Ok(TileCoord::new(x, y))
}

fn arch_from(j: &Json) -> Result<ArchParams, String> {
    Ok(ArchParams {
        rows: req_usize(j, "rows")?,
        cols: req_usize(j, "cols")?,
        mem_col_period: req_usize(j, "mem_col_period")?,
        tracks: req_usize(j, "tracks")?,
        data_in_ports: req_usize(j, "data_in_ports")?,
        data_out_ports: req_usize(j, "data_out_ports")?,
        bit_in_ports: req_usize(j, "bit_in_ports")?,
        bit_out_ports: req_usize(j, "bit_out_ports")?,
        regfile_words: req_usize(j, "regfile_words")?,
        fifo_depth: req_usize(j, "fifo_depth")?,
        hardened_flush: req_bool(j, "hardened_flush")?,
    })
}

fn node_from(j: &Json) -> Result<Node, String> {
    let alu = |key: &str| -> Result<AluOp, String> {
        let code = req_u64(j, key)?;
        AluOp::decode(code as u32).ok_or_else(|| format!("artifact: bad alu op {code}"))
    };
    let op = match req_str(j, "op")? {
        "input" => Op::Input { lane: req_u64(j, "lane")? as u16 },
        "output" => {
            Op::Output { lane: req_u64(j, "lane")? as u16, decimate: req_u64(j, "dec")? as u32 }
        }
        "const" => Op::Const { value: i64_from(get(j, "value")?, "'value'")? },
        "alu" => {
            let const_b = match j.get("cb") {
                None | Some(Json::Null) => None,
                Some(v) => Some(i64_from(v, "'cb'")?),
            };
            Op::Alu { op: alu("alu")?, const_b }
        }
        "fused" => {
            let steps = req_arr(j, "steps")?
                .iter()
                .map(|s| -> Result<crate::dfg::ir::FusedStep, String> {
                    let a = s.as_arr().filter(|a| a.len() == 2).ok_or("artifact: bad fused step")?;
                    let code =
                        a[0].as_u64().ok_or_else(|| "artifact: bad fused step op".to_string())?;
                    let op = AluOp::decode(code as u32)
                        .ok_or_else(|| format!("artifact: bad alu op {code}"))?;
                    let const_b = match &a[1] {
                        Json::Null => None,
                        v => Some(i64_from(v, "fused step const")?),
                    };
                    Ok(crate::dfg::ir::FusedStep { op, const_b })
                })
                .collect::<Result<Vec<_>, String>>()?;
            Op::Fused { ops: steps }
        }
        "delay" => Op::Delay {
            cycles: req_u64(j, "cycles")? as u32,
            pipelined: req_bool(j, "pipelined")?,
        },
        "rom" => {
            let values = req_arr(j, "values")?
                .iter()
                .map(|v| i64_from(v, "rom value"))
                .collect::<Result<Vec<i64>, String>>()?;
            Op::Rom { values }
        }
        "accum" => Op::Accum { period: req_u64(j, "period")? as u32 },
        "flush" => Op::FlushSrc,
        "sparse" => Op::Sparse(match req_str(j, "kind")? {
            "crdscan" => SparseOp::CrdScan {
                tensor: req_u64(j, "tensor")? as u8,
                mode: req_u64(j, "mode")? as u8,
            },
            "valread" => SparseOp::ValRead { tensor: req_u64(j, "tensor")? as u8 },
            "intersect" => SparseOp::Intersect,
            "union" => SparseOp::Union,
            "spalu" => SparseOp::SpAlu(alu("alu")?),
            "reduce" => SparseOp::Reduce,
            "repeat" => SparseOp::Repeat,
            other => return Err(format!("artifact: unknown sparse kind '{other}'")),
        }),
        other => return Err(format!("artifact: unknown op '{other}'")),
    };
    Ok(Node {
        op,
        name: req_str(j, "name")?.to_string(),
        input_regs: j.get("ir").and_then(Json::as_bool).unwrap_or(false),
    })
}

fn segment_from(j: &Json) -> Result<Segment, String> {
    let ek = get(j, "end_kind")?;
    let end = match req_str(ek, "t")? {
        "sbreg" => SegmentEnd::SbReg,
        "in" => SegmentEnd::NodeInput { node: req_u64(ek, "node")? as u32 },
        "core" => SegmentEnd::NodeCore { node: req_u64(ek, "node")? as u32 },
        other => return Err(format!("artifact: unknown segment end '{other}'")),
    };
    Ok(Segment {
        delay_ps: req_f64(j, "delay_ps")?,
        start_tile: tile_from(get(j, "start")?, "segment start")?,
        end_tile: tile_from(get(j, "end")?, "segment end")?,
        nodes: u32s(req_arr(j, "nodes")?, "segment nodes")?,
        end,
    })
}

/// Rebuild a [`Compiled`] from its [`to_json`] image, then verify the
/// embedded fingerprint against the rebuilt artifact. Any structural
/// damage either fails a parse step or changes the recomputed fingerprint;
/// both reject the document instead of returning a corrupt artifact.
pub fn from_json(j: &Json) -> Result<Compiled, String> {
    let format = req_u64(j, "format")?;
    if format != ART_FORMAT {
        return Err(format!("artifact: unsupported format {format}"));
    }
    let fp_hex = req_str(j, "fp")?;
    let fp = u64::from_str_radix(fp_hex, 16)
        .map_err(|_| format!("artifact: bad fingerprint '{fp_hex}'"))?;

    let arch = arch_from(get(j, "arch")?)?;

    let jdfg = get(j, "dfg")?;
    let mut dfg = Dfg::new();
    for n in req_arr(jdfg, "nodes")? {
        dfg.nodes.push(node_from(n)?);
    }
    let nnodes = dfg.nodes.len() as u64;
    for e in req_arr(jdfg, "edges")? {
        let a = e.as_arr().filter(|a| a.len() == 6).ok_or("artifact: bad edge")?;
        let num = |i: usize| a[i].as_u64().ok_or_else(|| "artifact: bad edge field".to_string());
        let (src, dst) = (num(0)?, num(1)?);
        if src >= nnodes || dst >= nnodes {
            return Err("artifact: edge references missing node".into());
        }
        dfg.edges.push(Edge {
            src: src as u32,
            dst: dst as u32,
            dst_port: num(2)? as u8,
            layer: match num(3)? {
                0 => Layer::B16,
                1 => Layer::B1,
                other => return Err(format!("artifact: bad edge layer {other}")),
            },
            regs: num(4)? as u32,
            fifos: num(5)? as u32,
        });
    }

    let jp = get(j, "placement")?;
    let pos = req_arr(jp, "pos")?
        .iter()
        .map(|t| tile_from(t, "placement pos"))
        .collect::<Result<Vec<TileCoord>, String>>()?;
    let slot = req_arr(jp, "slot")?
        .iter()
        .map(|v| {
            v.as_u64().map(|x| x as u8).ok_or_else(|| "artifact: bad placement slot".to_string())
        })
        .collect::<Result<Vec<u8>, String>>()?;
    if pos.len() != dfg.nodes.len() || slot.len() != dfg.nodes.len() {
        return Err("artifact: placement length mismatch".into());
    }
    let placement = Placement { pos, slot, cost: req_f64(jp, "cost")? };

    let mut routes = Vec::new();
    for r in req_arr(j, "routes")? {
        let mut sink_paths = Vec::new();
        for p in req_arr(r, "paths")? {
            sink_paths
                .push(u32s(p.as_arr().ok_or("artifact: bad route path")?, "route path")?);
        }
        routes.push(NetRoute { net: req_usize(r, "net")?, sink_paths });
    }

    // Nets and the delay library are re-derived, not stored — and they
    // MUST derive from the stored (possibly flush-hardened) design arch,
    // exactly as the compile flow did: `build_nets` omits the flush net
    // under `hardened_flush`, so a base-arch derivation would shift net
    // ids under the stored routes. `DelayLib::generate` depends only on
    // the structural parameters, so either arch yields the same library.
    let nets = build_nets(&dfg, &arch);
    for r in &routes {
        if r.net >= nets.len() {
            return Err("artifact: route references missing net".into());
        }
    }
    let lib = DelayLib::generate(&arch, &DelayModelParams::default());
    let mut design = RoutedDesign::new(dfg, nets, placement, routes, arch, lib);
    for &r in &u32s(req_arr(j, "sb_regs")?, "sb_regs")? {
        design.sb_regs.insert(r);
    }
    for &r in &u32s(req_arr(j, "pinned_regs")?, "pinned_regs")? {
        design.pinned_regs.insert(r);
    }
    let nedges = design.dfg.edges.len() as u64;
    for pair in req_arr(j, "rf_delay")? {
        let a = pair.as_arr().filter(|a| a.len() == 2).ok_or("artifact: bad rf_delay")?;
        let e = a[0].as_u64().ok_or("artifact: bad rf_delay edge")?;
        let v = a[1].as_u64().ok_or("artifact: bad rf_delay value")?;
        if e >= nedges {
            return Err("artifact: rf_delay references missing edge".into());
        }
        design.rf_delay.insert(e as u32, v as u32);
    }

    let jsta = get(j, "sta")?;
    let sta = CritPath {
        period_ps: req_f64(jsta, "period_ps")?,
        fmax_mhz: req_f64(jsta, "fmax_mhz")?,
        segment: segment_from(get(jsta, "segment")?)?,
        num_segments: req_usize(jsta, "num_segments")?,
    };

    let jsched = get(j, "schedule")?;
    let jshape = get(jsched, "shape")?;
    let shape = WorkloadShape {
        frame_w: req_u64(jshape, "frame_w")?,
        frame_h: req_u64(jshape, "frame_h")?,
        unroll: req_u64(jshape, "unroll")?,
        time_mult: req_u64(jshape, "time_mult")?,
    };
    let mut mem_params = BTreeMap::new();
    for o in req_arr(jsched, "mem")? {
        let extents = u32s(req_arr(o, "extents")?, "mem extents")?;
        let strides = req_arr(o, "strides")?
            .iter()
            .map(|v| {
                v.as_i64().map(|x| x as i32).ok_or_else(|| "artifact: bad stride".to_string())
            })
            .collect::<Result<Vec<i32>, String>>()?;
        mem_params.insert(
            req_u64(o, "node")? as u32,
            MemSchedule { extents, strides, start_offset: req_u64(o, "off")? as u32 },
        );
    }
    let schedule = Schedule {
        total_cycles: req_u64(jsched, "total_cycles")?,
        fill_latency: req_u64(jsched, "fill_latency")?,
        mem_params,
        shape,
    };

    let jmap = get(j, "map_report")?;
    let map_report = MapReport {
        consts_folded: req_usize(jmap, "consts_folded")?,
        muls_reduced: req_usize(jmap, "muls_reduced")?,
        pe_used: req_usize(jmap, "pe_used")?,
        mem_used: req_usize(jmap, "mem_used")?,
        io_used: req_usize(jmap, "io_used")?,
        pe_capacity: req_usize(jmap, "pe_capacity")?,
        mem_capacity: req_usize(jmap, "mem_capacity")?,
        io_capacity: req_usize(jmap, "io_capacity")?,
    };

    let postpnr = match get(j, "postpnr")? {
        Json::Null => None,
        o => Some(PostPnrReport {
            iters: req_usize(o, "iters")?,
            regs_enabled: req_usize(o, "regs_enabled")?,
            period_before_ps: req_f64(o, "period_before_ps")?,
            period_after_ps: req_f64(o, "period_after_ps")?,
        }),
    };
    let dup = match get(j, "dup")? {
        Json::Null => None,
        o => Some(DupPlan {
            region_cols: req_usize(o, "region_cols")?,
            copies: req_usize(o, "copies")?,
            lanes_per_copy: req_u64(o, "lanes_per_copy")?,
        }),
    };

    let c = Compiled {
        design,
        sta,
        schedule,
        map_report,
        pes_pipelined: req_usize(j, "pes_pipelined")?,
        bdm_regs: req_u64(j, "bdm_regs")?,
        bcast_buffers: req_usize(j, "bcast_buffers")?,
        postpnr,
        dup,
        // The fusion report is advisory (not part of the fingerprint);
        // rehydrated artifacts carry the fused graph itself in `design`.
        fused: None,
    };
    let actual = fingerprint(&c);
    if actual != fp {
        return Err(format!(
            "artifact: fingerprint mismatch (file says {fp:016x}, rebuilt artifact is \
             {actual:016x}) — torn or stale file, recompile instead"
        ));
    }
    Ok(c)
}

/// Parse [`to_bytes`] output: strict UTF-8 JSON, whole-document checksum,
/// then the [`from_json`] fingerprint verification. Any failure rejects
/// the whole document.
pub fn from_bytes(bytes: &[u8]) -> Result<Compiled, String> {
    let text =
        std::str::from_utf8(bytes).map_err(|_| "artifact: not valid UTF-8".to_string())?;
    let mut j = Json::parse(text).map_err(|e| format!("artifact: {e}"))?;
    let check_hex = req_str(&j, "check")?.to_string();
    let check = u64::from_str_radix(&check_hex, 16)
        .map_err(|_| format!("artifact: bad checksum '{check_hex}'"))?;
    if let Json::Obj(m) = &mut j {
        m.remove("check");
    }
    if fnv1a(j.to_string_compact().as_bytes()) != check {
        return Err(
            "artifact: checksum mismatch — corrupt or hand-edited file, recompile instead"
                .into(),
        );
    }
    from_json(&j)
}

// ---------------------------------------------------------------------------
// The bounded on-disk store
// ---------------------------------------------------------------------------

/// Size/count budget for [`ArtifactStore::gc`]. Parsed from the CLI's
/// `--cache-cap` (`8M`, `512K`, `1G`, plain bytes, or `<N>n` for an entry
/// count).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCap {
    /// Maximum total artifact bytes (`None` = unbounded).
    pub max_bytes: Option<u64>,
    /// Maximum artifact count (`None` = unbounded).
    pub max_entries: Option<usize>,
}

impl CacheCap {
    pub fn bytes(n: u64) -> CacheCap {
        CacheCap { max_bytes: Some(n), max_entries: None }
    }

    pub fn entries(n: usize) -> CacheCap {
        CacheCap { max_bytes: None, max_entries: Some(n) }
    }

    /// Parse the CLI form: `123456` (bytes), `512K` / `8M` / `1G`
    /// (binary-multiple bytes), or `200n` (entry count).
    pub fn parse(s: &str) -> Result<CacheCap, String> {
        let s = s.trim();
        let (digits, mult) = match s.chars().last() {
            Some('k') | Some('K') => (&s[..s.len() - 1], Some(1u64 << 10)),
            Some('m') | Some('M') => (&s[..s.len() - 1], Some(1u64 << 20)),
            Some('g') | Some('G') => (&s[..s.len() - 1], Some(1u64 << 30)),
            Some('n') | Some('N') => (&s[..s.len() - 1], None),
            _ => (s, Some(1)),
        };
        let n: u64 = digits
            .parse()
            .map_err(|_| format!("bad --cache-cap '{s}' (use bytes, K/M/G, or <N>n entries)"))?;
        Ok(match mult {
            Some(m) => CacheCap::bytes(n.saturating_mul(m)),
            None => CacheCap::entries(n as usize),
        })
    }

    /// Whether a store of `entries` artifacts totalling `bytes` fits.
    pub fn admits(&self, entries: usize, bytes: u64) -> bool {
        self.max_bytes.map(|b| bytes <= b).unwrap_or(true)
            && self.max_entries.map(|e| entries <= e).unwrap_or(true)
    }
}

/// What [`ArtifactStore::gc`] did.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GcReport {
    pub entries_before: usize,
    pub entries_after: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
    pub evicted: usize,
    /// Pinned artifacts, which are never evicted — if the store still
    /// exceeds the cap after GC, it is because pins alone exceed it.
    pub pinned: usize,
}

impl GcReport {
    pub fn summary(&self) -> String {
        format!(
            "evicted {} artifact(s) ({} -> {} entries, {} -> {} bytes), {} pinned",
            self.evicted,
            self.entries_before,
            self.entries_after,
            self.bytes_before,
            self.bytes_after,
            self.pinned
        )
    }
}

/// Store-wide statistics (`cascade cache stat`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StoreStat {
    pub entries: usize,
    pub bytes: u64,
    pub pinned: usize,
    pub journal_lines: usize,
}

/// Parse a `pins` file (one hex key per line; unparseable lines are
/// ignored, absent file = empty set). A free function so readers — like
/// `explore-merge` collecting a *source* shard's pins — need no store
/// handle, whose constructor creates the directory as a side effect.
pub fn read_pins_file(path: &Path) -> BTreeSet<u64> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return BTreeSet::new();
    };
    text.lines().filter_map(|l| u64::from_str_radix(l.trim(), 16).ok()).collect()
}

/// The persistent artifact store: one `<key>.art` file per compiled
/// artifact under `<cache>/artifacts/`, an append-only LRU journal
/// (`atime.log`, one hex key per access), and a `pins` file of keys GC
/// must never evict. Every full-file write (`.art` bodies, the pins
/// file, journal compaction) is atomic (temp file + rename); journal
/// touches are single-`write_all` appends whose worst failure is one
/// unparseable line, which readers skip. All artifact reads are
/// checksum- and fingerprint-checked, so a torn file is recompiled,
/// never trusted.
pub struct ArtifactStore {
    dir: PathBuf,
    hits: AtomicUsize,
    rejected: AtomicUsize,
    stores: AtomicUsize,
}

impl ArtifactStore {
    /// Open (creating) a store at `dir`. Like [`super::cache::DiskCache`],
    /// an uncreatable directory degrades to a store-nothing handle.
    pub fn at(dir: impl AsRef<Path>) -> ArtifactStore {
        let dir = dir.as_ref().to_path_buf();
        let _ = std::fs::create_dir_all(&dir);
        ArtifactStore {
            dir,
            hits: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            stores: AtomicUsize::new(0),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn art_path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.art"))
    }

    fn journal_path(&self) -> PathBuf {
        self.dir.join("atime.log")
    }

    fn pins_path(&self) -> PathBuf {
        self.dir.join("pins")
    }

    /// Artifacts rehydrated by this handle.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Files rejected by this handle (parse or fingerprint failure).
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Artifacts written by this handle.
    pub fn stores(&self) -> usize {
        self.stores.load(Ordering::Relaxed)
    }

    pub fn contains(&self, key: u64) -> bool {
        self.art_path(key).exists()
    }

    /// Record a logical *use* of `key` without loading it — e.g. a
    /// metrics-cache hit that made rehydration unnecessary — so LRU
    /// eviction tracks point usage, not just artifact reads (otherwise a
    /// hot, fully-warm sweep would look cold to GC and lose exactly the
    /// artifacts it relies on). No-op for keys without a stored artifact.
    pub fn note_use(&self, key: u64) {
        if self.contains(key) {
            self.touch(key);
        }
    }

    /// Atomic replace (temp file + rename): a killed writer leaves either
    /// the old content or the new, never a truncation. Used for `.art`
    /// bodies, the pins file and journal compaction alike.
    fn atomic_write(&self, path: &Path, bytes: &[u8]) -> bool {
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { return false };
        let tmp = self.dir.join(format!("{name}.tmp{}", std::process::id()));
        let ok = std::fs::write(&tmp, bytes).is_ok() && std::fs::rename(&tmp, path).is_ok();
        if !ok {
            let _ = std::fs::remove_file(&tmp);
        }
        ok
    }

    fn touch(&self, key: u64) {
        use std::io::Write as _;
        if let Ok(mut f) =
            std::fs::OpenOptions::new().append(true).create(true).open(self.journal_path())
        {
            // One write_all per line: O_APPEND keeps concurrent touches
            // whole, same policy as the partial-results journal.
            let _ = f.write_all(format!("{key:016x}\n").as_bytes());
        }
    }

    /// Persist `c` under `key` (atomic write; an existing file is replaced
    /// — compiles are deterministic, so replacement bytes are identical
    /// unless the old file was torn, in which case replacing repairs it).
    pub fn store(&self, key: u64, c: &Compiled) {
        if self.atomic_write(&self.art_path(key), &to_bytes(c)) {
            self.stores.fetch_add(1, Ordering::Relaxed);
            self.touch(key);
        }
    }

    /// Rehydrate the artifact stored under `key`, verifying its embedded
    /// fingerprint and, when given, the caller's `expect_fp` (normally the
    /// `artifact_fp` of the point's metrics record). Returns `None` for an
    /// absent file *and* for a rejected one — the caller recompiles either
    /// way; [`Self::rejected`] distinguishes them for reporting.
    pub fn load(&self, key: u64, expect_fp: Option<u64>) -> Option<Compiled> {
        let bytes = std::fs::read(self.art_path(key)).ok()?;
        match from_bytes(&bytes) {
            Ok(c) => {
                if let Some(fp) = expect_fp {
                    if fingerprint(&c) != fp {
                        self.rejected.fetch_add(1, Ordering::Relaxed);
                        return None;
                    }
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.touch(key);
                Some(c)
            }
            Err(_) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Keys currently stored, ascending.
    pub fn keys(&self) -> Vec<u64> {
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return Vec::new() };
        let mut keys: Vec<u64> = rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name();
                let name = name.to_str()?;
                let stem = name.strip_suffix(".art")?;
                u64::from_str_radix(stem, 16).ok()
            })
            .collect();
        keys.sort_unstable();
        keys
    }

    /// Mark `keys` as GC survivors (set union with the existing pins).
    pub fn pin(&self, keys: impl IntoIterator<Item = u64>) {
        let mut pins = self.pinned();
        pins.extend(keys);
        self.write_pins(&pins);
    }

    fn write_pins(&self, pins: &BTreeSet<u64>) {
        let mut text = String::new();
        for k in pins {
            text.push_str(&format!("{k:016x}\n"));
        }
        self.atomic_write(&self.pins_path(), text.as_bytes());
    }

    /// The pinned key set (unparseable lines are ignored).
    pub fn pinned(&self) -> BTreeSet<u64> {
        read_pins_file(&self.pins_path())
    }

    /// Stored keys in least-recently-used-first order, from the access
    /// journal: keys the journal never mentions first (key order), then by
    /// last journal appearance, oldest first.
    pub fn lru_order(&self) -> Vec<u64> {
        let mut last: HashMap<u64, usize> = HashMap::new();
        if let Ok(text) = std::fs::read_to_string(self.journal_path()) {
            for (i, line) in text.lines().enumerate() {
                if let Ok(k) = u64::from_str_radix(line.trim(), 16) {
                    last.insert(k, i);
                }
            }
        }
        let mut keys = self.keys();
        keys.sort_by_key(|k| (last.get(k).map(|&i| i as i64).unwrap_or(-1), *k));
        keys
    }

    pub fn stat(&self) -> StoreStat {
        let keys = self.keys();
        let bytes = keys
            .iter()
            .map(|&k| std::fs::metadata(self.art_path(k)).map(|m| m.len()).unwrap_or(0))
            .sum();
        let stored: BTreeSet<u64> = keys.iter().copied().collect();
        let journal_lines = std::fs::read_to_string(self.journal_path())
            .map(|t| t.lines().count())
            .unwrap_or(0);
        StoreStat {
            entries: keys.len(),
            bytes,
            pinned: self.pinned().intersection(&stored).count(),
            journal_lines,
        }
    }

    /// Evict unpinned artifacts, least recently used first, until the
    /// store fits `cap`; then compact the journal (one line per surviving
    /// key, LRU order preserved) and prune pins of evicted-or-absent keys.
    /// Pinned artifacts are never evicted, even if they alone exceed the
    /// cap — the report's `pinned` count says when that happened.
    pub fn gc(&self, cap: &CacheCap) -> GcReport {
        self.gc_with_tmp_grace(cap, TMP_GRACE)
    }

    /// [`Self::gc`] with an explicit staleness threshold for the `.tmp`
    /// sweep (tests use zero; production uses [`TMP_GRACE`]).
    pub fn gc_with_tmp_grace(&self, cap: &CacheCap, grace: std::time::Duration) -> GcReport {
        // Sweep `.tmp` leftovers from killed writers first: never valid
        // reads, invisible to the `.art` accounting, and otherwise they
        // accumulate outside the cap forever. Only *stale* ones go — a GC
        // racing a live same-directory writer (local multi-process
        // shards) must not delete an in-flight temp file between its
        // write and rename.
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let name = e.file_name();
                if !name.to_str().map(|n| n.contains(".tmp")).unwrap_or(false) {
                    continue;
                }
                let stale = e
                    .metadata()
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|t| t.elapsed().ok())
                    .map(|age| age >= grace)
                    .unwrap_or(true);
                if stale {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        let pins = self.pinned();
        let order = self.lru_order();
        let sizes: HashMap<u64, u64> = order
            .iter()
            .map(|&k| (k, std::fs::metadata(self.art_path(k)).map(|m| m.len()).unwrap_or(0)))
            .collect();
        let mut entries = order.len();
        let mut bytes: u64 = sizes.values().sum();
        let report_before = (entries, bytes);

        let mut evicted = 0usize;
        let mut survivors: Vec<u64> = Vec::with_capacity(order.len());
        let mut victims = order.iter().copied().filter(|k| !pins.contains(k));
        let mut kept: BTreeSet<u64> = order.iter().copied().collect();
        while !cap.admits(entries, bytes) {
            let Some(k) = victims.next() else { break };
            if std::fs::remove_file(self.art_path(k)).is_ok() {
                kept.remove(&k);
                entries -= 1;
                bytes -= sizes[&k];
                evicted += 1;
            }
        }
        for &k in &order {
            if kept.contains(&k) {
                survivors.push(k);
            }
        }
        // Compact the journal and prune stale pins (atomic, like every
        // other non-append write in the store).
        let mut text = String::new();
        for k in &survivors {
            text.push_str(&format!("{k:016x}\n"));
        }
        self.atomic_write(&self.journal_path(), text.as_bytes());
        let stored: BTreeSet<u64> = survivors.iter().copied().collect();
        let live_pins: BTreeSet<u64> = pins.intersection(&stored).copied().collect();
        self.write_pins(&live_pins);

        GcReport {
            entries_before: report_before.0,
            entries_after: entries,
            bytes_before: report_before.1,
            bytes_after: bytes,
            evicted,
            pinned: live_pins.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{compile, CompileCtx, PipelineConfig};

    fn tiny_compiled(level: &str, seed: u64) -> (CompileCtx, Compiled) {
        let ctx = CompileCtx::paper();
        let app = crate::apps::by_name_tiny("gaussian").unwrap();
        let cfg = PipelineConfig::by_name(level).unwrap();
        let c = compile(&app, &ctx, &cfg, seed).unwrap();
        (ctx, c)
    }

    #[test]
    fn round_trip_is_bit_identical_under_fingerprint() {
        let (_ctx, c) = tiny_compiled("compute", 3);
        let bytes = to_bytes(&c);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(fingerprint(&c), fingerprint(&back));
        // Serialization is canonical: a second round trip is byte-stable.
        assert_eq!(bytes, to_bytes(&back));
        // Metrics derived from the rehydrated artifact match exactly.
        use super::super::cache::PointMetrics;
        assert_eq!(PointMetrics::from_compiled(&c), PointMetrics::from_compiled(&back));
    }

    #[test]
    fn fused_artifact_round_trips() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::by_name_tiny("unsharp").unwrap();
        let cfg = PipelineConfig { fusion: true, ..PipelineConfig::with_postpnr() };
        let c = compile(&app, &ctx, &cfg, 3).unwrap();
        assert!(
            c.design.dfg.nodes.iter().any(|n| matches!(n.op, Op::Fused { .. })),
            "fixture must exercise a compound node"
        );
        let bytes = to_bytes(&c);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(fingerprint(&c), fingerprint(&back));
        assert_eq!(bytes, to_bytes(&back));
    }

    #[test]
    fn sparse_artifact_round_trips() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::sparse::vec_elemadd(1024, 0.2);
        let c = compile(&app, &ctx, &PipelineConfig::compute_only(), 5).unwrap();
        let back = from_bytes(&to_bytes(&c)).unwrap();
        assert_eq!(fingerprint(&c), fingerprint(&back));
        // The rehydrated DFG drives the functional simulation identically.
        let data = crate::apps::sparse::data_for(app.name, 42);
        let a = crate::sparse::sim::simulate_app(app.name, &c.design.dfg, &data);
        let b = crate::sparse::sim::simulate_app(app.name, &back.design.dfg, &data);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn torn_and_tampered_files_are_rejected() {
        let (_ctx, c) = tiny_compiled("none", 3);
        let bytes = to_bytes(&c);
        // Truncation (torn write) fails the parse.
        assert!(from_bytes(&bytes[..bytes.len() / 2]).is_err());
        assert!(from_bytes(b"").is_err());
        assert!(from_bytes(b"{}\n").is_err());
        // A parseable but tampered document fails the fingerprint check.
        let text = String::from_utf8(bytes).unwrap();
        let mut j = Json::parse(&text).unwrap();
        let cycles = j.get("schedule").unwrap().get("total_cycles").unwrap().as_u64().unwrap();
        let mut sched = j.get("schedule").unwrap().clone();
        sched.set("total_cycles", cycles + 1);
        j.set("schedule", sched);
        let err = from_json(&j).unwrap_err();
        assert!(err.contains("fingerprint mismatch"), "{err}");
        // Tampering with a field the fingerprint does NOT hash (the map
        // report here) is caught by the whole-document checksum instead.
        assert!(text.contains("\"consts_folded\":"), "fixture drifted");
        let tampered = text.replacen("\"consts_folded\":", "\"consts_folded\":9", 1);
        let err = from_bytes(tampered.as_bytes()).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn i64_values_round_trip_beyond_f64_exact_range() {
        // Constants outside f64's exact-integer window travel as strings.
        for v in [0i64, -1, 1 << 15, I64_EXACT - 1, I64_EXACT, -I64_EXACT, i64::MIN, i64::MAX] {
            let j = i64_json(v);
            assert_eq!(i64_from(&j, "test").unwrap(), v, "value {v}");
        }
        assert!(i64_from(&Json::Bool(true), "test").is_err());
    }

    #[test]
    fn store_load_counts_and_verifies() {
        let dir = std::env::temp_dir().join(format!("cascade-art-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ArtifactStore::at(&dir);
        let (_ctx, c) = tiny_compiled("none", 4);
        let fp = fingerprint(&c);
        assert!(store.load(1, None).is_none(), "absent key is a miss, not a rejection");
        assert_eq!(store.rejected(), 0);
        store.store(1, &c);
        assert!(store.contains(1));
        let back = store.load(1, Some(fp)).unwrap();
        assert_eq!(fingerprint(&back), fp);
        assert_eq!(store.hits(), 1);
        // A wrong expected fingerprint (stale metrics record) is rejected.
        assert!(store.load(1, Some(fp ^ 1)).is_none());
        assert_eq!(store.rejected(), 1);
        // A torn file is rejected and the key reports absent-equivalent.
        std::fs::write(dir.join(format!("{:016x}.art", 1u64)), b"{\"format\":1,").unwrap();
        assert!(store.load(1, None).is_none());
        assert_eq!(store.rejected(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The daemon-concurrency regression: journal touches must stay one
    /// `write_all` per access (O_APPEND), so interleaved `note_use` calls
    /// from concurrent serve workers — same handle shared across threads
    /// *and* separate handles on the same directory — never produce a
    /// torn journal line. Every line must stay an individually parseable
    /// hex key and the line count must account for every touch.
    #[test]
    fn concurrent_journal_touches_never_tear_lines() {
        let dir =
            std::env::temp_dir().join(format!("cascade-journal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ArtifactStore::at(&dir);
        let (_ctx, c) = tiny_compiled("none", 2);
        let keys: Vec<u64> = vec![0x11, 0x2222, 0xdeadbeef12345678];
        for &k in &keys {
            store.store(k, &c); // one journal touch each
        }

        const THREADS: usize = 4;
        const TOUCHES: usize = 50;
        let other = ArtifactStore::at(&dir); // a second process's handle
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let store = &store;
                let other = &other;
                let keys = &keys;
                s.spawn(move || {
                    let handle: &ArtifactStore = if t % 2 == 0 { store } else { other };
                    for i in 0..TOUCHES {
                        handle.note_use(keys[(t + i) % keys.len()]);
                    }
                });
            }
        });

        let text = std::fs::read_to_string(dir.join("atime.log")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(
            lines.len(),
            keys.len() + THREADS * TOUCHES,
            "every store and every touch must land as exactly one line"
        );
        assert!(text.ends_with('\n'), "the journal must end on a line boundary");
        for line in lines {
            assert_eq!(line.len(), 16, "torn or glued journal line: {line:?}");
            let k = u64::from_str_radix(line, 16).expect("unparseable journal line");
            assert!(keys.contains(&k), "journal line names an unknown key: {line}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// GC tests drive the store through its file layout directly (fake
    /// fixed-size entries), since eviction never parses artifact bodies.
    fn fake_store(tag: &str, n: usize, size: usize) -> (PathBuf, ArtifactStore) {
        let dir = std::env::temp_dir().join(format!("cascade-gc-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ArtifactStore::at(&dir);
        let mut journal = String::new();
        for k in 1..=n as u64 {
            std::fs::write(dir.join(format!("{k:016x}.art")), vec![b'x'; size]).unwrap();
            journal.push_str(&format!("{k:016x}\n"));
        }
        std::fs::write(dir.join("atime.log"), journal).unwrap();
        (dir, store)
    }

    #[test]
    fn gc_honors_entry_and_byte_caps_lru_first() {
        let (dir, store) = fake_store("cap", 6, 100);
        // Touch key 1 so it becomes the most recently used.
        std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join("atime.log"))
            .map(|mut f| {
                use std::io::Write as _;
                f.write_all(format!("{:016x}\n", 1u64).as_bytes()).unwrap();
            })
            .unwrap();
        let r = store.gc(&CacheCap::entries(3));
        assert_eq!(r.evicted, 3);
        assert_eq!(r.entries_after, 3);
        // LRU evicts 2, 3, 4 (1 was touched last); 1, 5, 6 survive.
        assert_eq!(store.keys(), vec![1, 5, 6]);
        // The journal is compacted to the survivors.
        let stat = store.stat();
        assert_eq!(stat.journal_lines, 3);
        // Byte cap on what remains: 300 bytes now, cap at 150 keeps 1.
        let r2 = store.gc(&CacheCap::bytes(150));
        assert_eq!(r2.entries_after, 1);
        assert_eq!(store.keys(), vec![1], "most recently used survives a byte cap");
        // Under-cap GC is a no-op on artifacts, but sweeps *stale* tmp
        // leftovers a killed writer abandoned (they live outside the
        // cap). A fresh tmp — possibly a live writer's — survives the
        // production grace window.
        let tmp = dir.join(format!("{:016x}.tmp999", 7u64));
        std::fs::write(&tmp, b"torn").unwrap();
        let r3 = store.gc(&CacheCap::bytes(1 << 20));
        assert_eq!(r3.evicted, 0);
        assert!(tmp.exists(), "a just-written tmp must survive the grace window");
        store.gc_with_tmp_grace(&CacheCap::bytes(1 << 20), std::time::Duration::ZERO);
        assert!(!tmp.exists(), "stale tmp leftovers swept");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_never_evicts_pinned_survivors() {
        let (dir, store) = fake_store("pin", 5, 10);
        store.pin([1u64, 2]);
        // Cap of one entry: only unpinned artifacts (3, 4, 5) may go.
        let r = store.gc(&CacheCap::entries(1));
        assert_eq!(r.evicted, 3);
        assert_eq!(store.keys(), vec![1, 2], "pinned artifacts survive any cap");
        assert_eq!(r.pinned, 2);
        assert_eq!(r.entries_after, 2, "pins may leave the store over-cap; GC reports it");
        // Pins of evicted/absent keys are pruned on GC.
        store.pin([99u64]);
        store.gc(&CacheCap::default());
        assert_eq!(store.pinned().into_iter().collect::<Vec<_>>(), vec![1, 2]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cache_cap_parses_all_forms() {
        assert_eq!(CacheCap::parse("1234").unwrap(), CacheCap::bytes(1234));
        assert_eq!(CacheCap::parse("512K").unwrap(), CacheCap::bytes(512 << 10));
        assert_eq!(CacheCap::parse("8m").unwrap(), CacheCap::bytes(8 << 20));
        assert_eq!(CacheCap::parse("1G").unwrap(), CacheCap::bytes(1 << 30));
        assert_eq!(CacheCap::parse("200n").unwrap(), CacheCap::entries(200));
        assert!(CacheCap::parse("").is_err());
        assert!(CacheCap::parse("x12").is_err());
        assert!(CacheCap::parse("12x3M").is_err());
        assert!(CacheCap::bytes(100).admits(5, 100));
        assert!(!CacheCap::bytes(100).admits(5, 101));
        assert!(!CacheCap::entries(4).admits(5, 0));
    }
}
