//! Reporting for exploration runs: Pareto analysis per application, a
//! ranked markdown summary, and deterministic JSON emission via
//! [`crate::util::json`].
//!
//! Reports contain only run-invariant content (no cache traffic, no wall
//! clock), so a re-run served from the artifact cache emits byte-identical
//! files — the property the CLI acceptance check relies on.
//!
//! The building blocks are pure functions over result rows:
//!
//! ```
//! use cascade::explore::report::{search_to_json, search_to_markdown};
//! use cascade::explore::{HalvingParams, RungReport};
//!
//! let rungs = vec![RungReport { rung: 0, budget: 5, evaluated: 6, kept: 2 }];
//! let params = HalvingParams::default();
//! let md = search_to_markdown(&params, &rungs);
//! assert!(md.contains("| 0 | 5 | 6 | 2 |"), "one table row per rung");
//! let j = search_to_json(&params, &rungs).to_string_compact();
//! assert!(j.contains("\"mode\":\"halving\""));
//! ```

use crate::util::json::Json;

use super::pareto::{knee_point, pareto_front};
use super::runner::PointResult;
use super::search::{HalvingParams, RungReport};
use super::space::ExploreSpec;

/// Pareto analysis of one application's feasible points.
#[derive(Debug)]
pub struct AppAnalysis {
    pub app: String,
    /// Point ids on the frontier, ascending.
    pub frontier: Vec<usize>,
    /// Knee point id (balanced trade-off), if the frontier is non-empty.
    pub knee: Option<usize>,
    /// Point ids excluded by the power cap.
    pub capped: Vec<usize>,
    /// Point ids whose compile failed.
    pub failed: Vec<usize>,
}

/// Objective vector: (critical-path delay ns, EDP mJ*ms, pipelining regs).
/// Shared between the frontier analysis here and the halving search's
/// knee-distance promotion ranking.
pub fn objectives(m: &super::cache::PointMetrics) -> Vec<f64> {
    vec![m.crit_ns, m.edp, m.pipe_regs as f64]
}

/// Analyze each app's points independently — objectives are only
/// commensurable within one application.
pub fn analyze(spec: &ExploreSpec, results: &[PointResult]) -> Vec<AppAnalysis> {
    spec.apps
        .iter()
        .map(|app| {
            let mut ids = Vec::new();
            let mut vecs = Vec::new();
            let mut capped = Vec::new();
            let mut failed = Vec::new();
            for r in results.iter().filter(|r| &r.point.app == app) {
                match &r.metrics {
                    Ok(m) => {
                        if crate::sim::power::within_cap(m.power_mw, spec.power_cap_mw) {
                            ids.push(r.point.id);
                            vecs.push(objectives(m));
                        } else {
                            capped.push(r.point.id);
                        }
                    }
                    Err(_) => failed.push(r.point.id),
                }
            }
            let front_local = pareto_front(&vecs);
            let knee_local = knee_point(&vecs, &front_local);
            AppAnalysis {
                app: app.clone(),
                frontier: front_local.iter().map(|&i| ids[i]).collect(),
                knee: knee_local.map(|i| ids[i]),
                capped,
                failed,
            }
        })
        .collect()
}

/// One evaluation as a self-describing JSON object: grid coordinates plus
/// metrics (or the compile error). Used for the `points` array of the run
/// report and, with a `rung` tag, for the streamed
/// `results/explore_partial.jsonl` lines.
pub fn point_json(r: &PointResult, rung: Option<usize>) -> Json {
    let mut jp = Json::obj();
    jp.set("id", r.point.id)
        .set("app", r.point.app.as_str())
        .set("level", r.point.level.as_str())
        .set("alpha", r.point.alpha.map_or(Json::Null, Json::from))
        .set("seed", r.point.seed)
        .set("iters", r.point.iters.map_or(Json::Null, Json::from))
        .set("tracks", r.point.tracks.map_or(Json::Null, Json::from))
        .set("regwords", r.point.regwords.map_or(Json::Null, Json::from))
        .set("fifo", r.point.fifo.map_or(Json::Null, Json::from))
        .set("fuse", r.point.fuse.map_or(Json::Null, Json::from));
    if let Some(k) = rung {
        jp.set("rung", k);
    }
    match &r.metrics {
        Ok(m) => {
            jp.set("crit_ns", m.crit_ns)
                .set("fmax_mhz", m.fmax_mhz)
                .set("runtime_ms", m.runtime_ms)
                .set("power_mw", m.power_mw)
                .set("energy_mj", m.energy_mj)
                .set("edp", m.edp)
                .set("pipe_regs", m.pipe_regs)
                .set("util_pct", m.util_pct);
            if m.cycles > 0 {
                jp.set("cycles", m.cycles);
            }
        }
        Err(e) => {
            jp.set("error", e.as_str());
        }
    }
    jp
}

/// Deterministic JSON document for the run.
pub fn to_json(spec: &ExploreSpec, results: &[PointResult], analyses: &[AppAnalysis]) -> Json {
    let mut j = Json::obj();
    j.set("spec", spec.to_json());

    let mut jpoints = Json::Arr(vec![]);
    for r in results {
        jpoints.push(point_json(r, None));
    }
    j.set("points", jpoints);

    let mut jfronts = Json::Arr(vec![]);
    for a in analyses {
        let mut ja = Json::obj();
        ja.set("app", a.app.as_str())
            .set("frontier", a.frontier.clone().into_iter().map(Json::from).collect::<Vec<Json>>())
            .set("knee", a.knee.map_or(Json::Null, Json::from))
            .set("capped", a.capped.clone().into_iter().map(Json::from).collect::<Vec<Json>>())
            .set("failed", a.failed.clone().into_iter().map(Json::from).collect::<Vec<Json>>());
        jfronts.push(ja);
    }
    j.set("pareto", jfronts);
    j
}

/// Render the complete run report — markdown and JSON, plus the per-app
/// analyses — for either an exhaustive grid (`trajectory = None`) or a
/// halving search. This is the single emission path shared by `cascade
/// explore` and `cascade explore-merge`: a merged multi-shard run reports
/// through exactly the code an unsharded run does, which is what makes
/// "merged output is byte-identical to the single-process run" a testable
/// property rather than an aspiration.
pub fn render_report(
    spec: &ExploreSpec,
    results: &[PointResult],
    trajectory: Option<(&HalvingParams, &[RungReport])>,
) -> (String, Json, Vec<AppAnalysis>) {
    let analyses = analyze(spec, results);
    let mut json = to_json(spec, results, &analyses);
    let md = match trajectory {
        None => to_markdown(spec, results, &analyses),
        Some((params, rungs)) => {
            json.set("search", search_to_json(params, rungs));
            // Head the survivor table with the candidate-space shape (the
            // budget axis is the rung ladder) and an honest label — only
            // final-rung survivors are listed, not a full grid.
            let survivors = spec.candidate_spec();
            format!(
                "{}\n{}",
                search_to_markdown(params, rungs),
                to_markdown_labeled("Survivors of candidate space", &survivors, results, &analyses)
            )
        }
    };
    (md, json, analyses)
}

/// Stage label of a `compile_stage_seconds{stage="..."}` series name,
/// or the whole name when it carries no stage label.
fn stage_label(name: &str) -> &str {
    name.split("stage=\"").nth(1).and_then(|s| s.split('"').next()).unwrap_or(name)
}

/// Bucket-bound quantile as display text: microseconds, `inf` when the
/// rank fell in the overflow bucket, `-` for an empty histogram.
fn q_str(snap: &crate::obs::HistoSnapshot, q: f64) -> String {
    match crate::obs::metrics::quantile_of(snap, q) {
        None => "-".into(),
        Some(u64::MAX) => "inf".into(),
        Some(us) => us.to_string(),
    }
}

fn profile_row(label: &str, snap: &crate::obs::HistoSnapshot) -> (Vec<String>, Json) {
    let row = vec![
        label.to_string(),
        snap.count.to_string(),
        crate::obs::metrics::secs_str(snap.sum_nanos, 1_000_000_000),
        q_str(snap, 0.50),
        q_str(snap, 0.99),
    ];
    let mut j = Json::obj();
    j.set("stage", label)
        .set("count", snap.count)
        .set("total_ns", snap.sum_nanos)
        .set(
            "p50_us",
            crate::obs::metrics::quantile_of(snap, 0.50).map_or(Json::Null, Json::from),
        )
        .set(
            "p99_us",
            crate::obs::metrics::quantile_of(snap, 0.99).map_or(Json::Null, Json::from),
        );
    (row, j)
}

/// Opt-in `--profile` section: per-stage compile-time breakdown read
/// from the run's metrics registry. Kept out of [`render_report`] on
/// purpose — the default report (and with it the sharded-merge
/// byte-identity contract) must never see wall-clock content, so the CLI
/// appends this only when asked.
pub fn profile_section(reg: &crate::obs::Registry) -> (String, Json) {
    let mut series = reg.histogram_series("compile_stage_seconds{");
    // Pipeline order first, any stage the order list does not know after
    // it in name order.
    let rank = |name: &str| {
        let stage = stage_label(name);
        crate::obs::STAGE_ORDER
            .iter()
            .position(|s| *s == stage)
            .unwrap_or(crate::obs::STAGE_ORDER.len())
    };
    series.sort_by(|a, b| rank(&a.0).cmp(&rank(&b.0)).then_with(|| a.0.cmp(&b.0)));

    let mut md = String::from("\n## Compile profile\n\n");
    md.push_str(
        "Per-stage wall clock over *fresh* compiles only — cache-served points are \
         not traced. Quantiles are log2-bucket upper bounds (µs).\n\n",
    );
    let mut rows = Vec::new();
    let mut jstages = Json::Arr(vec![]);
    for (name, snap) in &series {
        let (row, j) = profile_row(stage_label(name), snap);
        rows.push(row);
        jstages.push(j);
    }
    let mut json = Json::obj();
    for (family, label) in
        [("compile_seconds", "total (per compile)"), ("measure_seconds", "measure")]
    {
        if let Some((_, snap)) = reg.histogram_series(family).first() {
            let (row, mut j) = profile_row(label, snap);
            rows.push(row);
            j.set("stage", Json::Null);
            json.set(family, j);
        }
    }
    md.push_str(&crate::experiments::common::md_table(
        &["stage", "count", "total (s)", "p50 (µs)", "p99 (µs)"],
        &rows,
    ));
    json.set("stages", jstages);

    // Kernel-depth work counters (ISSUE 10): what the hot kernels *did*
    // across the run's fresh compiles — moves, rip-ups, repropagations —
    // next to where the time went. Registry order is name order, already
    // deterministic.
    let kernels = reg.counter_series("compile_kernel_");
    if !kernels.is_empty() {
        md.push_str(
            "\nKernel work counters over the same fresh compiles (see \
             `docs/observability.md` for per-counter semantics):\n\n",
        );
        let mut krows = Vec::new();
        let mut jkernels = Json::obj();
        for (name, value) in &kernels {
            let short = name.strip_prefix("compile_kernel_").unwrap_or(name);
            krows.push(vec![short.to_string(), value.to_string()]);
            jkernels.set(short, *value);
        }
        md.push_str(&crate::experiments::common::md_table(&["counter", "total"], &krows));
        json.set("kernels", jkernels);
    }
    (md, json)
}

/// Deterministic JSON section describing an adaptive search run: the
/// halving knobs plus the per-rung trajectory. Attached to the run report
/// under the `search` key.
pub fn search_to_json(params: &HalvingParams, rungs: &[RungReport]) -> Json {
    let mut j = Json::obj();
    j.set("mode", "halving")
        .set("eta", params.eta)
        .set("objective", params.objective.tag());
    let mut jr = Json::Arr(vec![]);
    for r in rungs {
        let mut o = Json::obj();
        o.set("rung", r.rung)
            .set("budget", r.budget)
            .set("evaluated", r.evaluated)
            .set("kept", r.kept);
        jr.push(o);
    }
    j.set("rungs", jr);
    j
}

/// Markdown table of the halving trajectory, prepended to the run report
/// so the budget/survivor schedule is visible next to the frontier.
pub fn search_to_markdown(params: &HalvingParams, rungs: &[RungReport]) -> String {
    let mut md = format!(
        "Successive halving (eta {}, objective {}): {} rung(s)\n\n",
        params.eta,
        params.objective.tag(),
        rungs.len()
    );
    let rows: Vec<Vec<String>> = rungs
        .iter()
        .map(|r| {
            vec![
                r.rung.to_string(),
                r.budget.to_string(),
                r.evaluated.to_string(),
                r.kept.to_string(),
            ]
        })
        .collect();
    md.push_str(&crate::experiments::common::md_table(
        &["rung", "post-PnR budget", "evaluated", "kept"],
        &rows,
    ));
    md
}

/// Ranked markdown summary: per app, points sorted by critical-path delay
/// with frontier (`*`), knee (`**`), power-capped (`cap`) and failed
/// (`FAIL`) markers.
pub fn to_markdown(
    spec: &ExploreSpec,
    results: &[PointResult],
    analyses: &[AppAnalysis],
) -> String {
    to_markdown_labeled("Grid", spec, results, analyses)
}

/// [`to_markdown`] with a custom header label — the halving path heads
/// the table with "Survivors of candidate space: <shape>" because it
/// lists final-rung survivors, not the full cross-product.
pub fn to_markdown_labeled(
    label: &str,
    spec: &ExploreSpec,
    results: &[PointResult],
    analyses: &[AppAnalysis],
) -> String {
    let mut md = String::new();
    md.push_str(&format!(
        "{label}: {} ({} points){}{}\n",
        spec.shape(),
        results.len(),
        if spec.fast { ", fast mode" } else { "" },
        spec.power_cap_mw
            .map(|c| format!(", power cap {c} mW"))
            .unwrap_or_default()
    ));
    for a in analyses {
        md.push_str(&format!("\n### {}\n\n", a.app));
        let mut rows: Vec<&PointResult> =
            results.iter().filter(|r| r.point.app == a.app).collect();
        rows.sort_by(|x, y| {
            let kx = x.metrics.as_ref().map(|m| m.crit_ns).unwrap_or(f64::INFINITY);
            let ky = y.metrics.as_ref().map(|m| m.crit_ns).unwrap_or(f64::INFINITY);
            kx.partial_cmp(&ky).unwrap().then(x.point.id.cmp(&y.point.id))
        });
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                let mark = if a.knee == Some(r.point.id) {
                    "**"
                } else if a.frontier.contains(&r.point.id) {
                    "*"
                } else if a.capped.contains(&r.point.id) {
                    "cap"
                } else if a.failed.contains(&r.point.id) {
                    "FAIL"
                } else {
                    ""
                };
                match &r.metrics {
                    Ok(m) => vec![
                        r.point.label(),
                        format!("{:.2}", m.crit_ns),
                        format!("{:.0}", m.fmax_mhz),
                        format!("{:.4}", m.runtime_ms),
                        format!("{:.0}", m.power_mw),
                        format!("{:.5}", m.edp),
                        format!("{}", m.pipe_regs),
                        mark.to_string(),
                    ],
                    Err(e) => vec![
                        r.point.label(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        e.clone(),
                        mark.to_string(),
                    ],
                }
            })
            .collect();
        md.push_str(&crate::experiments::common::md_table(
            &["point", "crit (ns)", "fmax (MHz)", "runtime (ms)", "power (mW)", "EDP", "regs", ""],
            &table,
        ));
        md.push_str(&format!(
            "\nPareto frontier (crit, EDP, regs): {} of {} feasible points",
            a.frontier.len(),
            rows.len() - a.capped.len() - a.failed.len()
        ));
        if let Some(k) = a.knee {
            let knee = results.iter().find(|r| r.point.id == k).unwrap();
            md.push_str(&format!("; knee: {} (**)", knee.point.label()));
        }
        if !a.capped.is_empty() {
            md.push_str(&format!("; {} point(s) over the power cap", a.capped.len()));
        }
        if !a.failed.is_empty() {
            md.push_str(&format!("; {} point(s) failed", a.failed.len()));
        }
        md.push('\n');
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::cache::PointMetrics;
    use crate::explore::space::ExplorePoint;

    fn mk(id: usize, app: &str, level: &str, crit: f64, edp: f64, regs: u64) -> PointResult {
        PointResult {
            point: ExplorePoint {
                id,
                app: app.into(),
                level: level.into(),
                alpha: None,
                seed: 1,
                iters: None,
                tracks: None,
                regwords: None,
                fifo: None,
                fuse: None,
            },
            metrics: Ok(PointMetrics {
                crit_ns: crit,
                fmax_mhz: 1000.0 / crit,
                runtime_ms: crit / 10.0,
                power_mw: 100.0 + regs as f64,
                energy_mj: 0.1,
                edp,
                pipe_regs: regs,
                util_pct: 50.0,
                cycles: 0,
                artifact_fp: id as u64,
            }),
            from_disk: false,
        }
    }

    fn spec2() -> ExploreSpec {
        ExploreSpec::default()
            .with_apps(["gaussian"])
            .with_levels(["none", "full"])
            .with_seeds([1])
    }

    #[test]
    fn frontier_includes_dominating_full_and_reg_free_none() {
        let spec = spec2();
        // full: far better crit/EDP but spends registers; none: reg-free.
        let rs = vec![
            mk(0, "gaussian", "none", 24.0, 10.0, 0),
            mk(1, "gaussian", "full", 2.0, 0.5, 400),
        ];
        let a = analyze(&spec, &rs);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].frontier, vec![0, 1]);
        let md = to_markdown(&spec, &rs, &a);
        assert!(md.contains("gaussian/full"));
        let j = to_json(&spec, &rs, &a).to_string_pretty();
        assert!(j.contains("\"frontier\""));
    }

    #[test]
    fn power_cap_excludes_points_from_frontier() {
        let spec = spec2().with_power_cap(Some(150.0));
        let rs = vec![
            mk(0, "gaussian", "none", 24.0, 10.0, 0),    // 100 mW: feasible
            mk(1, "gaussian", "full", 2.0, 0.5, 400),    // 500 mW: capped
        ];
        let a = analyze(&spec, &rs);
        assert_eq!(a[0].frontier, vec![0]);
        assert_eq!(a[0].capped, vec![1]);
    }

    #[test]
    fn dominated_point_left_off_frontier() {
        let spec = spec2().with_levels(["none", "compute", "full"]);
        let rs = vec![
            mk(0, "gaussian", "none", 24.0, 10.0, 100),
            mk(1, "gaussian", "compute", 6.0, 2.0, 80), // dominates 0
            mk(2, "gaussian", "full", 2.0, 0.5, 400),
        ];
        let a = analyze(&spec, &rs);
        assert_eq!(a[0].frontier, vec![1, 2]);
        // Normalized over the frontier, point 2 is (0, 0, 1) and point 1
        // is (1, 1, 0): point 2 sits closer to the ideal corner.
        assert_eq!(a[0].knee, Some(2));
    }

    #[test]
    fn point_json_carries_arch_coords_and_rung_tag() {
        let mut r = mk(7, "gaussian", "full", 2.0, 0.5, 40);
        r.point.tracks = Some(3);
        r.point.regwords = Some(16);
        let line = point_json(&r, Some(1)).to_string_compact();
        assert!(line.contains("\"tracks\":3"));
        assert!(line.contains("\"regwords\":16"));
        assert!(line.contains("\"fifo\":null"));
        assert!(line.contains("\"rung\":1"));
        let untagged = point_json(&r, None).to_string_compact();
        assert!(!untagged.contains("\"rung\""));
    }

    #[test]
    fn search_report_lists_every_rung() {
        let params = HalvingParams::default();
        let rungs = vec![
            RungReport { rung: 0, budget: 7, evaluated: 9, kept: 3 },
            RungReport { rung: 1, budget: 22, evaluated: 3, kept: 1 },
            RungReport { rung: 2, budget: 200, evaluated: 1, kept: 1 },
        ];
        let j = search_to_json(&params, &rungs).to_string_compact();
        assert!(j.contains("\"mode\":\"halving\""));
        assert!(j.contains("\"eta\":3"));
        assert_eq!(j.matches("\"budget\"").count(), 3);
        let md = search_to_markdown(&params, &rungs);
        assert!(md.contains("3 rung(s)"));
        assert!(md.contains("| 0 | 7 | 9 | 3 |"));
    }

    #[test]
    fn profile_section_orders_stages_and_reports_totals() {
        let reg = crate::obs::Registry::new();
        let spans = vec![
            crate::obs::SpanRecord { stage: "sta", nanos: 3_000_000, counters: Vec::new() },
            crate::obs::SpanRecord {
                stage: "map",
                nanos: 1_000_000,
                counters: vec![("place_moves_proposed", 10)],
            },
        ];
        crate::obs::record_compile_spans(&reg, &spans);
        let (md, json) = profile_section(&reg);
        assert!(md.contains("## Compile profile"));
        let map_at = md.find("| map |").expect("map row");
        let sta_at = md.find("| sta |").expect("sta row");
        assert!(map_at < sta_at, "pipeline order, not name order:\n{md}");
        let j = json.to_string_compact();
        assert!(j.contains("\"stages\""), "{j}");
        assert!(j.contains("\"compile_seconds\""), "{j}");
        assert!(j.contains("\"total_ns\":4000000"), "per-compile total is the span sum: {j}");
        // Kernel counters carried by the spans surface as their own table
        // (short names — the compile_kernel_ prefix is presentation noise).
        assert!(md.contains("| place_moves_proposed | 10 |"), "{md}");
        assert!(j.contains("\"kernels\":{\"place_moves_proposed\":10}"), "{j}");
    }

    #[test]
    fn profile_section_without_counters_has_no_kernel_table() {
        let reg = crate::obs::Registry::new();
        crate::obs::record_compile_spans(
            &reg,
            &[crate::obs::SpanRecord { stage: "sta", nanos: 1_000, counters: Vec::new() }],
        );
        let (md, json) = profile_section(&reg);
        assert!(!md.contains("Kernel work counters"), "{md}");
        assert!(!json.to_string_compact().contains("\"kernels\""));
    }

    #[test]
    fn failed_points_reported_not_ranked() {
        let spec = spec2();
        let mut bad = mk(1, "gaussian", "full", 0.0, 0.0, 0);
        bad.metrics = Err("routing: congestion".into());
        let rs = vec![mk(0, "gaussian", "none", 24.0, 10.0, 0), bad];
        let a = analyze(&spec, &rs);
        assert_eq!(a[0].frontier, vec![0]);
        assert_eq!(a[0].failed, vec![1]);
        let j = to_json(&spec, &rs, &a).to_string_compact();
        assert!(j.contains("routing: congestion"));
    }
}
