//! Parallel evaluation of an exploration grid.
//!
//! A work-queue executor over `std::thread::scope`: workers pull point
//! indices from a shared atomic cursor and write results into a
//! preallocated slot vector indexed by point id, so the output order is
//! the spec's enumeration order *regardless of thread count or
//! scheduling*. Compilation goes through the in-memory [`ArtifactCache`]
//! (in-flight deduplication of effective-config collisions) and the
//! persistent [`DiskCache`] (skip recompiles across invocations).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::experiments::common::compile_dense;
use crate::pipeline::{compile, CompileCtx, Compiled};

use super::cache::{point_key, ArtifactCache, DiskCache, PointMetrics};
use super::space::{ExplorePoint, ExploreSpec, Scale};

/// Outcome of one grid point.
#[derive(Debug, Clone)]
pub struct PointResult {
    pub point: ExplorePoint,
    pub metrics: Result<PointMetrics, String>,
    /// Served from the persistent metrics cache (informational only —
    /// excluded from reports so repeated runs emit identical JSON).
    pub from_disk: bool,
}

/// Cache traffic for one run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// In-memory artifact hits (intra-run effective-config collisions).
    pub memory_hits: usize,
    /// Fresh compiles.
    pub misses: usize,
    /// Points served from the persistent metrics cache.
    pub disk_hits: usize,
}

impl CacheStats {
    pub fn total_hits(&self) -> usize {
        self.memory_hits + self.disk_hits
    }
}

/// A completed exploration run: one result per grid point, in enumeration
/// order, plus cache statistics.
#[derive(Debug)]
pub struct RunOutcome {
    pub results: Vec<PointResult>,
    pub stats: CacheStats,
}

/// Evaluate every point of `spec` on `threads` worker threads.
pub fn run(
    spec: &ExploreSpec,
    ctx: &CompileCtx,
    threads: usize,
    disk: Option<&DiskCache>,
) -> RunOutcome {
    let points = spec.points();
    let artifacts = ArtifactCache::new();
    let cursor = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<PointResult>>> = Mutex::new(vec![None; points.len()]);

    let workers = threads.max(1).min(points.len().max(1));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= points.len() {
                    break;
                }
                let r = evaluate(&points[i], spec, ctx, &artifacts, disk);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });

    let results: Vec<PointResult> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker left a hole in the result vector"))
        .collect();
    let stats = CacheStats {
        memory_hits: artifacts.hits(),
        misses: artifacts.misses(),
        disk_hits: disk.map(|d| d.disk_hits()).unwrap_or(0),
    };
    RunOutcome { results, stats }
}

/// Evaluate one point: persistent cache, then artifact cache, then a
/// fresh compile + measurement.
fn evaluate(
    point: &ExplorePoint,
    spec: &ExploreSpec,
    ctx: &CompileCtx,
    artifacts: &ArtifactCache,
    disk: Option<&DiskCache>,
) -> PointResult {
    let sparse = crate::apps::is_sparse_name(&point.app);
    let mut cfg = point.config(spec.fast);
    if spec.scale == Scale::Tiny || sparse {
        // These paths compile directly and never consume §V-E duplication
        // (tiny frames have no unrolling headroom; the sparse DFGs are not
        // duplicable); clear the flag so the cache key and config
        // signature match what actually compiles — levels differing only
        // in `unroll_dup` then share one artifact.
        cfg.unroll_dup = false;
    }
    let key = point_key(&point.app, &cfg, point.seed, spec.scale.tag(), &ctx.arch);

    if let Some(d) = disk {
        if let Some(m) = d.load(key) {
            return PointResult { point: point.clone(), metrics: Ok(m), from_disk: true };
        }
    }
    if let Some(m) = artifacts.measured(key) {
        return PointResult { point: point.clone(), metrics: Ok(m), from_disk: false };
    }
    let compiled = artifacts.get_or_compile(key, || {
        if sparse || spec.scale == Scale::Tiny {
            let app = match spec.scale {
                Scale::Paper => crate::apps::by_name(&point.app),
                Scale::Tiny => crate::apps::by_name_tiny(&point.app),
            }
            .ok_or_else(|| format!("unknown app '{}'", point.app))?;
            compile(&app, ctx, &cfg, point.seed).map_err(|e| format!("{}: {e}", point.app))
        } else {
            // Paper-scale dense: shared dispatch with the experiment
            // harness (honours `unroll_dup`, handles resnet). `fast` is
            // already folded into `cfg` by `ExplorePoint::config`.
            compile_dense(&point.app, &cfg, ctx, false, point.seed)
        }
    });

    let metrics = compiled.and_then(|c| measure(&point.app, &c, sparse));
    if let Ok(m) = &metrics {
        artifacts.record_measured(key, m);
        if let Some(d) = disk {
            d.store(key, m);
        }
    }
    PointResult { point: point.clone(), metrics, from_disk: false }
}

/// Measure a compiled artifact. Sparse workloads run the ready-valid
/// functional simulation for their cycle count; dense runtimes come from
/// the static schedule.
fn measure(app_name: &str, c: &Compiled, sparse: bool) -> Result<PointMetrics, String> {
    if sparse {
        let data = crate::apps::sparse::data_for(app_name, 42);
        let run = crate::sparse::sim::simulate_app(app_name, &c.design.dfg, &data);
        Ok(PointMetrics::from_sparse(c, run.cycles))
    } else {
        Ok(PointMetrics::from_compiled(c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ExploreSpec {
        ExploreSpec::default()
            .with_apps(["gaussian"])
            .with_levels(["none", "compute"])
            .with_seeds([1])
            .with_fast(true)
            .with_scale(Scale::Tiny)
    }

    /// The satellite determinism requirement: identical output with
    /// `--threads 1` and `--threads 4`.
    #[test]
    fn deterministic_across_thread_counts() {
        let ctx = CompileCtx::paper();
        let spec = tiny_spec();
        let one = run(&spec, &ctx, 1, None);
        let four = run(&spec, &ctx, 4, None);
        assert_eq!(one.results.len(), four.results.len());
        for (a, b) in one.results.iter().zip(&four.results) {
            assert_eq!(a.point, b.point);
            assert_eq!(
                a.metrics.as_ref().ok(),
                b.metrics.as_ref().ok(),
                "point {} diverges across thread counts",
                a.point.label()
            );
        }
        // Hit/miss totals are scheduling-independent too: one miss per
        // distinct effective config, one lookup per point.
        assert_eq!(one.stats, four.stats);
    }

    #[test]
    fn iteration_budgets_collapse_on_unpipelined_baseline() {
        // `none` has no post-PnR pass, so every budget resolves to the
        // same effective config: 3 points, 1 compile, 2 memory hits.
        let ctx = CompileCtx::paper();
        let spec = tiny_spec().with_levels(["none"]).with_iters([10, 50, 200]);
        let out = run(&spec, &ctx, 2, None);
        assert_eq!(out.results.len(), 3);
        assert_eq!(out.stats.misses, 1);
        assert_eq!(out.stats.memory_hits, 2);
        let fp0 = out.results[0].metrics.as_ref().unwrap().artifact_fp;
        for r in &out.results {
            assert_eq!(r.metrics.as_ref().unwrap().artifact_fp, fp0);
        }
    }

    #[test]
    fn disk_cache_serves_second_run() {
        let dir = std::env::temp_dir().join(format!("cascade-run-dc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let spec = tiny_spec();
        let n = spec.points().len();

        let dc = DiskCache::at(&dir);
        let first = run(&spec, &ctx, 2, Some(&dc));
        assert_eq!(first.stats.disk_hits, 0);

        let dc2 = DiskCache::at(&dir);
        let second = run(&spec, &ctx, 2, Some(&dc2));
        assert_eq!(second.stats.disk_hits, n);
        assert_eq!(second.stats.misses, 0);
        for (a, b) in first.results.iter().zip(&second.results) {
            assert_eq!(a.metrics.as_ref().ok(), b.metrics.as_ref().ok());
            assert!(b.from_disk);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
