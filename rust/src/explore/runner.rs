//! Parallel evaluation of exploration points.
//!
//! The heart is [`EvalSession`], a reusable work-queue executor over
//! `std::thread::scope`: workers pull point indices from a shared atomic
//! cursor and write results into a preallocated slot vector indexed by
//! position, so the output order is the input order *regardless of thread
//! count or scheduling*. Compilation goes through the in-memory
//! [`ArtifactCache`] (in-flight deduplication of effective-config
//! collisions), the persistent [`DiskCache`] (skip recompiles across
//! invocations) with its compiled-artifact store (a warm `.art` file from
//! a resumed or sharded run rehydrates, fingerprint-checked, instead of
//! recompiling), and a per-architecture [`CtxCache`] (points that override
//! tracks / regfile words / FIFO depth share one lazily built
//! [`CompileCtx`] per distinct effective architecture).
//!
//! A session outlives a single sweep: the successive-halving search in
//! [`super::search`] evaluates every rung through one session, so a
//! candidate promoted to a higher budget reuses the artifacts, contexts
//! and disk records its cheaper evaluation already produced. The
//! spec-independent part — caches plus lookup/compile logic — is factored
//! into [`SessionCore`], which `cascade serve` holds for its whole daemon
//! lifetime to resolve every client request (each with its own
//! single-point spec) through one set of warm caches.
//!
//! Completed points can be streamed to a [`PartialSink`]
//! (`results/explore_partial.jsonl`): one JSON line per evaluation, in
//! completion order, so long sweeps are inspectable mid-run and a killed
//! run leaves behind both the partial log and the disk-cache records that
//! make the re-run cheap.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::arch::params::ArchParams;
use crate::experiments::common::compile_dense;
use crate::pipeline::{compile, CompileCtx, Compiled, PipelineConfig};

use super::cache::{arch_signature, point_key, ArtifactCache, DiskCache, PointMetrics};
use super::space::{ExplorePoint, ExploreSpec, Scale};

/// The effective (pipeline config, architecture, cache key) triple for one
/// point — exactly what [`EvalSession`] compiles and hashes. Public so the
/// sharding layer can partition points and `explore-merge` can re-derive
/// cache keys from a manifest's spec without building a compile context
/// (the [`ArchParams`] base is enough).
pub fn effective_point(
    spec: &ExploreSpec,
    base: &ArchParams,
    point: &ExplorePoint,
) -> (PipelineConfig, ArchParams, u64) {
    let sparse = crate::apps::is_sparse_name(&point.app);
    let mut cfg = point.config(spec.fast);
    if spec.scale == Scale::Tiny || sparse {
        // These paths compile directly and never consume §V-E duplication
        // (tiny frames have no unrolling headroom; the sparse DFGs are not
        // duplicable); clear the flag so the cache key and config signature
        // match what actually compiles — levels differing only in
        // `unroll_dup` then share one artifact.
        cfg.unroll_dup = false;
    }
    let arch = point.arch(base);
    let key = point_key(&point.app, &cfg, point.seed, spec.scale.tag(), &arch);
    (cfg, arch, key)
}

/// Just the cache key of [`effective_point`] — the hash the shard
/// partition is computed over.
pub fn effective_key(spec: &ExploreSpec, base: &ArchParams, point: &ExplorePoint) -> u64 {
    effective_point(spec, base, point).2
}

/// Compile one point under its already-resolved effective config and
/// compile context — the single dispatch shared by [`EvalSession`] and
/// `cascade encode`, so a standalone encode compiles byte-identically to
/// the sweep that would cache the same point. Tiny-scale and sparse apps
/// compile directly; paper-scale dense goes through the experiment
/// harness's dispatch (which honours `unroll_dup` and handles resnet).
pub fn compile_effective(
    spec: &ExploreSpec,
    point: &ExplorePoint,
    cfg: &PipelineConfig,
    ctx: &CompileCtx,
) -> Result<Compiled, String> {
    let sparse = crate::apps::is_sparse_name(&point.app);
    if sparse || spec.scale == Scale::Tiny {
        let app = match spec.scale {
            Scale::Paper => crate::apps::by_name(&point.app),
            Scale::Tiny => crate::apps::by_name_tiny(&point.app),
        }
        .ok_or_else(|| format!("unknown app '{}'", point.app))?;
        compile(&app, ctx, cfg, point.seed).map_err(|e| format!("{}: {e}", point.app))
    } else {
        // `fast` is already folded into `cfg` by `ExplorePoint::config`.
        compile_dense(&point.app, cfg, ctx, false, point.seed)
    }
}

/// Outcome of one grid point.
#[derive(Debug, Clone)]
pub struct PointResult {
    pub point: ExplorePoint,
    pub metrics: Result<PointMetrics, String>,
    /// Served from the persistent metrics cache (informational only —
    /// excluded from reports so repeated runs emit identical JSON).
    pub from_disk: bool,
}

/// Cache traffic for one run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// In-memory artifact hits (intra-run effective-config collisions).
    pub memory_hits: usize,
    /// Fresh compiles.
    pub misses: usize,
    /// Points served from the persistent metrics cache.
    pub disk_hits: usize,
    /// Compiled artifacts rehydrated from the persistent artifact store
    /// instead of recompiling (fingerprint-verified).
    pub art_hits: usize,
    /// Compile contexts built for non-base architectures.
    pub ctx_builds: usize,
}

impl CacheStats {
    pub fn total_hits(&self) -> usize {
        self.memory_hits + self.disk_hits + self.art_hits
    }
}

/// A completed exploration run: one result per grid point, in enumeration
/// order, plus cache statistics.
#[derive(Debug)]
pub struct RunOutcome {
    pub results: Vec<PointResult>,
    pub stats: CacheStats,
}

/// Evaluate every point of `spec` on `threads` worker threads (exhaustive
/// grid mode; the adaptive path is [`super::search::run_halving`]).
pub fn run(
    spec: &ExploreSpec,
    ctx: &CompileCtx,
    threads: usize,
    disk: Option<&DiskCache>,
) -> RunOutcome {
    let session = EvalSession::new(spec, ctx, disk, None);
    let results = session.eval_points(&spec.points(), threads, None);
    RunOutcome { results, stats: session.stats() }
}

type CtxSlot = Arc<Mutex<Option<Arc<CompileCtx>>>>;

/// Lazily built compile contexts keyed by effective-architecture
/// signature, with in-flight deduplication: when several workers race on
/// the same architecture variant, exactly one builds the (expensive)
/// delay-annotated interconnect graph and the rest block on the slot.
#[derive(Default)]
pub struct CtxCache {
    slots: Mutex<std::collections::HashMap<String, CtxSlot>>,
    builds: AtomicUsize,
}

impl CtxCache {
    pub fn get_or_build(&self, arch: &crate::arch::params::ArchParams) -> Arc<CompileCtx> {
        let slot = {
            let mut slots = self.slots.lock().unwrap();
            slots.entry(arch_signature(arch)).or_default().clone()
        };
        let mut guard = slot.lock().unwrap();
        if let Some(ctx) = &*guard {
            return ctx.clone();
        }
        self.builds.fetch_add(1, Ordering::Relaxed);
        let ctx = Arc::new(CompileCtx::new(arch.clone()));
        *guard = Some(ctx.clone());
        ctx
    }

    pub fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }

    /// Drop every cached context, returning how many were dropped. A
    /// build already in flight keeps its slot alive through its own `Arc`
    /// and completes normally; later callers simply rebuild. The build
    /// counter is cumulative and is *not* reset.
    pub fn clear(&self) -> usize {
        let mut slots = self.slots.lock().unwrap();
        let n = slots.len();
        slots.clear();
        n
    }
}

/// Append-only JSONL journal of completed evaluations. Lines are written
/// in completion order (scheduling-dependent); each line is
/// self-describing (grid coordinates, optional rung and shard tags), so
/// consumers sort or filter on the embedded fields.
///
/// The file is opened in append mode and an existing log is never
/// truncated: a resumed run, a later shard run in the same results
/// directory, or a merge concatenating shard logs all *extend* the
/// journal. Each run's span is recoverable from
/// ([`Self::start_line`], [`Self::written`]) — shard manifests record it.
/// Records are appended as one `write_all` per line (O_APPEND), but the
/// span bookkeeping is snapshotted at open: *concurrent* shard processes
/// should each write into their own directory (as the CI matrix does) and
/// let `explore-merge` concatenate; same-directory sharing is for
/// sequential runs.
pub struct PartialSink {
    path: PathBuf,
    file: Mutex<Option<std::fs::File>>,
    dropped: AtomicUsize,
    written: AtomicUsize,
    start_line: usize,
    shard: Option<String>,
}

impl PartialSink {
    /// Default location, next to the explore reports.
    pub fn default_path() -> PathBuf {
        PathBuf::from("results/explore_partial.jsonl")
    }

    /// Open the journal at `path` for appending, creating the file if it
    /// does not exist. Falls back to a no-op sink if the file cannot be
    /// opened (e.g. read-only filesystem).
    pub fn open(path: impl AsRef<Path>) -> PartialSink {
        PartialSink::open_tagged(path, None)
    }

    /// [`Self::open`] with a shard tag (`"K/N"`) stamped on every line, so
    /// concatenated multi-shard logs stay attributable.
    pub fn open_tagged(path: impl AsRef<Path>, shard: Option<String>) -> PartialSink {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let (mut start_line, terminated) = count_lines(&path);
        let mut file = std::fs::OpenOptions::new().append(true).create(true).open(&path).ok();
        if !terminated {
            // The previous writer died mid-line (killed between write and
            // flush). Terminate the partial line so the first new record
            // does not get glued onto corrupt JSON, and account it as one
            // (truncated) prior line.
            start_line += 1;
            if let Some(f) = &mut file {
                if writeln!(f).is_err() {
                    file = None;
                }
            }
        }
        PartialSink {
            path,
            file: Mutex::new(file),
            dropped: AtomicUsize::new(0),
            written: AtomicUsize::new(0),
            start_line,
            shard,
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of lines the journal already held when this sink opened it —
    /// the start of this run's span.
    pub fn start_line(&self) -> usize {
        self.start_line
    }

    /// Lines successfully written by this sink (this run's span length).
    pub fn written(&self) -> usize {
        self.written.load(Ordering::Relaxed)
    }

    /// Whether the stream actually opened (false on e.g. a read-only
    /// filesystem, where records are dropped).
    pub fn is_active(&self) -> bool {
        self.file.lock().unwrap().is_some()
    }

    /// Records lost to a failed open or a mid-run write error. Non-zero
    /// means the log is incomplete and must not be trusted as
    /// one-line-per-evaluation.
    pub fn dropped(&self) -> usize {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record one completed evaluation (rung is `None` in grid mode).
    pub fn record(&self, rung: Option<usize>, r: &PointResult) {
        let mut j = super::report::point_json(r, rung);
        if let Some(tag) = &self.shard {
            j.set("shard", tag.as_str());
        }
        // One pre-assembled write_all per record (line + newline in a
        // single buffer): with O_APPEND this keeps lines whole even if
        // another process appends to the same file.
        let mut line = j.to_string_compact();
        line.push('\n');
        let mut guard = self.file.lock().unwrap();
        let written = match guard.as_mut() {
            Some(f) => f.write_all(line.as_bytes()).and_then(|_| f.flush()).is_ok(),
            None => false,
        };
        if written {
            self.written.fetch_add(1, Ordering::Relaxed);
        } else {
            // The stream never opened or just broke (disk full, fd
            // error): stop writing so the log is not silently truncated
            // mid-file, and account every lost record.
            *guard = None;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Newline count of an existing file plus whether it ends in a newline
/// (`(0, true)` if absent/empty/unreadable) — how the sink locates the
/// start of its span, and detects a torn final line, without loading the
/// log into memory.
fn count_lines(path: &Path) -> (usize, bool) {
    use std::io::Read as _;
    let Ok(mut f) = std::fs::File::open(path) else { return (0, true) };
    let mut buf = [0u8; 64 * 1024];
    let mut n = 0usize;
    let mut last = b'\n';
    while let Ok(read) = f.read(&mut buf) {
        if read == 0 {
            break;
        }
        n += buf[..read].iter().filter(|&&b| b == b'\n').count();
        last = buf[read - 1];
    }
    (n, last == b'\n')
}

/// Where one served evaluation's artifact (or its metrics) came from —
/// the per-request cache provenance `cascade serve` reports to clients.
/// The ordering is the lookup order of [`SessionCore::evaluate_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provenance {
    /// A fresh compile ran (counts toward [`CacheStats::misses`]).
    Fresh,
    /// Served from the in-memory artifact cache — either a completed
    /// entry or an in-flight compile this request waited on (the daemon's
    /// N-clients-one-compile deduplication path).
    WarmMem,
    /// Rehydrated from the persistent artifact store (`.art`,
    /// fingerprint-verified).
    WarmArt,
    /// Served from the persistent metrics record (`.rec`) without
    /// touching the compiled artifact at all.
    WarmRec,
}

impl Provenance {
    pub fn tag(self) -> &'static str {
        match self {
            Provenance::Fresh => "fresh",
            Provenance::WarmMem => "warm_mem",
            Provenance::WarmArt => "warm_art",
            Provenance::WarmRec => "warm_rec",
        }
    }
}

/// The spec-independent heart of an evaluation session: the shared caches
/// (in-memory artifacts, per-architecture compile contexts, persistent
/// disk cache) plus the lookup/compile logic, *without* a fixed
/// [`ExploreSpec`]. A sweep wraps one in an [`EvalSession`] with a single
/// spec; the `cascade serve` daemon holds one for its whole lifetime and
/// resolves every client request — each carrying its own single-point spec
/// — through the same warm caches, so concurrent identical requests
/// deduplicate to exactly one compile.
pub struct SessionCore<'a> {
    base: &'a CompileCtx,
    base_sig: String,
    artifacts: ArtifactCache,
    ctxs: CtxCache,
    disk: Option<&'a DiskCache>,
    /// Optional metrics registry: fresh compiles record per-stage spans
    /// (`compile_stage_seconds{stage=..}` + `compile_seconds`) and
    /// measurements record `measure_seconds`. Write-only telemetry —
    /// attaching one can never change what a compile produces.
    obs: Option<Arc<crate::obs::Registry>>,
}

impl<'a> SessionCore<'a> {
    /// A core whose in-memory artifact cache retains every compiled
    /// artifact for the session's lifetime (sweep behaviour: rungs and
    /// duplicate grid points reuse them).
    pub fn new(base: &'a CompileCtx, disk: Option<&'a DiskCache>) -> SessionCore<'a> {
        SessionCore::with_cache(base, disk, ArtifactCache::new())
    }

    /// A core for long-running many-client service: in-memory artifacts
    /// live only while a compile is in flight (concurrent identical
    /// requests still deduplicate to one compile), and completed artifacts
    /// are dropped in favour of the persistent store — artifact memory
    /// stays bounded by concurrency no matter how many distinct points
    /// clients request (the measured-metrics side table, ~100 bytes per
    /// distinct point, is retained in both modes).
    pub fn ephemeral(base: &'a CompileCtx, disk: Option<&'a DiskCache>) -> SessionCore<'a> {
        SessionCore::with_cache(base, disk, ArtifactCache::ephemeral())
    }

    fn with_cache(
        base: &'a CompileCtx,
        disk: Option<&'a DiskCache>,
        artifacts: ArtifactCache,
    ) -> SessionCore<'a> {
        SessionCore {
            base,
            base_sig: arch_signature(&base.arch),
            artifacts,
            ctxs: CtxCache::default(),
            disk,
            obs: None,
        }
    }

    /// Attach a metrics registry ([`crate::obs::Registry`]) for stage
    /// tracing: every *fresh* compile this core runs is traced and its
    /// spans recorded as `compile_stage_seconds{stage=..}` histogram
    /// observations (warm hits compile nothing, so they record nothing),
    /// and every measurement records `measure_seconds`.
    pub fn set_obs(&mut self, reg: Arc<crate::obs::Registry>) {
        self.obs = Some(reg);
    }

    /// The attached metrics registry, if any.
    pub fn obs(&self) -> Option<&Arc<crate::obs::Registry>> {
        self.obs.as_ref()
    }

    /// The effective cache key of `point` under `spec` (cheap parameter
    /// work, no compile context).
    pub fn key_of(&self, spec: &ExploreSpec, point: &ExplorePoint) -> u64 {
        effective_key(spec, &self.base.arch, point)
    }

    /// Cumulative cache traffic across everything this core served. A
    /// store rehydration happens *inside* an in-memory miss, so `misses`
    /// (fresh compiles) subtracts the rehydrated count back out.
    pub fn stats(&self) -> CacheStats {
        let art_hits = self.disk.map(|d| d.artifacts().hits()).unwrap_or(0);
        CacheStats {
            memory_hits: self.artifacts.hits(),
            misses: self.artifacts.misses().saturating_sub(art_hits),
            disk_hits: self.disk.map(|d| d.disk_hits()).unwrap_or(0),
            art_hits,
            ctx_builds: self.ctxs.builds(),
        }
    }

    /// Publish the in-memory cache layer's counters into `reg` as gauges
    /// (scrape-time totals — the twin of
    /// [`DiskCache::publish_metrics`], which covers the persistent
    /// layers). Called by exposition producers right before rendering.
    pub fn publish_metrics(&self, reg: &crate::obs::Registry) {
        let s = self.stats();
        reg.gauge("cache_memory_hits", "in-memory artifact/metrics hits")
            .set(s.memory_hits as u64);
        reg.gauge("cache_fresh_compiles", "points compiled fresh (every cache layer missed)")
            .set(s.misses as u64);
        reg.gauge("cache_ctx_builds", "compile contexts built for non-base architectures")
            .set(s.ctx_builds as u64);
    }

    /// Drop compile contexts built for non-base architectures (the base
    /// context is borrowed, not cached, and is never dropped). The daemon's
    /// housekeeping calls this so a long-lived server polled with many
    /// distinct architecture variants does not accumulate delay-annotated
    /// interconnect graphs forever; a dropped context is simply rebuilt on
    /// the next request that needs it. Returns how many were dropped.
    pub fn drop_arch_contexts(&self) -> usize {
        self.ctxs.clear()
    }

    /// Evaluate one point: persistent metrics cache, then in-memory
    /// artifact cache, then the persistent artifact store (rehydrate a
    /// warm artifact instead of recompiling), then a fresh compile +
    /// measurement under the point's effective architecture. Returns the
    /// result, which [`Provenance`] layer served it, and the effective
    /// cache key (already computed here — warm daemon hits must not pay
    /// the derivation twice).
    pub fn evaluate_with(
        &self,
        spec: &ExploreSpec,
        point: &ExplorePoint,
    ) -> (PointResult, Provenance, u64) {
        let sparse = crate::apps::is_sparse_name(&point.app);
        // Resolve the effective config, architecture and content-hash key
        // (cheap parameter work only, so cache hits below never pay for a
        // compile context).
        let (cfg, arch, key) = effective_point(spec, &self.base.arch, point);

        if let Some(d) = self.disk {
            if let Some(m) = d.load(key) {
                // The artifact was not loaded, but the point WAS used:
                // tell the LRU journal, or fully-warm sweeps would look
                // cold to a later GC.
                d.artifacts().note_use(key);
                let r = PointResult { point: point.clone(), metrics: Ok(m), from_disk: true };
                return (r, Provenance::WarmRec, key);
            }
        }
        if let Some(m) = self.artifacts.measured(key) {
            let r = PointResult { point: point.clone(), metrics: Ok(m), from_disk: false };
            return (r, Provenance::WarmMem, key);
        }
        let (compiled, prov) = self.compile_slot(spec, point, &cfg, &arch, key);

        let metrics = match compiled {
            Err(e) => Err(e),
            Ok(c) => {
                // A waiter that shared an in-flight winner's artifact can
                // often reuse the winner's measurement too (the sparse
                // functional simulation can cost as much as the compile).
                // Quiet probe: whether it lands is scheduling-dependent,
                // so it must not perturb the hit/miss statistics.
                let reused = if prov == Provenance::WarmMem {
                    self.artifacts.measured_quiet(key)
                } else {
                    None
                };
                match reused {
                    Some(m) => Ok(m),
                    None => self.timed_measure(&point.app, &c, sparse),
                }
            }
        };
        if let Ok(m) = &metrics {
            self.artifacts.record_measured(key, m);
            if let Some(d) = self.disk {
                d.store(key, m);
            }
        }
        (PointResult { point: point.clone(), metrics, from_disk: false }, prov, key)
    }

    /// Resolve `point` to its *compiled artifact* (not just metrics): the
    /// in-memory cache, then the persistent store, then a fresh compile —
    /// the path `cascade serve`'s `encode` requests take, sharing in-flight
    /// deduplication with concurrent `compile` requests for the same key.
    /// A fresh compile persists its artifact, warming the store.
    pub fn compiled_with(
        &self,
        spec: &ExploreSpec,
        point: &ExplorePoint,
    ) -> (u64, Result<Arc<Compiled>, String>, Provenance) {
        let (cfg, arch, key) = effective_point(spec, &self.base.arch, point);
        let (res, prov) = self.compile_slot(spec, point, &cfg, &arch, key);
        (key, res, prov)
    }

    /// The shared dedup slot: exactly one caller per in-flight key runs
    /// the store-load-or-compile closure; everyone else blocks on the slot
    /// and shares its result ([`Provenance::WarmMem`]).
    fn compile_slot(
        &self,
        spec: &ExploreSpec,
        point: &ExplorePoint,
        cfg: &PipelineConfig,
        arch: &ArchParams,
        key: u64,
    ) -> (Result<Arc<Compiled>, String>, Provenance) {
        // A point needs its own context only when the arch signature
        // actually deviates from the base (overrides that merely restate
        // base values reuse the base context).
        let needs_own_ctx = point.has_arch_overrides() && arch_signature(arch) != self.base_sig;
        let prov = std::cell::Cell::new(Provenance::WarmMem);
        let res = self.artifacts.get_or_compile(key, || {
            // A warm artifact from an earlier (possibly killed or sharded)
            // run rehydrates instead of recompiling; the fingerprint check
            // inside `load` rejects torn or stale files, which then fall
            // through to a fresh compile that repairs the store entry.
            if let Some(store) = self.disk.map(DiskCache::artifacts) {
                if let Some(c) = store.load(key, None) {
                    prov.set(Provenance::WarmArt);
                    return Ok(c);
                }
            }
            // From here on this is a fresh compile attempt — errors are
            // compile failures, not cache traffic.
            prov.set(Provenance::Fresh);
            // Only a real compile pays for a delay-annotated context.
            let ctx_arc;
            let ctx: &CompileCtx = if needs_own_ctx {
                ctx_arc = self.ctxs.get_or_build(arch);
                &ctx_arc
            } else {
                self.base
            };
            let c = match &self.obs {
                Some(reg) => {
                    let (res, spans) =
                        crate::obs::with_spans(|| compile_effective(spec, point, cfg, ctx));
                    crate::obs::record_compile_spans(reg, &spans);
                    // Relay the stage spans (kernel counters attached) to
                    // whoever is tracing this request — the serve worker
                    // grafts them into its span tree. No-op otherwise.
                    crate::obs::trace::publish(&spans);
                    res?
                }
                None => compile_effective(spec, point, cfg, ctx)?,
            };
            if let Some(store) = self.disk.map(DiskCache::artifacts) {
                store.store(key, &c);
            }
            Ok(c)
        });
        (res, prov.get())
    }

    /// [`measure`] plus an optional `measure_seconds` observation.
    fn timed_measure(
        &self,
        app: &str,
        c: &Compiled,
        sparse: bool,
    ) -> Result<PointMetrics, String> {
        match &self.obs {
            Some(reg) => {
                let t0 = std::time::Instant::now();
                let m = measure(app, c, sparse);
                reg.histogram("measure_seconds", crate::obs::help::MEASURE)
                    .observe_duration(t0.elapsed());
                m
            }
            None => measure(app, c, sparse),
        }
    }
}

/// A reusable evaluation session: a [`SessionCore`] bound to one spec,
/// plus the streaming sink. The grid runner evaluates one batch; the
/// halving search evaluates one batch per rung through the same session.
pub struct EvalSession<'a> {
    spec: &'a ExploreSpec,
    core: SessionCore<'a>,
    sink: Option<&'a PartialSink>,
}

impl<'a> EvalSession<'a> {
    pub fn new(
        spec: &'a ExploreSpec,
        base: &'a CompileCtx,
        disk: Option<&'a DiskCache>,
        sink: Option<&'a PartialSink>,
    ) -> EvalSession<'a> {
        EvalSession { spec, core: SessionCore::new(base, disk), sink }
    }

    /// Attach a metrics registry to the underlying [`SessionCore`]
    /// (stage-span histograms for `cascade explore --profile`).
    pub fn set_obs(&mut self, reg: Arc<crate::obs::Registry>) {
        self.core.set_obs(reg);
    }

    /// Evaluate `points` on `threads` worker threads; results come back in
    /// input order independent of scheduling. `rung` tags the streamed
    /// partial records when called from the halving search.
    pub fn eval_points(
        &self,
        points: &[ExplorePoint],
        threads: usize,
        rung: Option<usize>,
    ) -> Vec<PointResult> {
        let cursor = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<PointResult>>> = Mutex::new(vec![None; points.len()]);

        let workers = threads.max(1).min(points.len().max(1));
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::SeqCst);
                    if i >= points.len() {
                        break;
                    }
                    let r = self.evaluate(&points[i]);
                    if let Some(sink) = self.sink {
                        sink.record(rung, &r);
                    }
                    slots.lock().unwrap()[i] = Some(r);
                });
            }
        });

        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("worker left a hole in the result vector"))
            .collect()
    }

    /// Cumulative cache traffic across every batch this session ran.
    pub fn stats(&self) -> CacheStats {
        self.core.stats()
    }

    /// Evaluate one point through the shared [`SessionCore`].
    fn evaluate(&self, point: &ExplorePoint) -> PointResult {
        self.core.evaluate_with(self.spec, point).0
    }
}

/// Measure a compiled artifact. Sparse workloads run the ready-valid
/// functional simulation for their cycle count; dense runtimes come from
/// the static schedule.
fn measure(app_name: &str, c: &Compiled, sparse: bool) -> Result<PointMetrics, String> {
    if sparse {
        let data = crate::apps::sparse::data_for(app_name, 42);
        let run = crate::sparse::sim::simulate_app(app_name, &c.design.dfg, &data);
        Ok(PointMetrics::from_sparse(c, run.cycles))
    } else {
        Ok(PointMetrics::from_compiled(c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ExploreSpec {
        ExploreSpec::default()
            .with_apps(["gaussian"])
            .with_levels(["none", "compute"])
            .with_seeds([1])
            .with_fast(true)
            .with_scale(Scale::Tiny)
    }

    /// The satellite determinism requirement: identical output with
    /// `--threads 1` and `--threads 4`.
    #[test]
    fn deterministic_across_thread_counts() {
        let ctx = CompileCtx::paper();
        let spec = tiny_spec();
        let one = run(&spec, &ctx, 1, None);
        let four = run(&spec, &ctx, 4, None);
        assert_eq!(one.results.len(), four.results.len());
        for (a, b) in one.results.iter().zip(&four.results) {
            assert_eq!(a.point, b.point);
            assert_eq!(
                a.metrics.as_ref().ok(),
                b.metrics.as_ref().ok(),
                "point {} diverges across thread counts",
                a.point.label()
            );
        }
        // Hit/miss totals are scheduling-independent too: one miss per
        // distinct effective config, one lookup per point.
        assert_eq!(one.stats, four.stats);
    }

    #[test]
    fn iteration_budgets_collapse_on_unpipelined_baseline() {
        // `none` has no post-PnR pass, so every budget resolves to the
        // same effective config: 3 points, 1 compile, 2 memory hits.
        let ctx = CompileCtx::paper();
        let spec = tiny_spec().with_levels(["none"]).with_iters([10, 50, 200]);
        let out = run(&spec, &ctx, 2, None);
        assert_eq!(out.results.len(), 3);
        assert_eq!(out.stats.misses, 1);
        assert_eq!(out.stats.memory_hits, 2);
        let fp0 = out.results[0].metrics.as_ref().unwrap().artifact_fp;
        for r in &out.results {
            assert_eq!(r.metrics.as_ref().unwrap().artifact_fp, fp0);
        }
    }

    #[test]
    fn disk_cache_serves_second_run() {
        let dir = std::env::temp_dir().join(format!("cascade-run-dc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let spec = tiny_spec();
        let n = spec.points().len();

        let dc = DiskCache::at(&dir);
        let first = run(&spec, &ctx, 2, Some(&dc));
        assert_eq!(first.stats.disk_hits, 0);

        let dc2 = DiskCache::at(&dir);
        let second = run(&spec, &ctx, 2, Some(&dc2));
        assert_eq!(second.stats.disk_hits, n);
        assert_eq!(second.stats.misses, 0);
        for (a, b) in first.results.iter().zip(&second.results) {
            assert_eq!(a.metrics.as_ref().ok(), b.metrics.as_ref().ok());
            assert!(b.from_disk);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Artifact persistence: when the metrics records are gone but the
    /// `.art` files survive, a re-run rehydrates every artifact instead of
    /// recompiling (zero fresh compiles), and the metrics it re-derives
    /// are identical.
    #[test]
    fn artifact_store_rehydrates_when_metrics_records_are_lost() {
        let dir = std::env::temp_dir().join(format!("cascade-rehydrate-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let spec = tiny_spec();

        let dc = DiskCache::at(&dir);
        let first = run(&spec, &ctx, 2, Some(&dc));
        let distinct = first.stats.misses;
        assert!(distinct > 0);
        assert_eq!(dc.artifacts().stores(), distinct, "every fresh compile persists its artifact");

        // Lose the metrics records (e.g. a partial rsync), keep the .art
        // files: the re-run must rehydrate, not recompile.
        for r in &first.results {
            let key = effective_key(&spec, &ctx.arch, &r.point);
            let _ = std::fs::remove_file(dir.join(format!("{key:016x}.rec")));
        }
        let dc2 = DiskCache::at(&dir);
        let second = run(&spec, &ctx, 2, Some(&dc2));
        assert_eq!(second.stats.misses, 0, "no fresh compiles on a warm artifact store");
        assert_eq!(second.stats.art_hits, distinct);
        for (a, b) in first.results.iter().zip(&second.results) {
            assert_eq!(a.metrics.as_ref().ok(), b.metrics.as_ref().ok());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A torn `.art` file (writer killed mid-write, disk corruption) is
    /// detected by the fingerprint check and recompiled — never trusted —
    /// and the fresh compile repairs the store entry in place.
    #[test]
    fn torn_artifact_is_recompiled_not_trusted() {
        let dir = std::env::temp_dir().join(format!("cascade-torn-art-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let spec = tiny_spec().with_levels(["compute"]);
        let dc = DiskCache::at(&dir);
        let first = run(&spec, &ctx, 1, Some(&dc));
        let key = effective_key(&spec, &ctx.arch, &spec.points()[0]);
        let art = dir.join("artifacts").join(format!("{key:016x}.art"));
        assert!(art.exists());

        // Tear the artifact and drop the metrics record so the next run
        // must go through the store.
        let bytes = std::fs::read(&art).unwrap();
        std::fs::write(&art, &bytes[..bytes.len() / 3]).unwrap();
        std::fs::remove_file(dir.join(format!("{key:016x}.rec"))).unwrap();

        let dc2 = DiskCache::at(&dir);
        let second = run(&spec, &ctx, 1, Some(&dc2));
        assert_eq!(second.stats.art_hits, 0, "a torn artifact must not count as a hit");
        assert_eq!(second.stats.misses, 1, "the torn artifact is recompiled");
        assert_eq!(dc2.artifacts().rejected(), 1);
        assert_eq!(
            first.results[0].metrics.as_ref().ok(),
            second.results[0].metrics.as_ref().ok()
        );
        // The fresh compile repaired the store: a third run rehydrates.
        let reread = std::fs::read(&art).unwrap();
        assert_eq!(reread, bytes, "repaired artifact is byte-identical to the original");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn arch_axis_points_get_distinct_contexts_and_artifacts() {
        // Narrower interconnect: same app, same config, different arch ->
        // distinct cache keys, one extra context build, and (in general) a
        // different compiled artifact.
        let ctx = CompileCtx::paper();
        let spec = tiny_spec().with_levels(["compute"]).with_tracks([3, 5]);
        let out = run(&spec, &ctx, 2, None);
        assert_eq!(out.results.len(), 2);
        assert_eq!(out.stats.misses, 2, "arch variants must not share artifacts");
        // tracks=5 restates the base track count, so it reuses the base
        // context; only tracks=3 builds a new one.
        assert_eq!(out.stats.ctx_builds, 1);
        // The base-width variant always routes; the narrow one may fail
        // (that is a legitimate DSE datum), but it must fail *measured*,
        // not by panicking or sharing the wide artifact.
        assert!(out.results[1].metrics.is_ok(), "{:?}", out.results[1].metrics);
        if let (Ok(narrow), Ok(wide)) = (&out.results[0].metrics, &out.results[1].metrics) {
            assert_ne!(narrow.artifact_fp, wide.artifact_fp);
        }
    }

    #[test]
    fn ctx_cache_memoizes_by_signature() {
        let cache = CtxCache::default();
        let a = crate::arch::params::ArchParams::tiny(4, 8).with_tracks(3);
        let c1 = cache.get_or_build(&a);
        let c2 = cache.get_or_build(&a.clone());
        assert!(Arc::ptr_eq(&c1, &c2));
        assert_eq!(cache.builds(), 1);
        let b = a.clone().with_tracks(4);
        let c3 = cache.get_or_build(&b);
        assert!(!Arc::ptr_eq(&c1, &c3));
        assert_eq!(cache.builds(), 2);
    }

    #[test]
    fn partial_sink_streams_one_line_per_point() {
        let path = std::env::temp_dir()
            .join(format!("cascade-partial-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let ctx = CompileCtx::paper();
        let spec = tiny_spec();
        let sink = PartialSink::open(&path);
        let session = EvalSession::new(&spec, &ctx, None, Some(&sink));
        let results = session.eval_points(&spec.points(), 2, Some(0));
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), results.len());
        assert!(sink.is_active());
        assert_eq!(sink.dropped(), 0);
        for line in &lines {
            assert!(line.starts_with('{') && line.ends_with('}'), "bad JSONL line: {line}");
            assert!(line.contains("\"rung\":0"));
            assert!(line.contains("\"crit_ns\""));
        }
        assert_eq!(sink.start_line(), 0);
        assert_eq!(sink.written(), results.len());
        let _ = std::fs::remove_file(&path);
    }

    /// The append-mode bugfix: reopening an existing journal must extend
    /// it, never truncate it, and a shard tag stamps every line.
    #[test]
    fn partial_sink_appends_and_tags_shard() {
        let path = std::env::temp_dir()
            .join(format!("cascade-partial-append-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, "{\"prior\":true}\n{\"prior\":true}\n").unwrap();

        let ctx = CompileCtx::paper();
        let spec = tiny_spec().with_levels(["none"]);
        let sink = PartialSink::open_tagged(&path, Some("2/3".into()));
        assert_eq!(sink.start_line(), 2, "must account the existing span");
        let session = EvalSession::new(&spec, &ctx, None, Some(&sink));
        let results = session.eval_points(&spec.points(), 1, None);
        assert_eq!(sink.written(), results.len());

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2 + results.len(), "prior lines must survive a reopen");
        assert!(lines[0].contains("prior"), "existing content must not be truncated");
        for line in &lines[2..] {
            assert!(line.contains("\"shard\":\"2/3\""), "shard tag missing: {line}");
        }
        let _ = std::fs::remove_file(&path);
    }

    /// A journal whose writer died mid-line is repaired on reopen: the
    /// torn line is terminated (and counted), so new records stay valid
    /// JSONL instead of being glued onto corrupt JSON.
    #[test]
    fn partial_sink_repairs_torn_final_line() {
        let path = std::env::temp_dir()
            .join(format!("cascade-partial-torn-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, "{\"complete\":true}\n{\"torn\":tr").unwrap();
        let sink = PartialSink::open(&path);
        assert_eq!(sink.start_line(), 2, "the torn line must be counted");
        let ctx = CompileCtx::paper();
        let spec = tiny_spec().with_levels(["none"]);
        let session = EvalSession::new(&spec, &ctx, None, Some(&sink));
        let results = session.eval_points(&spec.points(), 1, None);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2 + results.len());
        assert_eq!(lines[1], "{\"torn\":tr", "torn line terminated, not extended");
        assert!(lines[2].starts_with('{') && lines[2].ends_with('}'), "bad line: {}", lines[2]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn effective_key_matches_session_cache_key() {
        // One compile via the session, then a direct disk probe with the
        // externally derived key: the record must be there. This pins the
        // contract the sharding layer depends on (partition and merge both
        // re-derive keys through `effective_key`).
        let dir = std::env::temp_dir().join(format!("cascade-effkey-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let spec = tiny_spec().with_levels(["none"]);
        let dc = DiskCache::at(&dir);
        let out = run(&spec, &ctx, 1, Some(&dc));
        assert!(out.results.iter().all(|r| r.metrics.is_ok()));
        let dc2 = DiskCache::at(&dir);
        for p in spec.points() {
            let key = effective_key(&spec, &ctx.arch, &p);
            assert!(dc2.load(key).is_some(), "no cache record under derived key for {}", p.label());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
