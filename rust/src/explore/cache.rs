//! Content-hash keyed artifact caching for design-space exploration.
//!
//! Two layers:
//!
//! * [`ArtifactCache`] — in-memory, thread-safe memoization of full
//!   [`Compiled`] artifacts keyed by the content hash of the evaluation
//!   point. Per-key slot locks give in-flight deduplication: when several
//!   workers race on the same effective configuration (e.g. the shared
//!   `level=none` baseline reached through different grid axes), exactly
//!   one compiles and the rest block on the slot and reuse the artifact.
//! * [`DiskCache`] — persistent memoization of the *measured* point
//!   metrics under `results/explore_cache/`, so a repeated `cascade
//!   explore` (or a later `cascade exp summary`) skips recompilation
//!   entirely. Records are flat `key=value` text; floats round-trip
//!   exactly via Rust's shortest-representation formatting. Each disk
//!   cache also carries an [`ArtifactStore`](super::artifact::ArtifactStore)
//!   (`explore_cache/artifacts/`) persisting the *compiled artifacts*
//!   themselves, fingerprint-checked and LRU-evictable — see
//!   [`super::artifact`] and `docs/cache.md`.
//!
//! The cache key hashes the *effective* configuration (every field of the
//! resolved [`PipelineConfig`]), the app name and scale, the PnR seed, and
//! the architecture signature — never the grid coordinates — so distinct
//! grid points that resolve identically share an entry, and any change to
//! a knob that affects the artifact changes the key.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::arch::params::ArchParams;
use crate::pipeline::{Compiled, PipelineConfig};

/// FNV-1a over bytes: the crate-wide content-hash primitive.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Canonical serialization of an effective pipeline configuration. Every
/// field participates; `{:?}` on floats is the shortest round-trip form,
/// so distinct values never collide textually.
///
/// [`crate::pnr::IncrementalCfg`] is deliberately absent: the incremental
/// kernel switches cannot affect any compiled output (the byte-identity
/// contract in `docs/performance.md`), so they must not perturb cache keys
/// — artifacts compiled with and without `--no-incremental` are
/// interchangeable. `fusion` is the opposite case: fused and unfused
/// compiles are semantically equivalent but structurally different
/// artifacts (see `docs/fusion.md`), so the knob MUST participate and the
/// two never share a key.
pub fn config_signature(cfg: &PipelineConfig) -> String {
    let bcast = match &cfg.broadcast {
        None => "off".to_string(),
        Some(b) => format!(
            "{}/{}/{}",
            b.fanout_threshold, b.max_stage_fanout, b.max_buffers_per_net
        ),
    };
    let postpnr = match &cfg.postpnr {
        None => "off".to_string(),
        Some(p) => format!("{}/{:?}", p.max_iters, p.min_gain),
    };
    format!(
        "compute={};rf={:?};bcast={};alpha={:?};effort={:?};postpnr={};dup={};flush={};fuse={}",
        cfg.compute,
        cfg.regfile_threshold,
        bcast,
        cfg.place_alpha,
        cfg.place_effort,
        postpnr,
        cfg.unroll_dup,
        cfg.hardened_flush,
        cfg.fusion
    )
}

/// Canonical serialization of every architecture parameter (a change to
/// any knob that can affect a compiled artifact must change the key).
/// Tracks, regfile words and FIFO depth are live `explore` sweep axes:
/// this signature is also the memoization key of the runner's
/// per-architecture compile-context cache, so it must stay injective over
/// the parameter set.
pub fn arch_signature(arch: &ArchParams) -> String {
    format!(
        "{}x{};memp={};tracks={};ports={}/{}/{}/{};rf={};fifo={};hflush={}",
        arch.cols,
        arch.rows,
        arch.mem_col_period,
        arch.tracks,
        arch.data_in_ports,
        arch.data_out_ports,
        arch.bit_in_ports,
        arch.bit_out_ports,
        arch.regfile_words,
        arch.fifo_depth,
        arch.hardened_flush
    )
}

/// Content-hash key for one evaluation point. The crate version
/// participates so persistent records from an older build miss rather
/// than serving stale numbers — bump the version in `Cargo.toml` when a
/// compiler pass changes behaviour (or pass `--no-cache` for one run).
pub fn point_key(
    app: &str,
    cfg: &PipelineConfig,
    seed: u64,
    scale: &str,
    arch: &ArchParams,
) -> u64 {
    let s = format!(
        "ver={};app={app};scale={scale};seed={seed};arch={};{}",
        env!("CARGO_PKG_VERSION"),
        arch_signature(arch),
        config_signature(cfg)
    );
    fnv1a(s.as_bytes())
}

fn mix(h: u64, v: u64) -> u64 {
    // FNV-1a over the value's 8 bytes.
    let mut h = h;
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Order-independent fingerprint of a compiled artifact: placement,
/// enabled pipelining registers, routes, timing and schedule. Two
/// artifacts with equal fingerprints are bit-identical as far as every
/// downstream consumer (STA, simulation, bitstream encoding) can observe.
pub fn fingerprint(c: &Compiled) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    h = mix(h, c.design.dfg.nodes.len() as u64);
    h = mix(h, c.design.dfg.edges.len() as u64);
    for (i, t) in c.design.placement.pos.iter().enumerate() {
        let slot = c.design.placement.slot[i] as u64;
        h = mix(h, ((t.x as u64) << 32) | ((t.y as u64) << 8) | slot);
    }
    let mut regs: Vec<u64> = c.design.sb_regs.iter().map(|&r| r as u64).collect();
    regs.sort_unstable();
    for r in regs {
        h = mix(h, r);
    }
    let mut rf: Vec<(u64, u64)> =
        c.design.rf_delay.iter().map(|(&e, &d)| (e as u64, d as u64)).collect();
    rf.sort_unstable();
    for (e, d) in rf {
        h = mix(h, (e << 32) | d);
    }
    for route in &c.design.routes {
        h = mix(h, route.net as u64);
        for path in &route.sink_paths {
            h = mix(h, path.len() as u64);
            for &n in path {
                h = mix(h, n as u64);
            }
        }
    }
    h = mix(h, c.sta.period_ps.to_bits());
    h = mix(h, c.schedule.total_cycles);
    h = mix(h, c.schedule.fill_latency);
    let (sb, rfw, fifos) = c.design.pipelining_resources();
    h = mix(h, sb as u64);
    h = mix(h, rfw);
    h = mix(h, fifos);
    h
}

/// Measured metrics for one evaluation point — the unit the disk cache
/// stores and the Pareto analysis consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct PointMetrics {
    /// Critical-path delay (ns).
    pub crit_ns: f64,
    pub fmax_mhz: f64,
    /// Per-frame runtime (dense) or total kernel runtime (sparse), ms.
    pub runtime_ms: f64,
    /// Total power (mW), duplication copies included.
    pub power_mw: f64,
    /// Energy over the runtime (mJ).
    pub energy_mj: f64,
    /// Energy-delay product (mJ*ms).
    pub edp: f64,
    /// Pipelining register footprint: SB regs + RF words + FIFO stages.
    pub pipe_regs: u64,
    /// Array utilization (%).
    pub util_pct: f64,
    /// Simulated cycles (sparse workloads; 0 for dense).
    pub cycles: u64,
    /// Fingerprint of the compiled artifact the metrics came from.
    pub artifact_fp: u64,
}

impl PointMetrics {
    /// Measure a compiled dense artifact (duplication-aware power).
    pub fn from_compiled(c: &Compiled) -> PointMetrics {
        let copies = c.dup.as_ref().map(|p| p.copies).unwrap_or(1);
        let m = crate::sim::power::EnergyModel::default();
        let p = crate::sim::power::estimate_scaled(&c.design, c.fmax_mhz(), copies, &m);
        let runtime_ms = c.runtime_ms();
        let (sb, rf, fifos) = c.design.pipelining_resources();
        PointMetrics {
            crit_ns: c.sta.period_ps / 1000.0,
            fmax_mhz: c.fmax_mhz(),
            runtime_ms,
            power_mw: p.total_mw(),
            energy_mj: p.energy_mj(runtime_ms),
            edp: p.edp(runtime_ms),
            pipe_regs: sb as u64 + rf + fifos,
            util_pct: c.map_report.utilization() * 100.0,
            cycles: 0,
            artifact_fp: fingerprint(c),
        }
    }

    /// Measure a compiled sparse artifact given its simulated cycle count.
    pub fn from_sparse(c: &Compiled, cycles: u64) -> PointMetrics {
        let m = crate::sim::power::EnergyModel::default();
        let p = crate::sim::power::estimate_scaled(&c.design, c.fmax_mhz(), 1, &m);
        // cycles / MHz = microseconds.
        let runtime_ms = cycles as f64 / c.fmax_mhz() / 1000.0;
        let (sb, rf, fifos) = c.design.pipelining_resources();
        PointMetrics {
            crit_ns: c.sta.period_ps / 1000.0,
            fmax_mhz: c.fmax_mhz(),
            runtime_ms,
            power_mw: p.total_mw(),
            energy_mj: p.energy_mj(runtime_ms),
            edp: p.edp(runtime_ms),
            pipe_regs: sb as u64 + rf + fifos,
            util_pct: c.map_report.utilization() * 100.0,
            cycles,
            artifact_fp: fingerprint(c),
        }
    }

    fn to_record(&self) -> String {
        format!(
            "v=1\ncrit_ns={:?}\nfmax_mhz={:?}\nruntime_ms={:?}\npower_mw={:?}\n\
             energy_mj={:?}\nedp={:?}\npipe_regs={}\nutil_pct={:?}\ncycles={}\nartifact_fp={}\n",
            self.crit_ns,
            self.fmax_mhz,
            self.runtime_ms,
            self.power_mw,
            self.energy_mj,
            self.edp,
            self.pipe_regs,
            self.util_pct,
            self.cycles,
            self.artifact_fp
        )
    }

    fn from_record(text: &str) -> Option<PointMetrics> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let (k, v) = line.split_once('=')?;
            kv.insert(k, v);
        }
        if kv.get("v") != Some(&"1") {
            return None;
        }
        let f = |k: &str| kv.get(k)?.parse::<f64>().ok();
        let u = |k: &str| kv.get(k)?.parse::<u64>().ok();
        Some(PointMetrics {
            crit_ns: f("crit_ns")?,
            fmax_mhz: f("fmax_mhz")?,
            runtime_ms: f("runtime_ms")?,
            power_mw: f("power_mw")?,
            energy_mj: f("energy_mj")?,
            edp: f("edp")?,
            pipe_regs: u("pipe_regs")?,
            util_pct: f("util_pct")?,
            cycles: u("cycles")?,
            artifact_fp: u("artifact_fp")?,
        })
    }
}

type Slot = Arc<Mutex<Option<Result<Arc<Compiled>, String>>>>;

/// Thread-safe in-memory artifact cache with in-flight deduplication,
/// plus a measured-metrics side table so duplicate points skip both the
/// compile *and* the measurement (the sparse functional simulation can
/// cost as much as the compile).
///
/// Artifacts are retained for the cache's lifetime — one per *distinct*
/// effective configuration, not per grid point. Bounded retention lives in
/// the persistent layer: [`super::artifact::ArtifactStore`] keeps compiled
/// artifacts across runs under an evictable `--cache-cap` budget.
#[derive(Default)]
pub struct ArtifactCache {
    slots: Mutex<HashMap<u64, Slot>>,
    metrics: Mutex<HashMap<u64, PointMetrics>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Ephemeral mode (`cascade serve`): artifact slots live only while a
    /// compile is in flight, so a long-running daemon's *artifact* memory
    /// is bounded by its concurrency, not by how many distinct points
    /// clients have ever requested. The measured-metrics side table (on
    /// the order of 100 bytes per distinct point) is kept in both modes —
    /// re-measuring can cost a full functional simulation.
    ephemeral: bool,
}

impl ArtifactCache {
    pub fn new() -> ArtifactCache {
        ArtifactCache::default()
    }

    /// A cache that deduplicates *in-flight* compiles but retains no
    /// compiled artifacts after they complete — waiters blocked on a slot
    /// still share its result; later callers fall through to the
    /// persistent store. Measured metrics are still retained (small, and
    /// re-measuring can cost a simulation). For long-running many-client
    /// service (the `cascade serve` daemon); sweeps want
    /// [`ArtifactCache::new`].
    pub fn ephemeral() -> ArtifactCache {
        ArtifactCache { ephemeral: true, ..ArtifactCache::default() }
    }

    /// Return the cached artifact for `key`, or run `compile` to produce
    /// it. Concurrent callers with the same key block until the first
    /// finishes and then share its result; callers with different keys
    /// proceed in parallel (only the slot-map lookup is serialized).
    pub fn get_or_compile(
        &self,
        key: u64,
        compile: impl FnOnce() -> Result<Compiled, String>,
    ) -> Result<Arc<Compiled>, String> {
        let slot = {
            let mut slots = self.slots.lock().unwrap();
            slots.entry(key).or_default().clone()
        };
        let mut guard = slot.lock().unwrap();
        if let Some(res) = &*guard {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return res.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let res = compile().map(Arc::new);
        *guard = Some(res.clone());
        if self.ephemeral {
            // Drop the map entry (and, once the waiters holding this
            // slot's Arc drain, the artifact). Anyone who grabbed the slot
            // before this removal still reads the result above; anyone
            // arriving later re-resolves through the persistent store.
            drop(guard);
            self.slots.lock().unwrap().remove(&key);
        }
        res
    }

    /// Measured metrics for `key`, if some worker already produced them.
    /// Counts as a cache hit: the caller skips compile and measurement.
    pub fn measured(&self, key: u64) -> Option<PointMetrics> {
        let m = self.metrics.lock().unwrap().get(&key).cloned();
        if m.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        m
    }

    /// [`Self::measured`] without the hit accounting — for the
    /// post-dedup recheck, where the slot already counted the hit and a
    /// scheduling-dependent probe must not perturb the (deterministic)
    /// cache statistics.
    pub fn measured_quiet(&self, key: u64) -> Option<PointMetrics> {
        self.metrics.lock().unwrap().get(&key).cloned()
    }

    /// Record the measured metrics for `key` (first writer wins; the
    /// compile is deterministic, so any writer's value is identical).
    pub fn record_measured(&self, key: u64, m: &PointMetrics) {
        self.metrics.lock().unwrap().entry(key).or_insert_with(|| m.clone());
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Persistent metrics cache: one `<key>.rec` file per point under `dir`,
/// plus the compiled-artifact store under `dir/artifacts/`.
pub struct DiskCache {
    dir: PathBuf,
    artifacts: super::artifact::ArtifactStore,
    disk_hits: AtomicUsize,
    stores: AtomicUsize,
}

impl DiskCache {
    /// Default location, shared by `cascade explore` and `cascade exp
    /// summary`.
    pub fn default_dir() -> PathBuf {
        PathBuf::from("results/explore_cache")
    }

    /// Open a cache at `dir`, creating the directory. Falls back to a
    /// load-nothing/store-nothing cache if the directory cannot be
    /// created (e.g. read-only filesystem).
    pub fn at(dir: impl AsRef<Path>) -> DiskCache {
        let dir = dir.as_ref().to_path_buf();
        let _ = std::fs::create_dir_all(&dir);
        let artifacts = super::artifact::ArtifactStore::at(dir.join("artifacts"));
        DiskCache { dir, artifacts, disk_hits: AtomicUsize::new(0), stores: AtomicUsize::new(0) }
    }

    pub fn open_default() -> DiskCache {
        DiskCache::at(DiskCache::default_dir())
    }

    /// The directory records live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The compiled-artifact store living under `dir/artifacts/`.
    pub fn artifacts(&self) -> &super::artifact::ArtifactStore {
        &self.artifacts
    }

    /// Number of metrics records currently on disk.
    pub fn record_count(&self) -> usize {
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return 0 };
        rd.filter_map(|e| e.ok())
            .filter(|e| e.path().extension().map(|x| x == "rec").unwrap_or(false))
            .count()
    }

    /// Human-readable cache summary (`cascade cache stat`): metrics
    /// records plus the artifact store's entry/byte/pin/journal counts.
    pub fn stat_string(&self) -> String {
        let s = self.artifacts.stat();
        format!(
            "cache {}: {} metrics record(s); {} artifact(s), {} byte(s), {} pinned, \
             {} journal line(s)",
            self.dir.display(),
            self.record_count(),
            s.entries,
            s.bytes,
            s.pinned,
            s.journal_lines
        )
    }

    /// Machine-readable cache summary — the one formatter behind both
    /// `cascade cache stat --json` and the serve daemon's `stat` response,
    /// so the two can never drift apart. Keys: `dir`, `metrics_records`,
    /// and an `artifacts` object with `entries` / `bytes` / `pinned` /
    /// `journal_lines`.
    pub fn stat_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let s = self.artifacts.stat();
        let mut art = Json::obj();
        art.set("entries", s.entries)
            .set("bytes", s.bytes)
            .set("pinned", s.pinned)
            .set("journal_lines", s.journal_lines);
        let mut j = Json::obj();
        j.set("dir", self.dir.display().to_string())
            .set("metrics_records", self.record_count())
            .set("artifacts", art);
        j
    }

    fn path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.rec"))
    }

    pub fn load(&self, key: u64) -> Option<PointMetrics> {
        let text = std::fs::read_to_string(self.path(key)).ok()?;
        let m = PointMetrics::from_record(&text)?;
        self.disk_hits.fetch_add(1, Ordering::Relaxed);
        Some(m)
    }

    pub fn store(&self, key: u64, m: &PointMetrics) {
        if std::fs::write(self.path(key), m.to_record()).is_ok() {
            self.stores.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn disk_hits(&self) -> usize {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// Records successfully written by this handle — what a shard manifest
    /// reports as its cache contribution.
    pub fn stores(&self) -> usize {
        self.stores.load(Ordering::Relaxed)
    }

    /// Publish this handle's counters and the artifact store's occupancy
    /// into `reg` as gauges. Scrape-time totals, not deltas: the caller
    /// (the serve daemon's `metrics` op, `--profile` reports) calls this
    /// right before rendering an exposition, so the hot paths carry no
    /// metrics bookkeeping at all. The in-memory layer's counters are
    /// published by [`super::runner::SessionCore::publish_metrics`].
    pub fn publish_metrics(&self, reg: &crate::obs::Registry) {
        reg.gauge("cache_disk_hits", "points served from the persistent metrics cache")
            .set(self.disk_hits() as u64);
        reg.gauge("cache_disk_stores", "metrics records written by this handle")
            .set(self.stores() as u64);
        reg.gauge("cache_artifact_rehydrations", "compiled artifacts rehydrated from the store")
            .set(self.artifacts.hits() as u64);
        reg.gauge("cache_artifact_rejections", "artifact loads rejected (parse or fingerprint)")
            .set(self.artifacts.rejected() as u64);
        reg.gauge("cache_artifact_stores", "compiled artifacts written by this handle")
            .set(self.artifacts.stores() as u64);
        let s = self.artifacts.stat();
        reg.gauge("cache_store_entries", "artifacts resident in the store")
            .set(s.entries as u64);
        reg.gauge("cache_store_bytes", "artifact store size in bytes").set(s.bytes);
        reg.gauge("cache_store_pinned", "artifacts pinned against eviction")
            .set(s.pinned as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{compile, CompileCtx, PipelineConfig};
    use crate::util::prop::forall;

    #[test]
    fn key_depends_on_every_knob() {
        let arch = ArchParams::paper();
        let base = PipelineConfig::full();
        let k0 = point_key("gaussian", &base, 3, "paper", &arch);
        assert_eq!(k0, point_key("gaussian", &base, 3, "paper", &arch));
        assert_ne!(k0, point_key("harris", &base, 3, "paper", &arch));
        assert_ne!(k0, point_key("gaussian", &base, 4, "paper", &arch));
        assert_ne!(k0, point_key("gaussian", &base, 3, "tiny", &arch));
        let mut alpha = base.clone();
        alpha.place_alpha = 1.5;
        assert_ne!(k0, point_key("gaussian", &alpha, 3, "paper", &arch));
        let mut effort = base.clone();
        effort.place_effort = 0.35;
        assert_ne!(k0, point_key("gaussian", &effort, 3, "paper", &arch));
        // Fusion produces a structurally different artifact — never share
        // a key with the unfused compile.
        let mut fuse = base.clone();
        fuse.fusion = true;
        assert_ne!(k0, point_key("gaussian", &fuse, 3, "paper", &arch));
        // Architecture knobs beyond the grid dimensions participate too.
        let mut rf = arch.clone();
        rf.regfile_words = 64;
        assert_ne!(k0, point_key("gaussian", &base, 3, "paper", &rf));
        let mut fifo = arch.clone();
        fifo.fifo_depth = 4;
        assert_ne!(k0, point_key("gaussian", &base, 3, "paper", &fifo));
    }

    #[test]
    fn record_round_trips_exactly() {
        let m = PointMetrics {
            crit_ns: 24.319999999999997,
            fmax_mhz: 41.118421052631575,
            runtime_ms: 0.123456789,
            power_mw: 903.0000001,
            energy_mj: 1.0 / 3.0,
            edp: 7.25e-4,
            pipe_regs: 421,
            util_pct: 93.75,
            cycles: 123456,
            artifact_fp: 0xDEADBEEF12345678,
        };
        let back = PointMetrics::from_record(&m.to_record()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn record_rejects_garbage() {
        assert!(PointMetrics::from_record("").is_none());
        assert!(PointMetrics::from_record("v=2\ncrit_ns=1.0\n").is_none());
        assert!(PointMetrics::from_record("v=1\ncrit_ns=abc\n").is_none());
    }

    #[test]
    fn disk_cache_round_trip() {
        let dir = std::env::temp_dir().join(format!("cascade-dc-{}", std::process::id()));
        let dc = DiskCache::at(&dir);
        let m = PointMetrics {
            crit_ns: 1.5,
            fmax_mhz: 666.6,
            runtime_ms: 0.25,
            power_mw: 100.0,
            energy_mj: 0.025,
            edp: 0.00625,
            pipe_regs: 7,
            util_pct: 50.0,
            cycles: 0,
            artifact_fp: 99,
        };
        assert!(dc.load(42).is_none());
        dc.store(42, &m);
        assert_eq!(dc.load(42), Some(m));
        assert_eq!(dc.disk_hits(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stat_json_reports_records_and_artifacts() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir().join(format!("cascade-statj-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dc = DiskCache::at(&dir);
        let m = PointMetrics {
            crit_ns: 1.0,
            fmax_mhz: 1.0,
            runtime_ms: 1.0,
            power_mw: 1.0,
            energy_mj: 1.0,
            edp: 1.0,
            pipe_regs: 1,
            util_pct: 1.0,
            cycles: 0,
            artifact_fp: 1,
        };
        dc.store(7, &m);
        let j = dc.stat_json();
        assert_eq!(j.get("metrics_records").and_then(Json::as_usize), Some(1));
        let art = j.get("artifacts").expect("artifacts section");
        assert_eq!(art.get("entries").and_then(Json::as_usize), Some(0));
        assert_eq!(art.get("pinned").and_then(Json::as_usize), Some(0));
        assert!(j.get("dir").and_then(Json::as_str).is_some());
        // One formatter, two consumers: the serialized form is what both
        // `cascade cache stat --json` and the serve daemon emit.
        let s = j.to_string_compact();
        assert!(s.contains("\"metrics_records\":1"), "{s}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The satellite property: the cache returns bit-identical `Compiled`
    /// artifacts to a fresh compile, and never recompiles a cached key.
    #[test]
    fn cache_returns_bit_identical_artifacts() {
        let ctx = CompileCtx::paper();
        forall("cache artifacts bit-identical", 3, |g| {
            let seed = g.int(1, 40) as u64;
            let level = *g.pick(&["none", "compute"]);
            let cfg = PipelineConfig::by_name(level).unwrap();
            let app = crate::apps::by_name_tiny("gaussian").unwrap();
            let fresh = compile(&app, &ctx, &cfg, seed).unwrap();
            let key = point_key("gaussian", &cfg, seed, "tiny", &ctx.arch);
            let cache = ArtifactCache::new();
            let first = cache
                .get_or_compile(key, || {
                    compile(&app, &ctx, &cfg, seed).map_err(|e| e.to_string())
                })
                .unwrap();
            let second = cache
                .get_or_compile(key, || panic!("cached key must not recompile"))
                .unwrap();
            assert_eq!(cache.hits(), 1);
            assert_eq!(cache.misses(), 1);
            assert_eq!(fingerprint(&fresh), fingerprint(&first));
            assert_eq!(fingerprint(&first), fingerprint(&second));
            assert_eq!(
                PointMetrics::from_compiled(&fresh),
                PointMetrics::from_compiled(&first)
            );
        });
    }
}
