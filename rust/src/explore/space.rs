//! Declarative exploration grid: [`ExploreSpec`] axis builders and
//! deterministic point enumeration.
//!
//! A spec is the cross-product of up to eight axes: five compiler axes
//! (app × pipelining level × placement `alpha` × PnR seed × post-PnR
//! iteration budget) and three architecture axes (routing tracks ×
//! register-file words × FIFO depth), as in the CGRA-PE DSE setting. Each
//! [`ExplorePoint`] resolves to one *effective* [`PipelineConfig`] — the
//! level's base configuration with the point's alpha / iteration overrides
//! applied, then `--fast` tuning folded in — plus one *effective*
//! [`ArchParams`] (the base architecture with the point's track / regfile
//! / FIFO overrides). Two points that resolve to the same effective pair
//! (e.g. every iteration budget at `level=none`, which has no post-PnR
//! pass) share one content-hash key and compile once through the artifact
//! cache; points that share an effective architecture share one compile
//! context through the runner's context cache.

use crate::arch::params::ArchParams;
use crate::experiments::common::tune;
use crate::pipeline::{PipelineConfig, PostPnrParams};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Scale at which dense applications are instantiated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Paper-scale frames (Table I dimensions).
    Paper,
    /// Small frames for unit tests and smoke runs (`--tiny`).
    Tiny,
}

impl Scale {
    pub fn tag(self) -> &'static str {
        match self {
            Scale::Paper => "paper",
            Scale::Tiny => "tiny",
        }
    }

    pub fn parse(tag: &str) -> Result<Scale, String> {
        match tag {
            "paper" => Ok(Scale::Paper),
            "tiny" => Ok(Scale::Tiny),
            _ => Err(format!("unknown scale tag '{tag}'")),
        }
    }
}

/// The exploration grid. Empty `alphas` / `iters` axes mean "use the
/// level's own default" (a single implicit point on that axis).
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreSpec {
    pub apps: Vec<String>,
    pub levels: Vec<String>,
    pub alphas: Vec<f64>,
    pub seeds: Vec<u64>,
    pub iters: Vec<usize>,
    /// Architecture axis: routing tracks per side per layer (empty = the
    /// base architecture's track count).
    pub tracks: Vec<usize>,
    /// Architecture axis: register-file words per PE tile.
    pub regwords: Vec<usize>,
    /// Architecture axis: sparse-pipelining FIFO depth.
    pub fifos: Vec<usize>,
    /// Compiler axis: op-fusion on/off (empty = the level's own default,
    /// i.e. fusion off). Participates in `config_signature`, so fused and
    /// unfused points never share a cache key.
    pub fuses: Vec<bool>,
    /// Capstone-style power cap (mW): points whose estimated total power
    /// exceeds the cap are reported but excluded from the frontier.
    pub power_cap_mw: Option<f64>,
    /// CI mode: shrink post-PnR iteration caps and placement effort.
    pub fast: bool,
    pub scale: Scale,
}

impl Default for ExploreSpec {
    fn default() -> Self {
        ExploreSpec {
            apps: vec!["gaussian".into(), "harris".into()],
            levels: vec!["none".into(), "compute".into(), "full".into()],
            alphas: Vec::new(),
            seeds: vec![3],
            iters: Vec::new(),
            tracks: Vec::new(),
            regwords: Vec::new(),
            fifos: Vec::new(),
            fuses: Vec::new(),
            power_cap_mw: None,
            fast: false,
            scale: Scale::Paper,
        }
    }
}

impl ExploreSpec {
    /// Axis builders (consuming, chainable).
    pub fn with_apps<S: Into<String>>(mut self, apps: impl IntoIterator<Item = S>) -> Self {
        self.apps = apps.into_iter().map(Into::into).collect();
        self
    }

    pub fn with_levels<S: Into<String>>(mut self, levels: impl IntoIterator<Item = S>) -> Self {
        self.levels = levels.into_iter().map(Into::into).collect();
        self
    }

    pub fn with_alphas(mut self, alphas: impl IntoIterator<Item = f64>) -> Self {
        self.alphas = alphas.into_iter().collect();
        self
    }

    pub fn with_seeds(mut self, seeds: impl IntoIterator<Item = u64>) -> Self {
        self.seeds = seeds.into_iter().collect();
        self
    }

    pub fn with_iters(mut self, iters: impl IntoIterator<Item = usize>) -> Self {
        self.iters = iters.into_iter().collect();
        self
    }

    pub fn with_tracks(mut self, tracks: impl IntoIterator<Item = usize>) -> Self {
        self.tracks = tracks.into_iter().collect();
        self
    }

    pub fn with_regwords(mut self, regwords: impl IntoIterator<Item = usize>) -> Self {
        self.regwords = regwords.into_iter().collect();
        self
    }

    pub fn with_fifos(mut self, fifos: impl IntoIterator<Item = usize>) -> Self {
        self.fifos = fifos.into_iter().collect();
        self
    }

    pub fn with_fuses(mut self, fuses: impl IntoIterator<Item = bool>) -> Self {
        self.fuses = fuses.into_iter().collect();
        self
    }

    pub fn with_power_cap(mut self, cap_mw: Option<f64>) -> Self {
        self.power_cap_mw = cap_mw;
        self
    }

    pub fn with_fast(mut self, fast: bool) -> Self {
        self.fast = fast;
        self
    }

    pub fn with_scale(mut self, scale: Scale) -> Self {
        self.scale = scale;
        self
    }

    /// Parse a spec from CLI arguments (`cascade explore ...`).
    ///
    /// Flags: `--apps a,b` `--levels l1,l2` `--alphas 1.0,1.35|sweep`
    /// `--seeds 1,2` `--iters 25,200` `--tracks 3,5` `--regwords 16,32`
    /// `--fifo 2,4` `--fuse on,off` `--power-cap MW` `--fast` `--tiny`.
    pub fn from_args(args: &Args) -> Result<ExploreSpec, String> {
        let mut spec = ExploreSpec::default();
        if let Some(s) = args.opt("apps") {
            spec.apps = split_csv(s);
        }
        if let Some(s) = args.opt("levels") {
            spec.levels = split_csv(s);
        }
        if let Some(s) = args.opt("alphas") {
            spec.alphas = if s == "sweep" {
                crate::pnr::place::ALPHA_SWEEP.to_vec()
            } else {
                parse_csv(s, "alphas")?
            };
        }
        if let Some(s) = args.opt("seeds") {
            spec.seeds = parse_csv(s, "seeds")?;
        }
        if let Some(s) = args.opt("iters") {
            spec.iters = parse_csv(s, "iters")?;
        }
        if let Some(s) = args.opt("tracks") {
            spec.tracks = parse_csv(s, "tracks")?;
        }
        if let Some(s) = args.opt("regwords") {
            spec.regwords = parse_csv(s, "regwords")?;
        }
        if let Some(s) = args.opt("fifo") {
            spec.fifos = parse_csv(s, "fifo")?;
        }
        if let Some(s) = args.opt("fuse") {
            spec.fuses = split_csv(s)
                .into_iter()
                .map(|x| match x.as_str() {
                    "on" => Ok(true),
                    "off" => Ok(false),
                    _ => Err(format!("bad --fuse entry '{x}' (use on|off)")),
                })
                .collect::<Result<Vec<bool>, String>>()?;
        }
        if let Some(s) = args.opt("power-cap") {
            let cap: f64 =
                s.parse().map_err(|_| format!("bad --power-cap value '{s}'"))?;
            spec.power_cap_mw = Some(cap);
        }
        spec.fast = args.flag("fast");
        if args.flag("tiny") {
            spec.scale = Scale::Tiny;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Check every axis value resolves to a known app / level.
    pub fn validate(&self) -> Result<(), String> {
        if self.apps.is_empty() || self.levels.is_empty() || self.seeds.is_empty() {
            return Err("explore: apps, levels and seeds must be non-empty".into());
        }
        for a in &self.apps {
            if !crate::apps::APP_NAMES.contains(&a.as_str()) {
                return Err(format!("explore: unknown app '{a}'"));
            }
        }
        for l in &self.levels {
            if PipelineConfig::by_name(l).is_none() {
                return Err(format!("explore: unknown level '{l}'"));
            }
        }
        if let Some(cap) = self.power_cap_mw {
            if !(cap > 0.0) {
                return Err(format!("explore: power cap must be positive, got {cap}"));
            }
        }
        if self.tracks.iter().any(|&t| t == 0) {
            return Err("explore: --tracks values must be >= 1".into());
        }
        if self.regwords.iter().any(|&w| w == 0) {
            return Err("explore: --regwords values must be >= 1".into());
        }
        if self.fifos.iter().any(|&f| f == 0) {
            return Err("explore: --fifo values must be >= 1".into());
        }
        Ok(())
    }

    /// Enumerate the grid in deterministic axis-major order (app → level →
    /// alpha → seed → iters → tracks → regwords → fifo → fuse). Point ids
    /// are dense indices into this order.
    pub fn points(&self) -> Vec<ExplorePoint> {
        fn axis<T: Copy>(values: &[T]) -> Vec<Option<T>> {
            if values.is_empty() {
                vec![None]
            } else {
                values.iter().copied().map(Some).collect()
            }
        }
        let alphas = axis(&self.alphas);
        let iters = axis(&self.iters);
        let tracks = axis(&self.tracks);
        let regwords = axis(&self.regwords);
        let fifos = axis(&self.fifos);
        let fuses = axis(&self.fuses);
        let mut out = Vec::new();
        for app in &self.apps {
            for level in &self.levels {
                for &alpha in &alphas {
                    for &seed in &self.seeds {
                        for &it in &iters {
                            for &t in &tracks {
                                for &rw in &regwords {
                                    for &fd in &fifos {
                                        for &fu in &fuses {
                                            out.push(ExplorePoint {
                                                id: out.len(),
                                                app: app.clone(),
                                                level: level.clone(),
                                                alpha,
                                                seed,
                                                iters: it,
                                                tracks: t,
                                                regwords: rw,
                                                fifo: fd,
                                                fuse: fu,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The spec with the post-PnR budget axis suppressed — the candidate
    /// space of the successive-halving search, which owns the budget
    /// dimension as its rung ladder.
    pub fn candidate_spec(&self) -> ExploreSpec {
        ExploreSpec { iters: Vec::new(), ..self.clone() }
    }

    /// Enumeration of [`candidate_spec`](Self::candidate_spec).
    pub fn candidates(&self) -> Vec<ExplorePoint> {
        self.candidate_spec().points()
    }

    /// Canonical JSON image of the spec: the `spec` section of the run
    /// report and the `spec` field of shard manifests. [`Self::from_json`]
    /// round-trips it exactly (floats use shortest-representation
    /// formatting), which is what lets `cascade explore-merge` re-enumerate
    /// the space a shard run evaluated.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("apps", self.apps.iter().map(|s| s.as_str().into()).collect::<Vec<Json>>())
            .set("levels", self.levels.iter().map(|s| s.as_str().into()).collect::<Vec<Json>>())
            .set("alphas", self.alphas.clone())
            .set("seeds", self.seeds.clone())
            .set("iters", self.iters.iter().map(|&i| i.into()).collect::<Vec<Json>>())
            .set("tracks", self.tracks.iter().map(|&t| t.into()).collect::<Vec<Json>>())
            .set("regwords", self.regwords.iter().map(|&w| w.into()).collect::<Vec<Json>>())
            .set("fifos", self.fifos.iter().map(|&f| f.into()).collect::<Vec<Json>>())
            .set("fuses", self.fuses.iter().map(|&b| b.into()).collect::<Vec<Json>>())
            .set("power_cap_mw", self.power_cap_mw.map_or(Json::Null, Json::from))
            .set("fast", self.fast)
            .set("scale", self.scale.tag());
        j
    }

    /// Rebuild a spec from its [`Self::to_json`] image, re-validating every
    /// axis (a manifest written by a build with different known apps or
    /// levels must fail loudly, not enumerate a different space).
    pub fn from_json(j: &Json) -> Result<ExploreSpec, String> {
        fn strings(j: &Json, key: &str) -> Result<Vec<String>, String> {
            let arr =
                j.get(key).and_then(Json::as_arr).ok_or_else(|| format!("spec: bad '{key}'"))?;
            arr.iter()
                .map(|v| {
                    v.as_str().map(String::from).ok_or_else(|| format!("spec: bad '{key}' entry"))
                })
                .collect()
        }
        fn numbers<T>(j: &Json, key: &str, conv: fn(&Json) -> Option<T>) -> Result<Vec<T>, String> {
            let arr =
                j.get(key).and_then(Json::as_arr).ok_or_else(|| format!("spec: bad '{key}'"))?;
            arr.iter().map(|v| conv(v).ok_or_else(|| format!("spec: bad '{key}' entry"))).collect()
        }
        let power_cap_mw = match j.get("power_cap_mw") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or("spec: bad 'power_cap_mw'")?),
        };
        let spec = ExploreSpec {
            apps: strings(j, "apps")?,
            levels: strings(j, "levels")?,
            alphas: numbers(j, "alphas", Json::as_f64)?,
            seeds: numbers(j, "seeds", Json::as_u64)?,
            iters: numbers(j, "iters", Json::as_usize)?,
            tracks: numbers(j, "tracks", Json::as_usize)?,
            regwords: numbers(j, "regwords", Json::as_usize)?,
            fifos: numbers(j, "fifos", Json::as_usize)?,
            // Absent in manifests written before the fusion axis existed;
            // tolerate that as "axis unset" rather than failing the load.
            fuses: match j.get("fuses") {
                None => Vec::new(),
                Some(_) => numbers(j, "fuses", Json::as_bool)?,
            },
            power_cap_mw,
            fast: j.get("fast").and_then(Json::as_bool).ok_or("spec: bad 'fast'")?,
            scale: Scale::parse(
                j.get("scale").and_then(Json::as_str).ok_or("spec: bad 'scale'")?,
            )?,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Human-readable axis summary (`2 apps x 3 levels x ...`).
    pub fn shape(&self) -> String {
        let mut s = format!(
            "{} apps x {} levels x {} alphas x {} seeds x {} budgets",
            self.apps.len(),
            self.levels.len(),
            self.alphas.len().max(1),
            self.seeds.len(),
            self.iters.len().max(1)
        );
        if !self.tracks.is_empty() {
            s.push_str(&format!(" x {} tracks", self.tracks.len()));
        }
        if !self.regwords.is_empty() {
            s.push_str(&format!(" x {} regwords", self.regwords.len()));
        }
        if !self.fifos.is_empty() {
            s.push_str(&format!(" x {} fifos", self.fifos.len()));
        }
        if !self.fuses.is_empty() {
            s.push_str(&format!(" x {} fuses", self.fuses.len()));
        }
        s
    }
}

/// One grid point. `alpha` / `iters` of `None` mean the level default;
/// `tracks` / `regwords` / `fifo` of `None` mean the base architecture.
#[derive(Debug, Clone, PartialEq)]
pub struct ExplorePoint {
    pub id: usize,
    pub app: String,
    pub level: String,
    pub alpha: Option<f64>,
    pub seed: u64,
    pub iters: Option<usize>,
    pub tracks: Option<usize>,
    pub regwords: Option<usize>,
    pub fifo: Option<usize>,
    /// Op-fusion override (`None` = the level default, fusion off).
    pub fuse: Option<bool>,
}

impl ExplorePoint {
    /// Resolve the point to its effective pipeline configuration: level
    /// base + alpha / iteration-budget overrides + `fast` tuning. The
    /// result is what actually compiles and what the cache key hashes.
    pub fn config(&self, fast: bool) -> PipelineConfig {
        let mut cfg = PipelineConfig::by_name(&self.level)
            .unwrap_or_else(|| panic!("unvalidated level '{}'", self.level));
        if let Some(a) = self.alpha {
            cfg.place_alpha = a;
        }
        if let Some(it) = self.iters {
            if let Some(p) = &mut cfg.postpnr {
                *p = PostPnrParams { max_iters: it, ..p.clone() };
            }
        }
        if let Some(f) = self.fuse {
            cfg.fusion = f;
        }
        tune(&cfg, fast)
    }

    /// Resolve the point's effective architecture: the base parameters
    /// with the track / regfile-word / FIFO-depth overrides applied. The
    /// runner builds (and memoizes) one compile context per distinct
    /// effective architecture.
    pub fn arch(&self, base: &ArchParams) -> ArchParams {
        let mut a = base.clone();
        if let Some(t) = self.tracks {
            a.tracks = t;
        }
        if let Some(w) = self.regwords {
            a.regfile_words = w;
        }
        if let Some(d) = self.fifo {
            a.fifo_depth = d;
        }
        a
    }

    /// Whether the point deviates from the base architecture (and so needs
    /// its own compile context).
    pub fn has_arch_overrides(&self) -> bool {
        self.tracks.is_some() || self.regwords.is_some() || self.fifo.is_some()
    }

    /// The same point with a different post-PnR iteration budget — how the
    /// successive-halving search promotes a candidate to the next rung.
    pub fn at_budget(&self, iters: usize) -> ExplorePoint {
        ExplorePoint { iters: Some(iters), ..self.clone() }
    }

    /// Compact display label.
    pub fn label(&self) -> String {
        let mut s = format!("{}/{}", self.app, self.level);
        if let Some(a) = self.alpha {
            s.push_str(&format!(" a={a}"));
        }
        s.push_str(&format!(" s={}", self.seed));
        if let Some(it) = self.iters {
            s.push_str(&format!(" it={it}"));
        }
        if let Some(t) = self.tracks {
            s.push_str(&format!(" t={t}"));
        }
        if let Some(w) = self.regwords {
            s.push_str(&format!(" rw={w}"));
        }
        if let Some(d) = self.fifo {
            s.push_str(&format!(" fd={d}"));
        }
        if let Some(f) = self.fuse {
            s.push_str(if f { " fuse=on" } else { " fuse=off" });
        }
        s
    }
}

fn split_csv(s: &str) -> Vec<String> {
    s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
}

fn parse_csv<T: std::str::FromStr>(s: &str, what: &str) -> Result<Vec<T>, String> {
    split_csv(s)
        .into_iter()
        .map(|x| x.parse().map_err(|_| format!("bad --{what} entry '{x}'")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn enumeration_is_dense_and_ordered() {
        let spec = ExploreSpec::default()
            .with_apps(["gaussian"])
            .with_levels(["none", "compute"])
            .with_seeds([1, 2]);
        let pts = spec.points();
        assert_eq!(pts.len(), 4);
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(p.id, i);
        }
        assert_eq!(pts[0].level, "none");
        assert_eq!(pts[0].seed, 1);
        assert_eq!(pts[1].seed, 2);
        assert_eq!(pts[2].level, "compute");
    }

    #[test]
    fn from_args_parses_all_axes() {
        let a = args(
            "explore --apps gaussian,harris --levels none,full --alphas 1.0,1.35 \
             --seeds 1,2 --iters 25 --power-cap 500 --fast",
        );
        let spec = ExploreSpec::from_args(&a).unwrap();
        assert_eq!(spec.apps, vec!["gaussian", "harris"]);
        assert_eq!(spec.levels, vec!["none", "full"]);
        assert_eq!(spec.alphas, vec![1.0, 1.35]);
        assert_eq!(spec.seeds, vec![1, 2]);
        assert_eq!(spec.iters, vec![25]);
        assert_eq!(spec.power_cap_mw, Some(500.0));
        assert!(spec.fast);
        assert_eq!(spec.points().len(), 2 * 2 * 2 * 2);
    }

    #[test]
    fn from_args_rejects_unknown_axis_values() {
        assert!(ExploreSpec::from_args(&args("explore --apps nope")).is_err());
        assert!(ExploreSpec::from_args(&args("explore --levels nope")).is_err());
        assert!(ExploreSpec::from_args(&args("explore --alphas abc")).is_err());
        assert!(ExploreSpec::from_args(&args("explore --power-cap -5")).is_err());
    }

    #[test]
    fn alpha_sweep_keyword_expands() {
        let spec = ExploreSpec::from_args(&args("explore --alphas sweep")).unwrap();
        assert_eq!(spec.alphas, crate::pnr::place::ALPHA_SWEEP.to_vec());
    }

    #[test]
    fn arch_axes_enumerate_and_resolve() {
        let spec = ExploreSpec::default()
            .with_apps(["gaussian"])
            .with_levels(["full"])
            .with_seeds([1])
            .with_tracks([3, 5])
            .with_regwords([16])
            .with_fifos([2, 4]);
        let pts = spec.points();
        assert_eq!(pts.len(), 4);
        let base = ArchParams::paper();
        let a0 = pts[0].arch(&base);
        assert_eq!(a0.tracks, 3);
        assert_eq!(a0.regfile_words, 16);
        assert_eq!(a0.fifo_depth, 2);
        let a3 = pts[3].arch(&base);
        assert_eq!(a3.tracks, 5);
        assert_eq!(a3.fifo_depth, 4);
        assert!(pts.iter().all(|p| p.has_arch_overrides()));
        // No overrides: the base architecture passes through untouched.
        let plain = ExploreSpec::default().points();
        assert!(!plain[0].has_arch_overrides());
        assert_eq!(plain[0].arch(&base).tracks, base.tracks);
        assert!(spec.shape().contains("2 tracks"));
        assert!(spec.shape().contains("2 fifos"));
    }

    #[test]
    fn from_args_parses_arch_axes_and_rejects_zero() {
        let spec = ExploreSpec::from_args(&args(
            "explore --tracks 3,5 --regwords 16,32 --fifo 4",
        ))
        .unwrap();
        assert_eq!(spec.tracks, vec![3, 5]);
        assert_eq!(spec.regwords, vec![16, 32]);
        assert_eq!(spec.fifos, vec![4]);
        assert!(ExploreSpec::from_args(&args("explore --tracks 0")).is_err());
        assert!(ExploreSpec::from_args(&args("explore --regwords 0")).is_err());
        assert!(ExploreSpec::from_args(&args("explore --fifo 0")).is_err());
    }

    #[test]
    fn fuse_axis_parses_enumerates_and_resolves() {
        let spec =
            ExploreSpec::from_args(&args("explore --apps gaussian --levels full --fuse on,off"))
                .unwrap();
        assert_eq!(spec.fuses, vec![true, false]);
        let pts = spec.points();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].fuse, Some(true));
        assert_eq!(pts[1].fuse, Some(false));
        assert!(pts[0].config(false).fusion);
        assert!(!pts[1].config(false).fusion);
        assert!(pts[0].label().contains("fuse=on"));
        assert!(pts[1].label().contains("fuse=off"));
        assert!(spec.shape().contains("2 fuses"));
        // The axis default leaves fusion off (the level default).
        let plain = ExploreSpec::default().points();
        assert_eq!(plain[0].fuse, None);
        assert!(!plain[0].config(false).fusion);
        // Bad values are rejected at parse time.
        assert!(ExploreSpec::from_args(&args("explore --fuse yes")).is_err());
        // A spec with the axis set has a different JSON image — the shard
        // manifest fingerprint covers it (mixed-fusion merges abort).
        let without = ExploreSpec::default();
        let with = ExploreSpec::default().with_fuses([true]);
        assert_ne!(with.to_json().to_string_compact(), without.to_json().to_string_compact());
        // Manifests written before the axis existed still load (axis unset).
        let mut old = without.to_json();
        if let Json::Obj(m) = &mut old {
            m.remove("fuses");
        }
        assert_eq!(ExploreSpec::from_json(&old).unwrap().fuses, Vec::<bool>::new());
    }

    #[test]
    fn candidates_suppress_budget_axis() {
        let spec = ExploreSpec::default()
            .with_apps(["gaussian"])
            .with_levels(["none", "full"])
            .with_seeds([1])
            .with_iters([10, 50, 200]);
        assert_eq!(spec.points().len(), 6);
        let cands = spec.candidates();
        assert_eq!(cands.len(), 2);
        assert!(cands.iter().all(|c| c.iters.is_none()));
        // Promotion rebinds only the budget.
        let p = cands[1].at_budget(50);
        assert_eq!(p.iters, Some(50));
        assert_eq!(p.level, cands[1].level);
        assert_eq!(p.id, cands[1].id);
    }

    #[test]
    fn spec_json_round_trips_exactly() {
        let spec = ExploreSpec::default()
            .with_apps(["gaussian", "harris"])
            .with_levels(["none", "full"])
            .with_alphas([1.0, 1.35])
            .with_seeds([1, 2])
            .with_iters([25, 200])
            .with_tracks([3, 5])
            .with_regwords([16])
            .with_fifos([2, 4])
            .with_fuses([true, false])
            .with_power_cap(Some(450.5))
            .with_fast(true)
            .with_scale(Scale::Tiny);
        let j = spec.to_json();
        let back = ExploreSpec::from_json(&j).unwrap();
        assert_eq!(back.to_json(), j, "spec JSON must round-trip exactly");
        assert_eq!(back.apps, spec.apps);
        assert_eq!(back.alphas, spec.alphas);
        assert_eq!(back.power_cap_mw, spec.power_cap_mw);
        assert_eq!(back.scale, spec.scale);
        // Through text too (the path a shard manifest actually takes).
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(ExploreSpec::from_json(&parsed).unwrap().to_json(), j);
        // And the defaults (null power cap, empty axes).
        let d = ExploreSpec::default();
        assert_eq!(ExploreSpec::from_json(&d.to_json()).unwrap().to_json(), d.to_json());
    }

    #[test]
    fn spec_from_json_rejects_drift() {
        let mut bad_app = ExploreSpec::default().to_json();
        bad_app.set("apps", vec![Json::from("nope")]);
        assert!(ExploreSpec::from_json(&bad_app).is_err());
        let mut missing = ExploreSpec::default().to_json();
        missing.set("fast", Json::Null);
        assert!(ExploreSpec::from_json(&missing).is_err());
        assert!(ExploreSpec::from_json(&Json::Null).is_err());
        assert!(Scale::parse("huge").is_err());
    }

    #[test]
    fn overrides_fold_into_effective_config() {
        let p = ExplorePoint {
            id: 0,
            app: "gaussian".into(),
            level: "full".into(),
            alpha: Some(1.5),
            seed: 1,
            iters: Some(50),
            tracks: None,
            regwords: None,
            fifo: None,
            fuse: None,
        };
        let cfg = p.config(false);
        assert_eq!(cfg.place_alpha, 1.5);
        assert_eq!(cfg.postpnr.as_ref().unwrap().max_iters, 50);
        // `none` ignores the iteration budget: same effective config for
        // any budget (the cache will collapse these points).
        let n1 = ExplorePoint { level: "none".into(), iters: Some(10), ..p.clone() };
        let n2 = ExplorePoint { level: "none".into(), iters: Some(99), ..p };
        assert!(n1.config(false).postpnr.is_none());
        assert!(n2.config(false).postpnr.is_none());
    }
}
