//! Sharded exploration: distribute one `cascade explore` space across
//! processes or machines, then reassemble the exact single-process report.
//!
//! The coordination substrate is what the explore engine already has — the
//! content-hash disk cache and the append-only partial log — plus one new
//! artifact, the **shard manifest** (`results/shard_K_of_N.json`):
//!
//! * `cascade explore --shard K/N` partitions the final point set by
//!   effective cache key ([`super::runner::effective_key`] modulo `N`).
//!   The key is independent of `N`, so runs sharded with different counts
//!   still deduplicate through the cache. The shard evaluates only its
//!   slice through the normal [`EvalSession`], stores metrics in its own
//!   `explore_cache/`, streams shard-tagged partial lines, and writes a
//!   manifest: spec image + fingerprint, owned point ids/keys (with
//!   compile errors inline), cache records written, and this run's span of
//!   the partial log.
//! * For `--search halving`, every shard deterministically replays the
//!   cheap lower rungs over the *full* candidate set — successive halving
//!   made those rungs cheap on purpose — so survivor selection is a
//!   bit-identical replica of the single-process search on every shard
//!   with no cross-process traffic. Only the expensive top rung is
//!   partitioned. Manifests record the global trajectory and survivor set;
//!   the merge refuses to combine shards that disagree.
//! * `cascade explore-merge <dir>...` loads every manifest, validates the
//!   cohort (single fingerprint and shard count, every shard present, no
//!   conflicting or overlapping claims — duplicate re-submissions of the
//!   same shard are deduplicated, not double-counted), unions the
//!   `explore_cache/` directories, concatenates the partial logs, rebuilds
//!   the full result vector from the merged cache, and emits
//!   `results/explore.{md,json}` through the same
//!   [`super::report::render_report`] path as an unsharded run — the
//!   merged report is byte-identical to the single-process one. The union
//!   covers the compiled-artifact store too (`explore_cache/artifacts/`),
//!   so downstream consumers of the merged directory (`cascade encode
//!   --from-cache`, simulation) rehydrate any shard's surviving artifact
//!   without recompiling (a shard-local `--cache-cap` GC runs unpinned
//!   and may have evicted some — those recompile on next use).
//!
//! The partition itself is plain arithmetic over the effective cache key:
//!
//! ```
//! use cascade::explore::shard::{owner_of, ShardSpec};
//!
//! let sh = ShardSpec::parse("2/3").unwrap();
//! assert_eq!((sh.index, sh.count), (2, 3));
//! assert_eq!(sh.manifest_name(), "shard_2_of_3.json");
//!
//! // Every key has exactly one owner — the partition is total and
//! // disjoint, so coverage gaps and overlaps are detectable.
//! let key = 0xdead_beef_u64;
//! let owners: Vec<usize> =
//!     (1..=3).filter(|&k| ShardSpec { index: k, count: 3 }.owns(key)).collect();
//! assert_eq!(owners, vec![owner_of(key, 3)]);
//! ```

use std::collections::btree_map::Entry;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use crate::arch::params::ArchParams;
use crate::pipeline::CompileCtx;
use crate::util::json::Json;

use super::cache::{fnv1a, DiskCache};
use super::runner::{effective_key, CacheStats, EvalSession, PartialSink, PointResult};
use super::search::{self, HalvingParams, Objective, RungReport};
use super::space::{ExplorePoint, ExploreSpec};
use super::SearchKind;

/// One shard of an `N`-way partition, `--shard K/N` on the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// 1-based shard index (`K`).
    pub index: usize,
    /// Total shard count (`N`).
    pub count: usize,
}

impl ShardSpec {
    /// Parse the CLI form `K/N`.
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let (k, n) =
            s.split_once('/').ok_or_else(|| format!("bad --shard '{s}' (expected K/N)"))?;
        let index: usize =
            k.trim().parse().map_err(|_| format!("bad --shard index '{k}' in '{s}'"))?;
        let count: usize =
            n.trim().parse().map_err(|_| format!("bad --shard count '{n}' in '{s}'"))?;
        let spec = ShardSpec { index, count };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.count == 0 {
            return Err("shard: count must be >= 1".into());
        }
        if self.index == 0 || self.index > self.count {
            return Err(format!("shard: index must be in 1..={}, got {}", self.count, self.index));
        }
        Ok(())
    }

    /// Deterministic ownership: effective cache key modulo shard count.
    pub fn owns(&self, key: u64) -> bool {
        owner_of(key, self.count) == self.index
    }

    /// Display / partial-log tag, `"K/N"`.
    pub fn tag(&self) -> String {
        format!("{}/{}", self.index, self.count)
    }

    /// Manifest file name, `shard_K_of_N.json`.
    pub fn manifest_name(&self) -> String {
        format!("shard_{}_of_{}.json", self.index, self.count)
    }
}

/// The 1-based shard index that owns `key` under an `n`-way partition.
pub fn owner_of(key: u64, n: usize) -> usize {
    (key % n.max(1) as u64) as usize + 1
}

/// Compatibility fingerprint of (crate version, spec, search strategy) —
/// the token every manifest carries and the merge matches before combining
/// anything. Axis drift and different search knobs always change it;
/// version detection is only as fine-grained as `CARGO_PKG_VERSION`, the
/// same policy the metrics cache uses — a compiler-pass change that is not
/// accompanied by a version bump is invisible to both, so bump the version
/// in `Cargo.toml` whenever compiled artifacts or metrics change.
pub fn spec_fingerprint(spec: &ExploreSpec, search: &SearchKind) -> String {
    let search_tag = match search {
        SearchKind::Grid => "grid".to_string(),
        SearchKind::Halving(p) => {
            format!("halving:eta={};min={};obj={}", p.eta, p.min_budget, p.objective.tag())
        }
    };
    let s = format!(
        "ver={};spec={};search={search_tag}",
        env!("CARGO_PKG_VERSION"),
        spec.to_json().to_string_compact()
    );
    format!("{:016x}", fnv1a(s.as_bytes()))
}

/// One owned final point as recorded in a manifest: its id in the final
/// enumeration, its effective cache key (hex in JSON — u64 keys do not
/// survive f64 number encoding), and the compile error if it failed
/// (successful points live in the shard's `explore_cache/`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestPoint {
    pub id: usize,
    pub key: u64,
    pub error: Option<String>,
}

/// Self-describing record of one shard run (`results/shard_K_of_N.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub shard: usize,
    pub of: usize,
    pub fingerprint: String,
    pub spec: ExploreSpec,
    pub search: SearchKind,
    /// This shard's owned slice of the final point set.
    pub points: Vec<ManifestPoint>,
    /// Global final point count (grid: the full enumeration; halving: the
    /// top-rung survivor count) — what full coverage must add up to.
    pub points_total: usize,
    /// Halving: the global survivor ids in report order (`None` for grid).
    pub survivor_ids: Option<Vec<usize>>,
    /// Halving: the global rung trajectory (`None` for grid).
    pub rungs: Option<Vec<RungReport>>,
    /// Cache records this run wrote.
    pub cache_stores: usize,
    /// This run's span of the shard-local partial log.
    pub log_start: usize,
    pub log_lines: usize,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("format", 1u64)
            .set("shard", self.shard)
            .set("of", self.of)
            .set("fingerprint", self.fingerprint.as_str())
            .set("spec", self.spec.to_json())
            .set("points_total", self.points_total)
            .set("cache_stores", self.cache_stores);
        let mut search = Json::obj();
        match &self.search {
            SearchKind::Grid => {
                search.set("mode", "grid");
            }
            SearchKind::Halving(p) => {
                search
                    .set("mode", "halving")
                    .set("eta", p.eta)
                    .set("min_budget", p.min_budget)
                    .set("objective", p.objective.tag());
            }
        }
        j.set("search", search);
        let mut pts = Json::Arr(vec![]);
        for p in &self.points {
            let mut o = Json::obj();
            o.set("id", p.id)
                .set("key", format!("{:016x}", p.key))
                .set("error", p.error.as_deref().map_or(Json::Null, Json::from));
            pts.push(o);
        }
        j.set("points", pts);
        if let Some(ids) = &self.survivor_ids {
            j.set("survivor_ids", ids.iter().map(|&i| i.into()).collect::<Vec<Json>>());
        }
        if let Some(rungs) = &self.rungs {
            let mut jr = Json::Arr(vec![]);
            for r in rungs {
                let mut o = Json::obj();
                o.set("rung", r.rung)
                    .set("budget", r.budget)
                    .set("evaluated", r.evaluated)
                    .set("kept", r.kept);
                jr.push(o);
            }
            j.set("rungs", jr);
        }
        let mut log = Json::obj();
        log.set("file", "explore_partial.jsonl")
            .set("start", self.log_start)
            .set("lines", self.log_lines);
        j.set("partial_log", log);
        j
    }

    pub fn from_json(j: &Json) -> Result<Manifest, String> {
        fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
            j.get(key).and_then(Json::as_usize).ok_or_else(|| format!("manifest: bad '{key}'"))
        }
        let format = req_usize(j, "format")?;
        if format != 1 {
            return Err(format!("manifest: unsupported format {format}"));
        }
        let spec = ExploreSpec::from_json(j.get("spec").ok_or("manifest: missing 'spec'")?)?;
        let jsearch = j.get("search").ok_or("manifest: missing 'search'")?;
        let search = match jsearch.get("mode").and_then(Json::as_str) {
            Some("grid") => SearchKind::Grid,
            Some("halving") => SearchKind::Halving(HalvingParams {
                eta: req_usize(jsearch, "eta")?,
                min_budget: req_usize(jsearch, "min_budget")?,
                objective: Objective::parse(
                    jsearch.get("objective").and_then(Json::as_str).unwrap_or(""),
                )?,
            }),
            _ => return Err("manifest: bad search mode".into()),
        };
        let jpoints = j.get("points").and_then(Json::as_arr).ok_or("manifest: bad 'points'")?;
        let mut points = Vec::with_capacity(jpoints.len());
        for o in jpoints {
            let key_hex = o.get("key").and_then(Json::as_str).ok_or("manifest: bad point 'key'")?;
            let key = u64::from_str_radix(key_hex, 16)
                .map_err(|_| format!("manifest: bad point key '{key_hex}'"))?;
            let error = match o.get("error") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_str().ok_or("manifest: bad point 'error'")?.to_string()),
            };
            points.push(ManifestPoint { id: req_usize(o, "id")?, key, error });
        }
        let survivor_ids = match j.get("survivor_ids") {
            None => None,
            Some(v) => {
                let arr = v.as_arr().ok_or("manifest: bad 'survivor_ids'")?;
                Some(
                    arr.iter()
                        .map(|x| x.as_usize().ok_or("manifest: bad survivor id".to_string()))
                        .collect::<Result<Vec<usize>, String>>()?,
                )
            }
        };
        let rungs = match j.get("rungs") {
            None => None,
            Some(v) => {
                let arr = v.as_arr().ok_or("manifest: bad 'rungs'")?;
                let mut out = Vec::with_capacity(arr.len());
                for o in arr {
                    out.push(RungReport {
                        rung: req_usize(o, "rung")?,
                        budget: req_usize(o, "budget")?,
                        evaluated: req_usize(o, "evaluated")?,
                        kept: req_usize(o, "kept")?,
                    });
                }
                Some(out)
            }
        };
        let jlog = j.get("partial_log").ok_or("manifest: missing 'partial_log'")?;
        let m = Manifest {
            shard: req_usize(j, "shard")?,
            of: req_usize(j, "of")?,
            fingerprint: j
                .get("fingerprint")
                .and_then(Json::as_str)
                .ok_or("manifest: bad 'fingerprint'")?
                .to_string(),
            spec,
            search,
            points,
            points_total: req_usize(j, "points_total")?,
            survivor_ids,
            rungs,
            cache_stores: req_usize(j, "cache_stores")?,
            log_start: req_usize(jlog, "start")?,
            log_lines: req_usize(jlog, "lines")?,
        };
        ShardSpec { index: m.shard, count: m.of }.validate()?;
        Ok(m)
    }

    /// Write `shard_K_of_N.json` under `dir`.
    pub fn write(&self, dir: &Path) -> Result<PathBuf, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("shard: cannot create {}: {e}", dir.display()))?;
        let path = dir.join(ShardSpec { index: self.shard, count: self.of }.manifest_name());
        std::fs::write(&path, self.to_json().to_string_pretty())
            .map_err(|e| format!("shard: cannot write {}: {e}", path.display()))?;
        Ok(path)
    }

    pub fn load(path: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("manifest {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("manifest {}: {e}", path.display()))?;
        Manifest::from_json(&j).map_err(|e| format!("manifest {}: {e}", path.display()))
    }

    /// Whether `other` is a benign re-submission of the same shard work
    /// (e.g. a retried CI job): identical claims, possibly different local
    /// bookkeeping (log span, cache-store count).
    fn same_claims(&self, other: &Manifest) -> bool {
        self.points == other.points
            && self.points_total == other.points_total
            && self.survivor_ids == other.survivor_ids
            && self.rungs == other.rungs
    }
}

/// Outcome of one shard run: the manifest (already on disk) plus cache
/// traffic.
#[derive(Debug)]
pub struct ShardOutcome {
    pub manifest: Manifest,
    pub manifest_path: PathBuf,
    pub stats: CacheStats,
}

/// Evaluate this shard's slice of the space and write its manifest, cache
/// records and shard-tagged partial log under `out_dir` (the CLI passes
/// `results/`). The disk cache is mandatory here: merged metrics are
/// reconstructed from `explore_cache/`, so a shard whose successful points
/// are not on disk would be unmergeable — it fails loudly instead.
pub fn run_sharded(
    spec: &ExploreSpec,
    ctx: &CompileCtx,
    threads: usize,
    search: &SearchKind,
    shard: &ShardSpec,
    out_dir: &Path,
) -> Result<ShardOutcome, String> {
    spec.validate()?;
    shard.validate()?;
    let threads = threads.max(1);
    std::fs::create_dir_all(out_dir)
        .map_err(|e| format!("shard: cannot create {}: {e}", out_dir.display()))?;
    let disk = DiskCache::at(out_dir.join("explore_cache"));
    let sink = PartialSink::open_tagged(out_dir.join("explore_partial.jsonl"), Some(shard.tag()));
    let log_start = sink.start_line();

    let (owned_results, points_total, survivor_ids, rungs, stats) = match search {
        SearchKind::Grid => {
            let all = spec.points();
            let owned: Vec<ExplorePoint> = all
                .iter()
                .filter(|p| shard.owns(effective_key(spec, &ctx.arch, p)))
                .cloned()
                .collect();
            println!(
                "explore: shard {} of grid, {} of {} point(s) owned ({}) on {} thread(s)...",
                shard.tag(),
                owned.len(),
                all.len(),
                spec.shape(),
                threads
            );
            let session = EvalSession::new(spec, ctx, Some(&disk), Some(&sink));
            let results = session.eval_points(&owned, threads, None);
            let stats = session.stats();
            (results, all.len(), None, None, stats)
        }
        SearchKind::Halving(params) => {
            println!(
                "explore: shard {} of halving (eta {}, objective {}): {} candidate(s) ({}) \
                 on {} thread(s)...",
                shard.tag(),
                params.eta,
                params.objective.tag(),
                spec.candidates().len(),
                spec.candidate_spec().shape(),
                threads
            );
            let out = search::run_halving(
                spec,
                ctx,
                threads,
                Some(&disk),
                Some(&sink),
                params,
                Some(shard),
            )?;
            let ids: Vec<usize> = out.survivors.iter().map(|p| p.id).collect();
            let total = out.survivors.len();
            (out.results, total, Some(ids), Some(out.rungs), out.stats)
        }
    };

    let points: Vec<ManifestPoint> = owned_results
        .iter()
        .map(|r| ManifestPoint {
            id: r.point.id,
            key: effective_key(spec, &ctx.arch, &r.point),
            error: r.metrics.as_ref().err().cloned(),
        })
        .collect();
    for p in &points {
        if p.error.is_none() && disk.load(p.key).is_none() {
            return Err(format!(
                "shard: cache record missing for point {} (key {:016x}) — cannot write a \
                 mergeable manifest",
                p.id, p.key
            ));
        }
    }

    let manifest = Manifest {
        shard: shard.index,
        of: shard.count,
        fingerprint: spec_fingerprint(spec, search),
        spec: spec.clone(),
        search: search.clone(),
        points,
        points_total,
        survivor_ids,
        rungs,
        cache_stores: disk.stores(),
        log_start,
        log_lines: sink.written(),
    };
    let manifest_path = manifest.write(out_dir)?;
    let stale = clear_foreign_manifests(out_dir, &manifest);
    if stale > 0 {
        println!(
            "shard: removed {stale} stale manifest(s) from other runs (different spec or \
             shard count) so they cannot poison a later explore-merge"
        );
    }
    println!(
        "shard {}: {} owned point(s) of {}, {} cache record(s) written, manifest {}",
        shard.tag(),
        manifest.points.len(),
        manifest.points_total,
        manifest.cache_stores,
        manifest_path.display()
    );
    println!(
        "cache: {} hit(s) ({} in-memory, {} disk metrics, {} rehydrated artifact(s)), \
         {} compile(s), {} extra context(s)",
        stats.total_hits(),
        stats.memory_hits,
        stats.disk_hits,
        stats.art_hits,
        stats.misses,
        stats.ctx_builds
    );
    if sink.dropped() > 0 {
        println!(
            "partial results: INCOMPLETE — {} record(s) dropped ({})",
            sink.dropped(),
            sink.path().display()
        );
    } else {
        println!("partial results: {} (shard-tagged)", sink.path().display());
    }
    Ok(ShardOutcome { manifest, manifest_path, stats })
}

/// A merged multi-shard run, ready for the shared report path.
#[derive(Debug)]
pub struct MergeOutcome {
    pub spec: ExploreSpec,
    pub search: SearchKind,
    /// Full final result vector in single-process report order.
    pub results: Vec<PointResult>,
    /// Halving: knobs + global trajectory for the report's search section.
    pub trajectory: Option<(HalvingParams, Vec<RungReport>)>,
    /// Distinct shards merged.
    pub shards: usize,
    /// Cache records copied into the merged `explore_cache/`.
    pub cache_copied: usize,
    /// Compiled artifacts copied into the merged `explore_cache/artifacts/`.
    pub artifacts_copied: usize,
    /// Partial-log lines appended to the merged journal.
    pub log_lines: usize,
}

/// Merge shard directories into `out_dir`: validate the manifest cohort,
/// union the caches, concatenate the partial logs, and rebuild the full
/// result vector. `base` must be the architecture the shards compiled
/// against (the CLI always uses the paper array).
pub fn merge(
    dirs: &[PathBuf],
    base: &ArchParams,
    out_dir: &Path,
) -> Result<MergeOutcome, String> {
    if dirs.is_empty() {
        return Err("explore-merge: at least one shard directory required".into());
    }
    // Visit each directory once even if listed twice.
    let mut unique_dirs: Vec<PathBuf> = Vec::new();
    for d in dirs {
        let canon = d.canonicalize().unwrap_or_else(|_| d.clone());
        if !unique_dirs.contains(&canon) {
            unique_dirs.push(canon);
        }
    }

    // 1. Collect manifests.
    let mut manifests: Vec<(PathBuf, Manifest)> = Vec::new();
    for dir in &unique_dirs {
        let rd = std::fs::read_dir(dir)
            .map_err(|e| format!("explore-merge: cannot read {}: {e}", dir.display()))?;
        let mut paths: Vec<PathBuf> = rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("shard_") && n.ends_with(".json"))
                    .unwrap_or(false)
            })
            .collect();
        paths.sort();
        if paths.is_empty() {
            return Err(format!(
                "explore-merge: no shard manifest (shard_*.json) in {}",
                dir.display()
            ));
        }
        for path in paths {
            let m = Manifest::load(&path)?;
            manifests.push((path, m));
        }
    }

    // 2. Validate the cohort: one fingerprint, one shard count, every
    // shard present exactly once (duplicate re-submissions deduplicated).
    let (first_path, first) = &manifests[0];
    let n = first.of;
    let fingerprint = first.fingerprint.clone();
    // Winning manifest per shard index, with the directory it came from
    // (so deduplicated re-submissions contribute neither results nor
    // journal/cache content twice).
    let mut by_index: BTreeMap<usize, (&Path, &Manifest)> = BTreeMap::new();
    for (path, m) in &manifests {
        if m.fingerprint != fingerprint {
            return Err(format!(
                "explore-merge: spec drift — {} has fingerprint {} but {} has {}",
                path.display(),
                m.fingerprint,
                first_path.display(),
                fingerprint
            ));
        }
        if m.of != n {
            return Err(format!(
                "explore-merge: shard-count mismatch — {} says {} shard(s), {} says {}",
                path.display(),
                m.of,
                first_path.display(),
                n
            ));
        }
        match by_index.entry(m.shard) {
            Entry::Vacant(v) => {
                v.insert((path.parent().unwrap_or_else(|| Path::new(".")), m));
            }
            Entry::Occupied(o) => {
                if !o.get().1.same_claims(m) {
                    return Err(format!(
                        "explore-merge: conflicting manifests for shard {}/{n} ({})",
                        m.shard,
                        path.display()
                    ));
                }
                // Identical claims: an overlapping re-submission, dedupe.
            }
        }
    }
    for k in 1..=n {
        if !by_index.contains_key(&k) {
            return Err(format!("explore-merge: coverage gap — shard {k}/{n} missing"));
        }
    }
    let canonical = by_index[&1].1;
    let spec = canonical.spec.clone();
    let search = canonical.search.clone();
    if spec_fingerprint(&spec, &search) != fingerprint {
        return Err(
            "explore-merge: manifest fingerprint does not match its own spec (version skew \
             between the shard writer and this binary?)"
                .into(),
        );
    }

    // 3. The expected final point set, in single-process report order.
    let expected: Vec<ExplorePoint> = match &search {
        SearchKind::Grid => spec.points(),
        SearchKind::Halving(_) => {
            for (k, (_, m)) in &by_index {
                if m.survivor_ids != canonical.survivor_ids || m.rungs != canonical.rungs {
                    return Err(format!(
                        "explore-merge: shard {k}/{n} disagrees on the survivor set or rung \
                         trajectory (non-deterministic shard runs?)"
                    ));
                }
            }
            let ids = canonical
                .survivor_ids
                .as_ref()
                .ok_or("explore-merge: halving manifest missing survivor_ids")?;
            let rungs = canonical
                .rungs
                .as_ref()
                .ok_or("explore-merge: halving manifest missing rungs")?;
            let final_budget = rungs.last().ok_or("explore-merge: empty rung trajectory")?.budget;
            let candidates = spec.candidates();
            let mut pts = Vec::with_capacity(ids.len());
            for &id in ids {
                let c = candidates
                    .get(id)
                    .ok_or_else(|| format!("explore-merge: survivor id {id} out of range"))?;
                pts.push(c.at_budget(final_budget));
            }
            pts
        }
    };
    for (k, (_, m)) in &by_index {
        if m.points_total != expected.len() {
            return Err(format!(
                "explore-merge: shard {k}/{n} reports {} total point(s), expected {}",
                m.points_total,
                expected.len()
            ));
        }
    }

    // 4. Claim every expected point exactly once, validating keys and the
    // partition (a point must be reported by the shard that owns it).
    let keys: Vec<u64> = expected.iter().map(|p| effective_key(&spec, base, p)).collect();
    let id_pos: HashMap<usize, usize> =
        expected.iter().enumerate().map(|(i, p)| (p.id, i)).collect();
    let mut claimed: Vec<Option<&ManifestPoint>> = vec![None; expected.len()];
    for (k, (_, m)) in &by_index {
        for mp in &m.points {
            let pos = *id_pos.get(&mp.id).ok_or_else(|| {
                format!("explore-merge: shard {k}/{n} claims unknown point id {}", mp.id)
            })?;
            if keys[pos] != mp.key {
                return Err(format!(
                    "explore-merge: key mismatch for point id {} (manifest {:016x}, \
                     recomputed {:016x})",
                    mp.id, mp.key, keys[pos]
                ));
            }
            let owner = owner_of(mp.key, n);
            if owner != *k {
                return Err(format!(
                    "explore-merge: overlap — point id {} belongs to shard {owner}/{n} but \
                     was reported by shard {k}/{n}",
                    mp.id
                ));
            }
            if claimed[pos].is_some() {
                return Err(format!("explore-merge: overlap — point id {} reported twice", mp.id));
            }
            claimed[pos] = Some(mp);
        }
    }
    let gaps: Vec<String> = expected
        .iter()
        .enumerate()
        .filter(|(i, _)| claimed[*i].is_none())
        .map(|(i, p)| format!("{} (id {}, shard {}/{n})", p.label(), p.id, owner_of(keys[i], n)))
        .collect();
    if !gaps.is_empty() {
        let shown = gaps.iter().take(5).cloned().collect::<Vec<_>>().join(", ");
        return Err(format!(
            "explore-merge: coverage gap — {} point(s) unreported: {shown}{}",
            gaps.len(),
            if gaps.len() > 5 { ", ..." } else { "" }
        ));
    }

    // 5. Union the caches and concatenate the partial logs.
    let out_cache = out_dir.join("explore_cache");
    std::fs::create_dir_all(&out_cache)
        .map_err(|e| format!("explore-merge: cannot create {}: {e}", out_cache.display()))?;
    let mut cache_copied = 0usize;
    let mut artifacts_copied = 0usize;
    let mut log_lines = 0usize;
    let out_log = out_dir.join("explore_partial.jsonl");
    if out_log.exists() {
        // Journals are append-only by contract (never truncate): flag the
        // pre-existing contents so a re-run's duplicated lines are not
        // mistaken for a pristine merged log.
        println!(
            "explore-merge: note — {} already exists; shard journals are appended after its \
             current contents (merge into a fresh directory for a pristine log)",
            out_log.display()
        );
    }
    let mut source_dirs: Vec<&Path> = Vec::new();
    for (_, (dir, _)) in &by_index {
        if !source_dirs.contains(dir) {
            source_dirs.push(dir);
        }
    }
    for dir in &source_dirs {
        cache_copied += union_cache(&dir.join("explore_cache"), &out_cache)?;
        artifacts_copied += union_artifacts(&dir.join("explore_cache"), &out_cache)?;
        log_lines += append_log(&dir.join("explore_partial.jsonl"), &out_log)?;
    }

    // 6. Rebuild the full result vector from the merged cache.
    let disk = DiskCache::at(&out_cache);
    let mut results = Vec::with_capacity(expected.len());
    for (pos, p) in expected.iter().enumerate() {
        let mp = claimed[pos].expect("gap check passed");
        let metrics = match &mp.error {
            Some(e) => Err(e.clone()),
            None => match disk.load(mp.key) {
                Some(m) => Ok(m),
                None => {
                    return Err(format!(
                        "explore-merge: cache record missing for {} (key {:016x}) — was the \
                         shard's explore_cache/ included?",
                        p.label(),
                        mp.key
                    ))
                }
            },
        };
        results.push(PointResult { point: p.clone(), metrics, from_disk: true });
    }

    let trajectory = match &search {
        SearchKind::Halving(p) => Some((
            p.clone(),
            canonical.rungs.clone().ok_or("explore-merge: halving manifest missing rungs")?,
        )),
        SearchKind::Grid => None,
    };
    Ok(MergeOutcome {
        spec,
        search,
        results,
        trajectory,
        shards: n,
        cache_copied,
        artifacts_copied,
        log_lines,
    })
}

/// CLI entry point for `cascade explore-merge <dir>...`: merge into
/// `results/` and emit the standard report. Mirrors `cascade explore`'s
/// exit behaviour: compile failures surface as an error after the report
/// is written.
pub fn merge_cli(dirs: &[PathBuf]) -> Result<(), String> {
    let out_dir = PathBuf::from("results");
    // `cascade explore` always compiles against the paper architecture
    // (arch overrides are per-point, folded into the keys).
    let merged = merge(dirs, &ArchParams::paper(), &out_dir)?;
    let trajectory = merged.trajectory.as_ref().map(|(p, r)| (p, r.as_slice()));
    let (md, json, analyses) =
        super::report::render_report(&merged.spec, &merged.results, trajectory);
    crate::experiments::common::emit("explore", "Design-space exploration", &md, &json);
    println!(
        "explore-merge: {} shard(s), {} point(s), {} cache record(s) + {} artifact(s) \
         unioned, {} partial-log line(s)",
        merged.shards,
        merged.results.len(),
        merged.cache_copied,
        merged.artifacts_copied,
        merged.log_lines
    );
    // The merged store is the one downstream consumers (encode, summary)
    // read: pin its frontier/knee artifacts and report its size.
    let disk = DiskCache::at(out_dir.join("explore_cache"));
    let pinned = super::pin_survivors(
        disk.artifacts(),
        &merged.spec,
        &ArchParams::paper(),
        &merged.results,
        &analyses,
    );
    if pinned > 0 {
        println!("cache: pinned {pinned} frontier/knee artifact(s) against eviction");
    }
    println!("{}", disk.stat_string());
    let failed: usize = analyses.iter().map(|a| a.failed.len()).sum();
    if failed > 0 {
        return Err(format!("{failed} point(s) failed to compile"));
    }
    Ok(())
}

/// Remove manifests from *other* cohorts (`shard_*.json` whose fingerprint
/// differs from the one just written) left behind by earlier runs in the
/// same results directory, so they cannot make a later `explore-merge`
/// over this directory fail on a stale spec or shard count. Same-cohort
/// manifests (local multi-process shard runs sharing one directory) and
/// unparseable files are left alone — the merge reports the latter loudly.
/// Returns the number of files removed.
fn clear_foreign_manifests(dir: &Path, keep: &Manifest) -> usize {
    let Ok(rd) = std::fs::read_dir(dir) else { return 0 };
    let own = ShardSpec { index: keep.shard, count: keep.of }.manifest_name();
    let mut removed = 0usize;
    for e in rd.filter_map(|e| e.ok()) {
        let path = e.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if !(name.starts_with("shard_") && name.ends_with(".json")) || name == own {
            continue;
        }
        if let Ok(m) = Manifest::load(&path) {
            // Foreign = different spec/search/version OR a different shard
            // count (the fingerprint deliberately excludes N, so a re-shard
            // of the same spec is same-fingerprint but still stale here).
            let foreign = m.fingerprint != keep.fingerprint || m.of != keep.of;
            if foreign && std::fs::remove_file(&path).is_ok() {
                removed += 1;
            }
        }
    }
    removed
}

/// Copy every `.{ext}` file from `src` into `dst`, skipping files already
/// present with identical bytes and refusing to merge conflicting ones
/// (same name, different bytes — a determinism violation, not a merge
/// problem; both layers serialize canonically, so equal content means
/// equal bytes). Returns the number of files copied. An absent `src`
/// contributes nothing (later lookups name any real gap).
fn union_files(src: &Path, dst: &Path, ext: &str, what: &str) -> Result<usize, String> {
    let Ok(rd) = std::fs::read_dir(src) else {
        return Ok(0);
    };
    std::fs::create_dir_all(dst)
        .map_err(|e| format!("explore-merge: cannot create {}: {e}", dst.display()))?;
    let mut paths: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == ext).unwrap_or(false))
        .collect();
    paths.sort();
    let mut copied = 0usize;
    for p in paths {
        let name = p.file_name().expect("filtered on file_name").to_owned();
        let to = dst.join(&name);
        let data = std::fs::read(&p)
            .map_err(|e| format!("explore-merge: read {}: {e}", p.display()))?;
        if to.exists() {
            let existing = std::fs::read(&to)
                .map_err(|e| format!("explore-merge: read {}: {e}", to.display()))?;
            if existing != data {
                return Err(format!(
                    "explore-merge: conflicting {what} for {} (shards produced different \
                     bytes for one key — determinism violation)",
                    name.to_string_lossy()
                ));
            }
        } else {
            std::fs::write(&to, &data)
                .map_err(|e| format!("explore-merge: write {}: {e}", to.display()))?;
            copied += 1;
        }
    }
    Ok(copied)
}

/// Union the metrics records of two cache directories.
fn union_cache(src: &Path, dst: &Path) -> Result<usize, String> {
    union_files(src, dst, "rec", "cache records")
}

/// Union the compiled-artifact stores under two cache directories: copy
/// every `artifacts/*.art` from `src_cache` into `dst_cache/artifacts/`,
/// with the same validation the metrics union applies — an already-present
/// artifact must be byte-identical (serialization is canonical, so two
/// shards that compiled one key deterministically wrote the same bytes;
/// anything else is a determinism violation and aborts the merge). Pin
/// sets are unioned and access journals concatenated so LRU history and
/// GC survivors carry over. Returns the number of artifacts copied.
fn union_artifacts(src_cache: &Path, dst_cache: &Path) -> Result<usize, String> {
    let src = src_cache.join("artifacts");
    let dst = dst_cache.join("artifacts");
    let copied = union_files(&src, &dst, "art", "compiled artifacts")?;
    // Pins: set union (a key any shard pinned stays pinned). The source
    // side is read without a store handle — sources are read-only to a
    // merge, and `ArtifactStore::at` creates its directory.
    let pins = crate::explore::artifact::read_pins_file(&src.join("pins"));
    if !pins.is_empty() {
        crate::explore::artifact::ArtifactStore::at(&dst).pin(pins);
    }
    // Journal: concatenate (append-only, like the partial log).
    append_log(&src.join("atime.log"), &dst.join("atime.log"))?;
    Ok(copied)
}

/// Append `src`'s partial log to `dst` (which is never truncated),
/// returning the number of lines appended. Skips absent sources and the
/// degenerate case where `src` *is* `dst`.
fn append_log(src: &Path, dst: &Path) -> Result<usize, String> {
    if !src.exists() {
        return Ok(0);
    }
    if let (Ok(a), Ok(b)) = (src.canonicalize(), dst.canonicalize()) {
        if a == b {
            return Ok(0);
        }
    }
    let text = std::fs::read_to_string(src)
        .map_err(|e| format!("explore-merge: read {}: {e}", src.display()))?;
    if text.is_empty() {
        return Ok(0);
    }
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .create(true)
        .open(dst)
        .map_err(|e| format!("explore-merge: open {}: {e}", dst.display()))?;
    f.write_all(text.as_bytes())
        .map_err(|e| format!("explore-merge: write {}: {e}", dst.display()))?;
    if !text.ends_with('\n') {
        let _ = writeln!(f);
    }
    Ok(text.lines().count())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::cache::PointMetrics;

    #[test]
    fn shard_spec_parses_and_rejects() {
        assert_eq!(ShardSpec::parse("1/3").unwrap(), ShardSpec { index: 1, count: 3 });
        assert_eq!(ShardSpec::parse("3/3").unwrap(), ShardSpec { index: 3, count: 3 });
        assert_eq!(ShardSpec::parse("1/1").unwrap().tag(), "1/1");
        assert!(ShardSpec::parse("0/3").is_err());
        assert!(ShardSpec::parse("4/3").is_err());
        assert!(ShardSpec::parse("3/0").is_err());
        assert!(ShardSpec::parse("x/3").is_err());
        assert!(ShardSpec::parse("3").is_err());
        assert_eq!(ShardSpec::parse("2/3").unwrap().manifest_name(), "shard_2_of_3.json");
    }

    #[test]
    fn partition_is_total_and_disjoint() {
        for n in [1usize, 2, 3, 7] {
            for key in [0u64, 1, 41, u64::MAX, 0xdeadbeef] {
                let owners: Vec<usize> = (1..=n)
                    .filter(|&k| ShardSpec { index: k, count: n }.owns(key))
                    .collect();
                assert_eq!(owners.len(), 1, "key {key:#x} must have exactly one owner of {n}");
                assert_eq!(owners[0], owner_of(key, n));
            }
        }
        // N = 1 owns everything.
        assert!(ShardSpec { index: 1, count: 1 }.owns(u64::MAX));
    }

    #[test]
    fn fingerprint_tracks_spec_and_search() {
        let spec = ExploreSpec::default();
        let grid = spec_fingerprint(&spec, &SearchKind::Grid);
        assert_eq!(grid, spec_fingerprint(&spec, &SearchKind::Grid));
        let halving = spec_fingerprint(&spec, &SearchKind::Halving(HalvingParams::default()));
        assert_ne!(grid, halving);
        let eta = SearchKind::Halving(HalvingParams { eta: 5, ..Default::default() });
        assert_ne!(halving, spec_fingerprint(&spec, &eta));
        let fast = spec.clone().with_fast(true);
        assert_ne!(grid, spec_fingerprint(&fast, &SearchKind::Grid));
    }

    fn tiny_two_point_spec() -> ExploreSpec {
        ExploreSpec::default()
            .with_apps(["gaussian"])
            .with_levels(["none", "compute"])
            .with_seeds([1])
            .with_fast(true)
            .with_scale(crate::explore::Scale::Tiny)
    }

    fn fake_metrics(tag: u64) -> PointMetrics {
        PointMetrics {
            crit_ns: 2.0 + tag as f64,
            fmax_mhz: 500.0,
            runtime_ms: 0.5,
            power_mw: 100.0,
            energy_mj: 0.05,
            edp: 0.025,
            pipe_regs: 10 + tag,
            util_pct: 50.0,
            cycles: 0,
            artifact_fp: tag,
        }
    }

    /// Build a consistent shard directory for `shard.index` of
    /// `shard.count` without compiling: fake metrics under the derived
    /// keys plus a matching manifest.
    fn fake_shard_dir(
        root: &Path,
        spec: &ExploreSpec,
        shard: ShardSpec,
        label: &str,
    ) -> PathBuf {
        let dir = root.join(label);
        let _ = std::fs::remove_dir_all(&dir);
        let disk = DiskCache::at(dir.join("explore_cache"));
        let base = ArchParams::paper();
        let mut points = Vec::new();
        let all = spec.points();
        for p in &all {
            let key = effective_key(spec, &base, p);
            if shard.owns(key) {
                disk.store(key, &fake_metrics(p.id as u64));
                points.push(ManifestPoint { id: p.id, key, error: None });
            }
        }
        std::fs::write(
            dir.join("explore_partial.jsonl"),
            format!("{{\"shard\":\"{}\"}}\n", shard.tag()),
        )
        .unwrap();
        let manifest = Manifest {
            shard: shard.index,
            of: shard.count,
            fingerprint: spec_fingerprint(spec, &SearchKind::Grid),
            spec: spec.clone(),
            search: SearchKind::Grid,
            points,
            points_total: all.len(),
            survivor_ids: None,
            rungs: None,
            cache_stores: 0,
            log_start: 0,
            log_lines: 1,
        };
        manifest.write(&dir).unwrap();
        dir
    }

    fn tmp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cascade-shard-{tag}-{}", std::process::id()))
    }

    #[test]
    fn manifest_json_round_trips() {
        let spec = tiny_two_point_spec();
        let m = Manifest {
            shard: 2,
            of: 3,
            fingerprint: "00ab34ffcd120099".into(),
            spec: spec.clone(),
            search: SearchKind::Halving(HalvingParams::default()),
            points: vec![
                ManifestPoint { id: 0, key: u64::MAX, error: None },
                ManifestPoint { id: 3, key: 7, error: Some("routing: congestion".into()) },
            ],
            points_total: 4,
            survivor_ids: Some(vec![0, 3]),
            rungs: Some(vec![RungReport { rung: 0, budget: 5, evaluated: 4, kept: 2 }]),
            cache_stores: 9,
            log_start: 12,
            log_lines: 4,
        };
        let j = m.to_json();
        let back = Manifest::from_json(&j).unwrap();
        assert_eq!(back, m, "manifest must round-trip through JSON");
        // And through text (the on-disk path).
        let text = j.to_string_pretty();
        assert!(text.contains("\"key\": \"ffffffffffffffff\""), "keys must travel as hex");
        assert_eq!(Manifest::from_json(&Json::parse(&text).unwrap()).unwrap(), m);
        // Grid manifests omit the halving sections.
        let g = Manifest {
            search: SearchKind::Grid,
            survivor_ids: None,
            rungs: None,
            ..m.clone()
        };
        let gj = g.to_json();
        assert!(gj.get("survivor_ids").is_none());
        assert_eq!(Manifest::from_json(&gj).unwrap(), g);
        // Corrupt documents fail loudly.
        assert!(Manifest::from_json(&Json::Null).is_err());
        let mut bad = m.to_json();
        bad.set("format", 2u64);
        assert!(Manifest::from_json(&bad).is_err());
    }

    #[test]
    fn merge_reassembles_fake_shards_and_dedupes_duplicates() {
        let root = tmp_root("merge-ok");
        let _ = std::fs::remove_dir_all(&root);
        let spec = tiny_two_point_spec();
        let n = 3;
        let dirs: Vec<PathBuf> = (1..=n)
            .map(|k| {
                let sh = ShardSpec { index: k, count: n };
                fake_shard_dir(&root, &spec, sh, &format!("shard{k}"))
            })
            .collect();
        let out = root.join("merged");
        let base = ArchParams::paper();
        let merged = merge(&dirs, &base, &out).unwrap();
        assert_eq!(merged.shards, n);
        assert_eq!(merged.results.len(), spec.points().len());
        for (i, r) in merged.results.iter().enumerate() {
            assert_eq!(r.point.id, i, "results must come back in enumeration order");
            assert_eq!(r.metrics.as_ref().unwrap().artifact_fp, i as u64);
        }
        assert_eq!(merged.log_lines, n, "every shard's journal must be concatenated");

        // An overlapping re-submission of shard 1 from a *different*
        // directory (same claims, e.g. a retried CI job) merges
        // identically: deduped at the manifest level, not double-counted.
        let dup = fake_shard_dir(&root, &spec, ShardSpec { index: 1, count: n }, "shard1-retry");
        let mut with_dup = dirs.clone();
        with_dup.push(dup);
        let out2 = root.join("merged2");
        let merged2 = merge(&with_dup, &base, &out2).unwrap();
        assert_eq!(merged2.results.len(), merged.results.len());
        assert_eq!(merged2.shards, n);
        assert_eq!(
            merged2.log_lines, merged.log_lines,
            "a deduped re-submission must not append its journal twice"
        );
        for (a, b) in merged.results.iter().zip(&merged2.results) {
            assert_eq!(a.metrics.as_ref().ok(), b.metrics.as_ref().ok());
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn foreign_manifests_cleared_same_cohort_kept() {
        let root = tmp_root("clear-foreign");
        let _ = std::fs::remove_dir_all(&root);
        let spec = tiny_two_point_spec();
        // One directory accumulates: a 1/1 run of an old spec, then a 2/2
        // sibling of the current cohort, then "this run's" 1/2 manifest.
        let dir = fake_shard_dir(&root, &spec, ShardSpec { index: 1, count: 2 }, "d");
        let old_spec = spec.clone().with_seeds([9]);
        let stale = Manifest {
            shard: 1,
            of: 1,
            fingerprint: spec_fingerprint(&old_spec, &SearchKind::Grid),
            spec: old_spec,
            search: SearchKind::Grid,
            points: vec![],
            points_total: 2,
            survivor_ids: None,
            rungs: None,
            cache_stores: 0,
            log_start: 0,
            log_lines: 0,
        };
        stale.write(&dir).unwrap();
        // Same spec re-sharded with a different N: identical fingerprint,
        // still stale (the fingerprint deliberately excludes N).
        let resharded = Manifest {
            of: 3,
            spec: spec.clone(),
            fingerprint: spec_fingerprint(&spec, &SearchKind::Grid),
            ..stale
        };
        resharded.write(&dir).unwrap();
        let sibling_dir = fake_shard_dir(&root, &spec, ShardSpec { index: 2, count: 2 }, "sib");
        let sibling = dir.join("shard_2_of_2.json");
        std::fs::copy(sibling_dir.join("shard_2_of_2.json"), &sibling).unwrap();

        let own = Manifest::load(&dir.join("shard_1_of_2.json")).unwrap();
        let removed = clear_foreign_manifests(&dir, &own);
        assert_eq!(removed, 2, "both foreign manifests go (other spec AND other N)");
        assert!(!dir.join("shard_1_of_1.json").exists());
        assert!(!dir.join("shard_1_of_3.json").exists(), "same-spec different-N is stale too");
        assert!(sibling.exists(), "same-cohort sibling must survive");
        assert!(dir.join("shard_1_of_2.json").exists());
        let _ = std::fs::remove_dir_all(&root);
    }

    /// Artifact stores union alongside the metrics: every shard's `.art`
    /// files land in the merged store, pins survive, a cap smaller than
    /// the merged store evicts only unpinned entries, and the merged
    /// report — whose source of truth is the metrics records — is
    /// byte-identical before and after the eviction.
    #[test]
    fn merge_unions_artifact_stores_with_pins_and_cap() {
        use crate::explore::artifact::{ArtifactStore, CacheCap};
        let root = tmp_root("merge-art");
        let _ = std::fs::remove_dir_all(&root);
        let spec = tiny_two_point_spec();
        let n = 2;
        let base = ArchParams::paper();
        let dirs: Vec<PathBuf> = (1..=n)
            .map(|k| {
                let sh = ShardSpec { index: k, count: n };
                fake_shard_dir(&root, &spec, sh, &format!("shard{k}"))
            })
            .collect();
        // Drop one (fake) artifact per point into its owner's store — the
        // union and GC layers never parse artifact bodies.
        let keys: Vec<u64> =
            spec.points().iter().map(|p| effective_key(&spec, &base, p)).collect();
        for &key in &keys {
            let art_dir = dirs[owner_of(key, n) - 1].join("explore_cache/artifacts");
            std::fs::create_dir_all(&art_dir).unwrap();
            std::fs::write(art_dir.join(format!("{key:016x}.art")), format!("fake-{key:016x}"))
                .unwrap();
        }
        let pin_key = keys[0];
        ArtifactStore::at(dirs[owner_of(pin_key, n) - 1].join("explore_cache/artifacts"))
            .pin([pin_key]);

        let out = root.join("merged");
        let merged = merge(&dirs, &base, &out).unwrap();
        assert_eq!(merged.artifacts_copied, keys.len());
        let store = ArtifactStore::at(out.join("explore_cache/artifacts"));
        assert_eq!(store.keys().len(), keys.len());
        assert!(store.pinned().contains(&pin_key), "pins survive the union");
        let (md1, json1, _) =
            crate::explore::report::render_report(&merged.spec, &merged.results, None);

        // Cap smaller than the merged store: only unpinned artifacts go.
        let r = store.gc(&CacheCap::entries(1));
        assert_eq!(r.evicted, keys.len() - 1);
        assert_eq!(store.keys(), vec![pin_key], "pinned survivor outlives the cap");

        // A subsequent merge over the same shard dirs regenerates a
        // byte-identical report (and restores the evicted artifacts).
        let merged2 = merge(&dirs, &base, &out).unwrap();
        let (md2, json2, _) =
            crate::explore::report::render_report(&merged2.spec, &merged2.results, None);
        assert_eq!(md1, md2);
        assert_eq!(json1.to_string_pretty(), json2.to_string_pretty());
        assert_eq!(store.keys().len(), keys.len(), "re-merge restores evicted artifacts");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn merge_names_the_missing_shard() {
        let root = tmp_root("merge-gap");
        let _ = std::fs::remove_dir_all(&root);
        let spec = tiny_two_point_spec();
        let d1 = fake_shard_dir(&root, &spec, ShardSpec { index: 1, count: 3 }, "s1");
        let d3 = fake_shard_dir(&root, &spec, ShardSpec { index: 3, count: 3 }, "s3");
        let err = merge(&[d1, d3], &ArchParams::paper(), &root.join("m")).unwrap_err();
        assert!(err.contains("shard 2/3 missing"), "gap must be named: {err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn merge_rejects_spec_drift_and_conflicts() {
        let root = tmp_root("merge-drift");
        let _ = std::fs::remove_dir_all(&root);
        let spec = tiny_two_point_spec();
        let other = spec.clone().with_seeds([2]);
        let d1 = fake_shard_dir(&root, &spec, ShardSpec { index: 1, count: 2 }, "a");
        let d2 = fake_shard_dir(&root, &other, ShardSpec { index: 2, count: 2 }, "b");
        let err = merge(&[d1.clone(), d2], &ArchParams::paper(), &root.join("m")).unwrap_err();
        assert!(err.contains("spec drift"), "{err}");

        // Same shard index, same fingerprint, different claims: conflict.
        let d1b = fake_shard_dir(&root, &spec, ShardSpec { index: 1, count: 2 }, "c");
        let manifest_path = d1b.join("shard_1_of_2.json");
        let mut m = Manifest::load(&manifest_path).unwrap();
        m.points.pop();
        m.write(&d1b).unwrap();
        let err = merge(&[d1, d1b], &ArchParams::paper(), &root.join("m2")).unwrap_err();
        assert!(err.contains("conflicting") || err.contains("missing"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn merge_rejects_shard_count_mixtures() {
        let root = tmp_root("merge-mixn");
        let _ = std::fs::remove_dir_all(&root);
        let spec = tiny_two_point_spec();
        let d1 = fake_shard_dir(&root, &spec, ShardSpec { index: 1, count: 1 }, "one");
        let d2 = fake_shard_dir(&root, &spec, ShardSpec { index: 1, count: 2 }, "half");
        let err = merge(&[d1, d2], &ArchParams::paper(), &root.join("m")).unwrap_err();
        assert!(err.contains("shard-count mismatch"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn merge_reports_missing_cache_records() {
        let root = tmp_root("merge-nocache");
        let _ = std::fs::remove_dir_all(&root);
        let spec = tiny_two_point_spec();
        let d = fake_shard_dir(&root, &spec, ShardSpec { index: 1, count: 1 }, "s");
        let _ = std::fs::remove_dir_all(d.join("explore_cache"));
        let err = merge(&[d], &ArchParams::paper(), &root.join("m")).unwrap_err();
        assert!(err.contains("cache record missing"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }
}
