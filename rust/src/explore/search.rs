//! Adaptive multi-fidelity search over the exploration space: successive
//! halving (the non-stochastic core of Hyperband / ASHA, as used for CGRA
//! PE design-space exploration).
//!
//! The exhaustive grid evaluates every point at full fidelity. Successive
//! halving instead treats the post-PnR iteration budget as a *fidelity*
//! ladder:
//!
//! 1. enumerate the candidate set — the spec's cross-product with the
//!    budget axis suppressed ([`ExploreSpec::candidates`]);
//! 2. evaluate every candidate at the cheapest rung budget;
//! 3. rank each application's cohort by the promotion objective
//!    (power-cap-infeasible points rank behind every feasible one, failed
//!    compiles behind those) and keep the top `ceil(n / eta)`;
//! 4. promote survivors to the next rung (budget × eta) and repeat until
//!    the top rung, which runs at the full budget.
//!
//! All rungs share one [`EvalSession`], so a promoted candidate whose
//! effective configuration did not change across budgets (e.g. `level =
//! none`, which has no post-PnR pass) is served from the artifact cache
//! instead of recompiling, and a re-run after a crash is served from the
//! persistent disk cache rung by rung.
//!
//! The final rung's survivors are reported through the same Pareto /
//! knee-point analysis as a grid run. On spaces where the cheap fidelity
//! ranks the eventual knee into the survivor set (empirically: whenever
//! budget-insensitive axes dominate), halving returns the grid's knee
//! point while compiling strictly fewer full-budget points.
//!
//! The rung ladder and the promotion knobs are plain data:
//!
//! ```
//! use cascade::explore::search::{rung_budgets, HalvingParams, Objective};
//!
//! // Full budget 200, floor 5, eta 3, largest per-app cohort of 9
//! // candidates: the ladder always ends at the full budget and rises
//! // strictly.
//! let ladder = rung_budgets(200, 5, 3, 9);
//! assert_eq!(*ladder.last().unwrap(), 200);
//! assert!(ladder.windows(2).all(|w| w[0] < w[1]));
//!
//! assert_eq!(Objective::parse("edp").unwrap(), Objective::Edp);
//! let bad = HalvingParams { eta: 1, ..HalvingParams::default() };
//! assert!(bad.validate().is_err(), "eta < 2 cannot halve anything");
//! ```

use std::collections::HashSet;

use crate::pipeline::CompileCtx;

use super::cache::DiskCache;
use super::pareto::knee_distances;
use super::report::objectives;
use super::runner::{effective_key, CacheStats, EvalSession, PartialSink, PointResult};
use super::shard::ShardSpec;
use super::space::{ExplorePoint, ExploreSpec};

/// Promotion objective: how a rung cohort is ranked before the 1/eta cut.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Distance to the ideal corner of the normalized
    /// (crit-delay, EDP, regs) space — the default, mirroring the
    /// knee-point selection of the final report.
    Knee,
    /// Critical-path delay only.
    Crit,
    /// Energy-delay product only.
    Edp,
    /// Pipelining-register footprint only.
    Regs,
}

impl Objective {
    pub fn parse(s: &str) -> Result<Objective, String> {
        Ok(match s {
            "knee" => Objective::Knee,
            "crit" => Objective::Crit,
            "edp" => Objective::Edp,
            "regs" => Objective::Regs,
            _ => return Err(format!("unknown --objective '{s}' (knee|crit|edp|regs)")),
        })
    }

    pub fn tag(self) -> &'static str {
        match self {
            Objective::Knee => "knee",
            Objective::Crit => "crit",
            Objective::Edp => "edp",
            Objective::Regs => "regs",
        }
    }
}

/// Successive-halving knobs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HalvingParams {
    /// Promotion factor: keep `ceil(n / eta)` of each cohort per rung and
    /// multiply the budget by `eta` between rungs. Must be >= 2.
    pub eta: usize,
    /// Floor for the cheapest rung's post-PnR budget.
    pub min_budget: usize,
    /// Cohort ranking objective.
    pub objective: Objective,
}

impl Default for HalvingParams {
    fn default() -> Self {
        HalvingParams { eta: 3, min_budget: 5, objective: Objective::Knee }
    }
}

impl HalvingParams {
    pub fn validate(&self) -> Result<(), String> {
        if self.eta < 2 {
            return Err(format!("halving: --eta must be >= 2, got {}", self.eta));
        }
        if self.min_budget == 0 {
            return Err("halving: minimum rung budget must be >= 1".into());
        }
        Ok(())
    }
}

/// What one rung did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RungReport {
    pub rung: usize,
    /// Post-PnR iteration budget this rung evaluated at.
    pub budget: usize,
    /// Candidates evaluated at this rung.
    pub evaluated: usize,
    /// Candidates promoted to the next rung (= `evaluated` on the top
    /// rung, whose survivors feed the final report instead).
    pub kept: usize,
}

/// A completed adaptive search: final-rung results (candidate enumeration
/// order), the rung trajectory, and cumulative cache traffic.
///
/// Under a shard (`run_halving` with `shard = Some(..)`), `results` holds
/// only the shard's owned slice of the top rung while `survivors` still
/// lists the *global* final survivor set — the agreement every shard's
/// manifest records and `explore-merge` validates.
#[derive(Debug)]
pub struct SearchOutcome {
    pub results: Vec<PointResult>,
    /// Global top-rung survivor points (full-budget-bound), shard-independent.
    pub survivors: Vec<ExplorePoint>,
    pub rungs: Vec<RungReport>,
    pub stats: CacheStats,
}

impl SearchOutcome {
    /// Points evaluated at the full (top-rung) budget — the quantity the
    /// grid-vs-halving acceptance check compares.
    pub fn full_budget_evals(&self) -> usize {
        self.rungs.last().map(|r| r.evaluated).unwrap_or(0)
    }

    /// Total evaluations across every rung (cache hits included).
    pub fn total_evals(&self) -> usize {
        self.rungs.iter().map(|r| r.evaluated).sum()
    }
}

/// The top-rung budget for a spec: the largest requested budget (or the
/// post-PnR default), clamped to what `--fast` tuning would allow anyway
/// so every rung's budget survives `ExplorePoint::config` intact.
pub fn full_budget(spec: &ExploreSpec) -> usize {
    let nominal = spec
        .iters
        .iter()
        .copied()
        .max()
        .unwrap_or(crate::pipeline::PostPnrParams::default().max_iters);
    if spec.fast {
        nominal.min(crate::experiments::common::FAST_MAX_POSTPNR_ITERS)
    } else {
        nominal
    }
}

/// The rung budget ladder, cheapest first, always ending at `full`. The
/// number of halvings is bounded by the budget span (`full / eta^s >=
/// min_budget`) and by the population (no more rungs than needed to cut
/// the largest per-app cohort down to one candidate). Built by repeated
/// division, never exponentiation, so arbitrarily large budgets cannot
/// overflow.
pub fn rung_budgets(full: usize, min_budget: usize, eta: usize, max_cohort: usize) -> Vec<usize> {
    let min_budget = min_budget.max(1);
    let eta = eta.max(2);
    let mut pop_halvings = 0usize;
    let mut n = max_cohort.max(1);
    while n > 1 {
        n = n.div_ceil(eta);
        pop_halvings += 1;
    }
    let mut ladder = vec![full.max(1)];
    while ladder.len() <= pop_halvings {
        let next = ladder.last().unwrap() / eta;
        if next < min_budget {
            break;
        }
        ladder.push(next);
    }
    ladder.reverse();
    ladder
}

/// Run successive halving over `spec`'s candidate set.
///
/// With `shard = Some(..)`, the search runs in *sharded* mode: every rung
/// below the top is evaluated over the full candidate set on every shard
/// (the cheap rungs are exactly the ones successive halving made cheap),
/// so survivor selection is a deterministic replica of the single-process
/// run on every shard — no cross-process coordination, which is what lets
/// independent CI jobs shard a halving search. Only the expensive top rung
/// is partitioned: this shard evaluates just the survivors whose effective
/// cache key it owns. `explore-merge` later validates that all shards
/// recorded identical rung trajectories and survivor sets.
pub fn run_halving(
    spec: &ExploreSpec,
    ctx: &CompileCtx,
    threads: usize,
    disk: Option<&DiskCache>,
    sink: Option<&PartialSink>,
    params: &HalvingParams,
    shard: Option<&ShardSpec>,
) -> Result<SearchOutcome, String> {
    run_halving_obs(spec, ctx, threads, disk, sink, params, shard, None)
}

/// [`run_halving`] with an optional metrics registry attached to the
/// session (`cascade explore --profile --search halving`): every fresh
/// compile across every rung records its per-stage spans. Telemetry only
/// — results are identical with or without it.
#[allow(clippy::too_many_arguments)]
pub fn run_halving_obs(
    spec: &ExploreSpec,
    ctx: &CompileCtx,
    threads: usize,
    disk: Option<&DiskCache>,
    sink: Option<&PartialSink>,
    params: &HalvingParams,
    shard: Option<&ShardSpec>,
    obs: Option<std::sync::Arc<crate::obs::Registry>>,
) -> Result<SearchOutcome, String> {
    spec.validate()?;
    params.validate()?;
    let mut alive = spec.candidates();
    let max_cohort = spec
        .apps
        .iter()
        .map(|a| alive.iter().filter(|c| &c.app == a).count())
        .max()
        .unwrap_or(0);
    let budgets = rung_budgets(full_budget(spec), params.min_budget, params.eta, max_cohort);
    let mut session = EvalSession::new(spec, ctx, disk, sink);
    if let Some(reg) = obs {
        session.set_obs(reg);
    }

    let mut rungs = Vec::new();
    let mut final_results = Vec::new();
    let mut survivors = Vec::new();
    for (k, &budget) in budgets.iter().enumerate() {
        let points: Vec<ExplorePoint> = alive.iter().map(|c| c.at_budget(budget)).collect();
        let top_rung = k + 1 == budgets.len();
        // Top rung under a shard: evaluate only the owned slice. Lower
        // rungs always run the full cohort so selection stays bit-identical
        // to the single-process search.
        let eval: Vec<ExplorePoint> = match shard {
            Some(sh) if top_rung => points
                .iter()
                .filter(|p| sh.owns(effective_key(spec, &ctx.arch, p)))
                .cloned()
                .collect(),
            _ => points.clone(),
        };
        let results = session.eval_points(&eval, threads, Some(k));
        let kept = if top_rung {
            points.len()
        } else {
            let keep: HashSet<usize> =
                select_survivors(spec, &results, params).into_iter().collect();
            alive.retain(|c| keep.contains(&c.id));
            keep.len()
        };
        let owned_note = match shard {
            Some(sh) if top_rung => format!(" ({} owned by shard {})", results.len(), sh.tag()),
            _ => String::new(),
        };
        println!(
            "rung {k}: budget {budget}, {} candidate(s) -> {} {}{owned_note}",
            points.len(),
            kept,
            if top_rung { "to report" } else { "promoted" }
        );
        // The trajectory records the *global* schedule (what a
        // single-process run would evaluate), so every shard's manifest
        // carries the same rungs and the merged report is run-invariant.
        rungs.push(RungReport { rung: k, budget, evaluated: points.len(), kept });
        if top_rung {
            final_results = results;
            survivors = points;
        }
    }
    Ok(SearchOutcome { results: final_results, survivors, rungs, stats: session.stats() })
}

/// Candidate ids to promote: per application, rank the cohort — feasible
/// points by the objective, then power-capped points, then failed compiles
/// — and keep the top `ceil(n / eta)`.
fn select_survivors(
    spec: &ExploreSpec,
    results: &[PointResult],
    params: &HalvingParams,
) -> Vec<usize> {
    let mut keep = Vec::new();
    for app in &spec.apps {
        let cohort: Vec<&PointResult> = results.iter().filter(|r| &r.point.app == app).collect();
        if cohort.is_empty() {
            continue;
        }
        let quota = cohort.len().div_ceil(params.eta);

        let mut feasible = Vec::new();
        let mut capped = Vec::new();
        let mut failed = Vec::new();
        for r in &cohort {
            match &r.metrics {
                Ok(m) if crate::sim::power::within_cap(m.power_mw, spec.power_cap_mw) => {
                    feasible.push(*r)
                }
                Ok(_) => capped.push(*r),
                Err(_) => failed.push(*r),
            }
        }
        let scores = rank_scores(&feasible, params.objective);
        let mut order: Vec<usize> = (0..feasible.len()).collect();
        order.sort_by(|&i, &j| {
            scores[i]
                .partial_cmp(&scores[j])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(feasible[i].point.id.cmp(&feasible[j].point.id))
        });
        keep.extend(
            order
                .into_iter()
                .map(|i| feasible[i].point.id)
                .chain(capped.iter().map(|r| r.point.id))
                .chain(failed.iter().map(|r| r.point.id))
                .take(quota),
        );
    }
    keep
}

/// Lower-is-better promotion score for each feasible cohort member.
fn rank_scores(feasible: &[&PointResult], objective: Objective) -> Vec<f64> {
    fn metric(r: &PointResult) -> &super::cache::PointMetrics {
        r.metrics.as_ref().expect("feasible implies Ok")
    }
    match objective {
        Objective::Crit => feasible.iter().map(|r| metric(r).crit_ns).collect(),
        Objective::Edp => feasible.iter().map(|r| metric(r).edp).collect(),
        Objective::Regs => feasible.iter().map(|r| metric(r).pipe_regs as f64).collect(),
        Objective::Knee => {
            let vecs: Vec<Vec<f64>> = feasible.iter().map(|r| objectives(metric(r))).collect();
            knee_distances(&vecs)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::cache::PointMetrics;

    #[test]
    fn objective_parses_and_rejects() {
        assert_eq!(Objective::parse("knee").unwrap(), Objective::Knee);
        assert_eq!(Objective::parse("crit").unwrap(), Objective::Crit);
        assert_eq!(Objective::parse("edp").unwrap(), Objective::Edp);
        assert_eq!(Objective::parse("regs").unwrap(), Objective::Regs);
        assert!(Objective::parse("speed").is_err());
    }

    #[test]
    fn params_validate() {
        assert!(HalvingParams::default().validate().is_ok());
        assert!(HalvingParams { eta: 1, ..Default::default() }.validate().is_err());
        assert!(HalvingParams { min_budget: 0, ..Default::default() }.validate().is_err());
    }

    /// The satellite monotonicity requirement: rung budgets strictly
    /// increase and top out at the full budget.
    #[test]
    fn rung_budgets_are_monotone_and_end_at_full() {
        for (full, min, eta, cohort) in [
            (200, 5, 3, 27),
            (200, 5, 2, 100),
            (25, 5, 3, 9),
            (7, 1, 2, 64),
            (1, 1, 2, 4),
            // Absurd inputs must not overflow or divide by zero.
            (usize::MAX, 1, 2, usize::MAX),
        ] {
            let b = rung_budgets(full, min, eta, cohort);
            assert!(!b.is_empty());
            assert_eq!(*b.last().unwrap(), full, "{b:?}");
            for w in b.windows(2) {
                assert!(w[0] < w[1], "budgets must strictly increase: {b:?}");
            }
            assert!(*b.first().unwrap() >= 1);
        }
    }

    #[test]
    fn rung_count_bounded_by_population() {
        // 2 candidates per app: one halving reduces to 1, so at most two
        // rungs no matter how wide the budget span is.
        let b = rung_budgets(200, 1, 3, 2);
        assert_eq!(b.len(), 2);
        // Single candidate: nothing to halve, single full-budget rung.
        assert_eq!(rung_budgets(200, 5, 3, 1), vec![200]);
    }

    fn result(id: usize, app: &str, crit: f64, edp: f64, regs: u64, power: f64) -> PointResult {
        PointResult {
            point: ExplorePoint {
                id,
                app: app.into(),
                level: "full".into(),
                alpha: None,
                seed: 1,
                iters: Some(5),
                tracks: None,
                regwords: None,
                fifo: None,
                fuse: None,
            },
            metrics: Ok(PointMetrics {
                crit_ns: crit,
                fmax_mhz: 1000.0 / crit,
                runtime_ms: 1.0,
                power_mw: power,
                energy_mj: 0.1,
                edp,
                pipe_regs: regs,
                util_pct: 50.0,
                cycles: 0,
                artifact_fp: id as u64,
            }),
            from_disk: false,
        }
    }

    #[test]
    fn survivors_prefer_balanced_points_and_drop_capped_first() {
        let spec = ExploreSpec::default()
            .with_apps(["gaussian"])
            .with_levels(["full"])
            .with_seeds([1])
            .with_power_cap(Some(300.0));
        let params = HalvingParams { eta: 2, ..Default::default() };
        // Four candidates: a balanced one, two extremes, and one that
        // would win on crit but blows the power cap.
        let results = vec![
            result(0, "gaussian", 10.0, 10.0, 100, 100.0),
            result(1, "gaussian", 2.0, 2.0, 20, 100.0), // balanced: best knee
            result(2, "gaussian", 9.0, 1.0, 500, 100.0),
            result(3, "gaussian", 1.0, 0.5, 10, 999.0), // capped
        ];
        let keep = select_survivors(&spec, &results, &params);
        assert_eq!(keep.len(), 2);
        assert!(keep.contains(&1), "balanced point must survive: {keep:?}");
        assert!(!keep.contains(&3), "capped point must be dropped first: {keep:?}");
    }

    #[test]
    fn survivors_failed_points_rank_last_but_cohort_never_empties() {
        let spec =
            ExploreSpec::default().with_apps(["gaussian"]).with_levels(["full"]).with_seeds([1]);
        let params = HalvingParams { eta: 4, ..Default::default() };
        let mut broken = result(0, "gaussian", 1.0, 1.0, 1, 100.0);
        broken.metrics = Err("routing: congestion".into());
        let keep = select_survivors(&spec, &[broken], &params);
        // Every point failed: still promote one so the failure is
        // reported at full budget rather than vanishing silently.
        assert_eq!(keep, vec![0]);
    }

    #[test]
    fn scalar_objectives_rank_by_their_metric() {
        let rs = vec![
            result(0, "gaussian", 5.0, 1.0, 50, 100.0),
            result(1, "gaussian", 1.0, 5.0, 500, 100.0),
        ];
        let refs: Vec<&PointResult> = rs.iter().collect();
        let crit = rank_scores(&refs, Objective::Crit);
        assert!(crit[1] < crit[0]);
        let edp = rank_scores(&refs, Objective::Edp);
        assert!(edp[0] < edp[1]);
        let regs = rank_scores(&refs, Objective::Regs);
        assert!(regs[0] < regs[1]);
    }
}
