//! N-dimensional Pareto dominance analysis and knee-point selection.
//!
//! All objectives are minimized. The `explore` engine uses the objective
//! vector (critical-path delay ns, EDP mJ*ms, pipelining-register count),
//! but the functions are dimension-agnostic.

/// Whether `a` dominates `b`: no worse in every objective and strictly
/// better in at least one. Ties (equal vectors) dominate in neither
/// direction, so duplicated points both stay on the frontier.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the non-dominated points, ascending. O(n^2) pairwise scan —
/// exploration grids are hundreds of points, not millions.
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && dominates(q, p) {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

/// Euclidean distance of every point to the ideal corner after
/// per-objective min-max normalization *over the given set*. Degenerate
/// spans (all members equal in an objective) are normalized to 0 so they
/// do not bias the distance. This is both the knee criterion (applied to a
/// frontier) and the successive-halving promotion objective (applied to a
/// whole rung cohort).
pub fn knee_distances(points: &[Vec<f64>]) -> Vec<f64> {
    let Some(first) = points.first() else {
        return Vec::new();
    };
    let dims = first.len();
    let mut lo = vec![f64::INFINITY; dims];
    let mut hi = vec![f64::NEG_INFINITY; dims];
    for p in points {
        for d in 0..dims {
            lo[d] = lo[d].min(p[d]);
            hi[d] = hi[d].max(p[d]);
        }
    }
    points
        .iter()
        .map(|p| {
            let mut dist2 = 0.0;
            for d in 0..dims {
                let span = hi[d] - lo[d];
                let z = if span > 0.0 { (p[d] - lo[d]) / span } else { 0.0 };
                dist2 += z * z;
            }
            dist2.sqrt()
        })
        .collect()
}

/// Knee point of a frontier: the member closest to the ideal point under
/// [`knee_distances`] computed over the frontier members. Ties resolve to
/// the lowest index. `None` for an empty frontier.
pub fn knee_point(points: &[Vec<f64>], front: &[usize]) -> Option<usize> {
    let members: Vec<Vec<f64>> = front.iter().map(|&i| points[i].clone()).collect();
    let dists = knee_distances(&members);
    let mut best: Option<(usize, f64)> = None;
    for (k, &d) in dists.iter().enumerate() {
        match best {
            Some((_, bd)) if bd <= d => {}
            _ => best = Some((front[k], d)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[f64]) -> Vec<f64> {
        xs.to_vec()
    }

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[2.0, 2.0], &[1.0, 1.0]));
        // Trade-off: neither dominates.
        assert!(!dominates(&[1.0, 3.0], &[3.0, 1.0]));
        assert!(!dominates(&[3.0, 1.0], &[1.0, 3.0]));
    }

    #[test]
    fn dominance_ties() {
        // Equal vectors dominate in neither direction.
        assert!(!dominates(&[1.0, 2.0], &[1.0, 2.0]));
        // Equal in one dim, better in another: dominates.
        assert!(dominates(&[1.0, 1.0], &[1.0, 2.0]));
    }

    #[test]
    fn front_single_point_is_degenerate_front() {
        let pts = vec![v(&[5.0, 5.0, 5.0])];
        assert_eq!(pareto_front(&pts), vec![0]);
        assert_eq!(knee_point(&pts, &[0]), Some(0));
    }

    #[test]
    fn front_keeps_duplicates_and_tradeoffs() {
        let pts = vec![
            v(&[1.0, 4.0]), // frontier
            v(&[4.0, 1.0]), // frontier
            v(&[1.0, 4.0]), // duplicate of 0: also frontier (tie)
            v(&[4.0, 4.0]), // dominated by 0 and 1
            v(&[2.0, 2.0]), // frontier (trade-off)
        ];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2, 4]);
    }

    #[test]
    fn front_in_three_dims() {
        let pts = vec![
            v(&[1.0, 9.0, 9.0]),
            v(&[9.0, 1.0, 9.0]),
            v(&[9.0, 9.0, 1.0]),
            v(&[2.0, 2.0, 2.0]),
            v(&[9.0, 9.0, 9.0]),  // dominated by everything above
            v(&[2.0, 2.0, 3.0]),  // dominated by [2,2,2]
        ];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2, 3]);
    }

    #[test]
    fn knee_prefers_balanced_point() {
        let pts = vec![
            v(&[0.0, 10.0]),
            v(&[10.0, 0.0]),
            v(&[1.0, 1.0]), // near-ideal corner
        ];
        let front = pareto_front(&pts);
        assert_eq!(front, vec![0, 1, 2]);
        assert_eq!(knee_point(&pts, &front), Some(2));
    }

    #[test]
    fn knee_handles_degenerate_span_and_empty_front() {
        // All equal in dim 1: span 0 must not produce NaN.
        let pts = vec![v(&[1.0, 5.0]), v(&[2.0, 5.0])];
        let front = pareto_front(&pts);
        assert_eq!(front, vec![0]);
        assert_eq!(knee_point(&pts, &front), Some(0));
        assert_eq!(knee_point(&pts, &[]), None);
    }

    #[test]
    fn empty_input() {
        assert!(pareto_front(&[]).is_empty());
        assert!(knee_distances(&[]).is_empty());
    }

    #[test]
    fn knee_distances_rank_balanced_point_first() {
        let pts = vec![v(&[0.0, 10.0]), v(&[10.0, 0.0]), v(&[1.0, 1.0])];
        let d = knee_distances(&pts);
        assert_eq!(d.len(), 3);
        assert!(d[2] < d[0] && d[2] < d[1], "{d:?}");
        // Distances are scale-free: each coordinate normalized to [0, 1].
        assert!((d[0] - 1.0).abs() < 1e-12);
        assert!((d[1] - 1.0).abs() < 1e-12);
    }
}
