//! Design-space exploration engine (`cascade explore`).
//!
//! Cascade's evaluation sweeps pipelining levels by hand (Fig. 7/10); the
//! paper's real promise — trading frequency against energy and resources —
//! is a design-space problem. This subsystem makes it one:
//!
//! * [`space`] — the declarative exploration grid ([`space::ExploreSpec`]):
//!   compiler axes (app × pipelining level × placement alpha × PnR seed ×
//!   post-PnR iteration budget) and architecture axes (routing tracks ×
//!   regfile words × FIFO depth), with axis builders and deterministic
//!   point enumeration.
//! * [`runner`] — a reusable multi-threaded work-queue session
//!   ([`runner::EvalSession`]) over `std::thread::scope` whose result
//!   order is independent of thread count and scheduling, with a
//!   per-architecture compile-context cache and streaming partial results
//!   (`results/explore_partial.jsonl`).
//! * [`search`] — adaptive successive halving ([`search::run_halving`]):
//!   evaluate every candidate at a cheap post-PnR budget, keep the top
//!   `1/eta` of each application's cohort by the promotion objective, and
//!   promote survivors up the budget ladder — rungs share the session's
//!   artifact cache, so unchanged effective configs never recompile.
//! * [`cache`] — content-hash keyed artifact memoization: in-memory
//!   deduplication of effective-config collisions within a run, plus a
//!   persistent metrics cache under `results/explore_cache/` that repeat
//!   invocations (and `cascade exp summary`) reuse.
//! * [`artifact`] — persistent *compiled-artifact* store
//!   (`results/explore_cache/artifacts/`): exact JSON round-trip of every
//!   [`crate::pipeline::Compiled`], fingerprint-checked rehydration for
//!   `cascade encode --from-cache` / `exp summary` / resumed and sharded
//!   sweeps, and bounded LRU eviction with Pareto/knee pinning
//!   (`--cache-cap`, `cascade cache gc|stat`).
//! * [`pareto`] — n-dimensional dominance frontier and knee-point
//!   selection over (critical-path delay, EDP, pipelining registers).
//! * [`report`] — ranked markdown summary + deterministic JSON emission;
//!   byte-identical across cache-served re-runs.
//! * [`shard`] — multi-process / multi-machine distribution: `--shard K/N`
//!   evaluates one deterministic slice of the space (partitioned by
//!   effective cache key) and writes a self-describing manifest
//!   (`results/shard_K_of_N.json`); `cascade explore-merge <dir>...`
//!   validates coverage, unions the caches and partial logs, and emits a
//!   report byte-identical to the single-process run.
//!
//! A Capstone-style `--power-cap` (mW) marks points whose estimated total
//! power exceeds the budget as infeasible before the frontier is computed;
//! the halving search additionally drops infeasible points first at every
//! promotion.

pub mod artifact;
pub mod cache;
pub mod pareto;
pub mod report;
pub mod runner;
pub mod search;
pub mod shard;
pub mod space;

pub use artifact::{ArtifactStore, CacheCap, GcReport, StoreStat};
pub use cache::{ArtifactCache, DiskCache, PointMetrics};
pub use runner::{
    run, EvalSession, PartialSink, PointResult, Provenance, RunOutcome, SessionCore,
};
pub use search::{run_halving, HalvingParams, Objective, RungReport, SearchOutcome};
pub use shard::{merge, merge_cli, owner_of, Manifest, MergeOutcome, ShardOutcome, ShardSpec};
pub use space::{ExplorePoint, ExploreSpec, Scale};

use std::path::Path;

use crate::arch::params::ArchParams;
use crate::pipeline::CompileCtx;

/// Search strategy for one `cascade explore` invocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SearchKind {
    /// Exhaustive evaluation of the full grid.
    Grid,
    /// Adaptive successive halving over the candidate set.
    Halving(HalvingParams),
}

/// Pin the Pareto-frontier and knee-point artifacts of every app so a
/// later `cache gc` keeps exactly the survivors downstream consumers
/// (bitstream encoding, simulation) want to rehydrate.
pub(crate) fn pin_survivors(
    store: &ArtifactStore,
    spec: &ExploreSpec,
    base: &ArchParams,
    results: &[PointResult],
    analyses: &[report::AppAnalysis],
) -> usize {
    let mut keys = Vec::new();
    for a in analyses {
        for r in results {
            let keep = a.frontier.contains(&r.point.id) || a.knee == Some(r.point.id);
            if r.point.app == a.app && keep && r.metrics.is_ok() {
                keys.push(runner::effective_key(spec, base, &r.point));
            }
        }
    }
    keys.sort_unstable();
    keys.dedup();
    let n = keys.len();
    if n > 0 {
        store.pin(keys);
    }
    n
}

/// CLI entry point: evaluate the space (exhaustively or adaptively),
/// analyze, emit `results/explore.*`, stream partials to
/// `results/explore_partial.jsonl`, pin the frontier/knee artifacts, run
/// a bounded-cache GC when `--cache-cap` is given, and print the cache
/// traffic (stdout only — reports stay run-invariant). With `shard =
/// Some(K/N)`, evaluate only this shard's slice and write
/// `results/shard_K_of_N.json` instead of the report; `cascade
/// explore-merge` reassembles the full report.
#[allow(clippy::too_many_arguments)]
pub fn run_cli(
    spec: &ExploreSpec,
    ctx: &CompileCtx,
    threads: usize,
    use_disk_cache: bool,
    search: &SearchKind,
    shard_of: Option<&ShardSpec>,
    cache_cap: Option<&CacheCap>,
    profile: bool,
) -> Result<(), String> {
    spec.validate()?;
    let threads = threads.max(1);
    if profile && shard_of.is_some() {
        return Err(
            "explore: --profile is not available with --shard (a shard's report is the \
             manifest; profile the unsharded run, or scrape a daemon's `metrics` op)"
                .into(),
        );
    }
    // `--profile` attaches a metrics registry to the session: fresh
    // compiles record per-stage spans, and the report gains a profile
    // section. Without the flag nothing is measured and the report is
    // byte-identical to earlier releases.
    let obs_reg = if profile { Some(std::sync::Arc::new(crate::obs::Registry::new())) } else { None };
    if let Some(sh) = shard_of {
        if !use_disk_cache {
            return Err(
                "explore: --shard requires the disk cache (drop --no-cache); merged metrics \
                 are reconstructed from explore_cache/"
                    .into(),
            );
        }
        shard::run_sharded(spec, ctx, threads, search, sh, Path::new("results"))?;
        if let Some(cap) = cache_cap {
            // A shard knows no global frontier, so nothing is pinned here
            // (the merge pins survivors on the merged store). The cap
            // still bounds the shard's local store — which means a
            // pre-merge GC may evict artifacts the merged store would
            // otherwise serve; that only costs a recompile on next use,
            // but say so.
            let store = ArtifactStore::at(DiskCache::default_dir().join("artifacts"));
            println!("cache gc: {}", store.gc(cap).summary());
            println!(
                "cache gc: note — shard-local eviction is unpinned; artifacts evicted \
                 here are absent from a later merge and recompile on next use"
            );
        }
        return Ok(());
    }
    if cache_cap.is_some() && !use_disk_cache {
        return Err(
            "explore: --cache-cap requires the disk cache (drop --no-cache); there is no \
             store to bound without it"
                .into(),
        );
    }
    let disk = if use_disk_cache { Some(DiskCache::open_default()) } else { None };
    let sink = PartialSink::open(PartialSink::default_path());

    let (results, stats, trajectory) = match search {
        SearchKind::Grid => {
            let points = spec.points();
            println!(
                "explore: grid, {} points ({}) on {} thread(s)...",
                points.len(),
                spec.shape(),
                threads
            );
            let mut session = EvalSession::new(spec, ctx, disk.as_ref(), Some(&sink));
            if let Some(reg) = &obs_reg {
                session.set_obs(reg.clone());
            }
            let results = session.eval_points(&points, threads, None);
            let stats = session.stats();
            (results, stats, None)
        }
        SearchKind::Halving(params) => {
            // Shape of the candidate space: the budget axis belongs to the
            // rung ladder, not the cross-product.
            let candidates = spec.candidate_spec();
            println!(
                "explore: halving (eta {}, objective {}): {} candidate(s) ({}) on {} thread(s)...",
                params.eta,
                params.objective.tag(),
                candidates.points().len(),
                candidates.shape(),
                threads
            );
            let outcome = search::run_halving_obs(
                spec,
                ctx,
                threads,
                disk.as_ref(),
                Some(&sink),
                params,
                None,
                obs_reg.clone(),
            )?;
            println!(
                "halving: {} evaluation(s) total, {} at full budget",
                outcome.total_evals(),
                outcome.full_budget_evals()
            );
            (outcome.results, outcome.stats, Some((params.clone(), outcome.rungs)))
        }
    };

    let trajectory = trajectory.as_ref().map(|(p, r)| (p, r.as_slice()));
    let (mut md, mut json, analyses) = report::render_report(spec, &results, trajectory);
    if let Some(reg) = &obs_reg {
        // Opt-in only: the profile section carries wall-clock data, so it
        // is appended *after* the run-invariant report body — default
        // reports (and the sharded-merge byte-identity contract) are
        // untouched.
        let (pmd, pjson) = report::profile_section(reg);
        md.push_str(&pmd);
        json.set("profile", pjson);
    }
    crate::experiments::common::emit("explore", "Design-space exploration", &md, &json);

    if sink.is_active() && sink.dropped() == 0 {
        // The journal is append-only across runs: report this run's span
        // so earlier runs' lines are not misattributed to this sweep.
        println!(
            "partial results: {} ({} line(s) this run, appended at line {})",
            sink.path().display(),
            sink.written(),
            sink.start_line()
        );
    } else {
        println!(
            "partial results: INCOMPLETE — {} record(s) dropped ({})",
            sink.dropped(),
            sink.path().display()
        );
    }
    println!(
        "cache: {} hit(s) ({} in-memory, {} disk metrics, {} rehydrated artifact(s)), \
         {} compile(s), {} extra context(s)",
        stats.total_hits(),
        stats.memory_hits,
        stats.disk_hits,
        stats.art_hits,
        stats.misses,
        stats.ctx_builds
    );
    if let Some(d) = &disk {
        let pinned = pin_survivors(d.artifacts(), spec, &ctx.arch, &results, &analyses);
        if pinned > 0 {
            println!("cache: pinned {pinned} frontier/knee artifact(s) against eviction");
        }
        if let Some(cap) = cache_cap {
            println!("cache gc: {}", d.artifacts().gc(cap).summary());
        }
        println!("{}", d.stat_string());
    }
    let failed: usize = analyses.iter().map(|a| a.failed.len()).sum();
    if failed > 0 {
        return Err(format!("{failed} point(s) failed to compile"));
    }
    Ok(())
}
