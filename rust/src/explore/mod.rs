//! Design-space exploration engine (`cascade explore`).
//!
//! Cascade's evaluation sweeps pipelining levels by hand (Fig. 7/10); the
//! paper's real promise — trading frequency against energy and resources —
//! is a design-space problem. This subsystem makes it one:
//!
//! * [`space`] — the declarative exploration grid ([`space::ExploreSpec`]):
//!   (app × pipelining level × placement alpha × PnR seed × post-PnR
//!   iteration budget), with axis builders and deterministic point
//!   enumeration.
//! * [`runner`] — a multi-threaded work-queue executor over
//!   `std::thread::scope` whose result order is independent of thread
//!   count and scheduling.
//! * [`cache`] — content-hash keyed artifact memoization: in-memory
//!   deduplication of effective-config collisions within a run, plus a
//!   persistent metrics cache under `results/explore_cache/` that repeat
//!   invocations (and `cascade exp summary`) reuse.
//! * [`pareto`] — n-dimensional dominance frontier and knee-point
//!   selection over (critical-path delay, EDP, pipelining registers).
//! * [`report`] — ranked markdown summary + deterministic JSON emission;
//!   byte-identical across cache-served re-runs.
//!
//! A Capstone-style `--power-cap` (mW) marks points whose estimated total
//! power exceeds the budget as infeasible before the frontier is computed.

pub mod cache;
pub mod pareto;
pub mod report;
pub mod runner;
pub mod space;

pub use cache::{ArtifactCache, DiskCache, PointMetrics};
pub use runner::{run, PointResult, RunOutcome};
pub use space::{ExplorePoint, ExploreSpec, Scale};

use crate::pipeline::CompileCtx;

/// CLI entry point: evaluate the grid, analyze, emit `results/explore.*`,
/// and print the cache traffic (stdout only — reports stay run-invariant).
pub fn run_cli(
    spec: &ExploreSpec,
    ctx: &CompileCtx,
    threads: usize,
    use_disk_cache: bool,
) -> Result<(), String> {
    spec.validate()?;
    let points = spec.points();
    println!(
        "explore: {} points ({}) on {} thread(s)...",
        points.len(),
        spec.shape(),
        threads.max(1)
    );
    let disk = if use_disk_cache { Some(DiskCache::open_default()) } else { None };
    let outcome = run(spec, ctx, threads, disk.as_ref());

    let analyses = report::analyze(spec, &outcome.results);
    let md = report::to_markdown(spec, &outcome.results, &analyses);
    let json = report::to_json(spec, &outcome.results, &analyses);
    crate::experiments::common::emit("explore", "Design-space exploration", &md, &json);

    println!(
        "cache: {} hit(s) ({} in-memory, {} disk), {} compile(s)",
        outcome.stats.total_hits(),
        outcome.stats.memory_hits,
        outcome.stats.disk_hits,
        outcome.stats.misses
    );
    let failed: usize = analyses.iter().map(|a| a.failed.len()).sum();
    if failed > 0 {
        return Err(format!("{failed} point(s) failed to compile"));
    }
    Ok(())
}
