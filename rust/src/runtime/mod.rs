//! PJRT golden-model runtime.
//!
//! The real implementation ([`pjrt`]) loads the AOT-compiled JAX/Pallas
//! golden models (`artifacts/*.hlo.txt`, produced once by `make artifacts`)
//! and executes them on the PJRT CPU client via the `xla` crate. It needs
//! the `xla` and `anyhow` crates, which are not vendored in the offline
//! build environment, so it is gated behind the `golden-pjrt` cargo
//! feature.
//!
//! With the feature off (the default), [`GoldenRuntime`] is a stub with the
//! same API surface that reports the runtime as unavailable: artifacts are
//! never found and every execution returns an error. The end-to-end example
//! and the integration tests already skip gracefully when artifacts are
//! missing, so the stub degrades them to a clean "artifacts missing" exit
//! instead of a link error.

#[cfg(feature = "golden-pjrt")]
mod pjrt;

#[cfg(feature = "golden-pjrt")]
pub use pjrt::{GoldenModel, GoldenRuntime};

#[cfg(not(feature = "golden-pjrt"))]
mod stub {
    use std::path::{Path, PathBuf};

    /// Error returned by every stub entry point.
    #[derive(Debug, Clone)]
    pub struct RuntimeUnavailable;

    impl std::fmt::Display for RuntimeUnavailable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(
                f,
                "PJRT golden runtime unavailable (build with --features golden-pjrt)"
            )
        }
    }

    impl std::error::Error for RuntimeUnavailable {}

    /// Stub standing in for a compiled golden model.
    pub struct GoldenModel {
        pub name: String,
    }

    /// Stub runtime: same API as the PJRT-backed one, but artifacts are
    /// never available.
    pub struct GoldenRuntime {
        artifacts_dir: PathBuf,
    }

    impl GoldenRuntime {
        pub fn new(dir: impl AsRef<Path>) -> Result<GoldenRuntime, RuntimeUnavailable> {
            Ok(GoldenRuntime { artifacts_dir: dir.as_ref().to_path_buf() })
        }

        pub fn from_repo_root() -> Result<GoldenRuntime, RuntimeUnavailable> {
            GoldenRuntime::new("artifacts")
        }

        /// Always false: without PJRT an artifact cannot be executed even
        /// if the HLO text exists on disk.
        pub fn has_artifact(&self, _name: &str) -> bool {
            let _ = &self.artifacts_dir;
            false
        }

        pub fn run_i32(
            &mut self,
            _name: &str,
            _input: &[i32],
        ) -> Result<Vec<i32>, RuntimeUnavailable> {
            Err(RuntimeUnavailable)
        }

        pub fn run_i32_2d(
            &mut self,
            _name: &str,
            _input: &[i32],
            _rows: usize,
            _cols: usize,
        ) -> Result<Vec<i32>, RuntimeUnavailable> {
            Err(RuntimeUnavailable)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_reports_unavailable() {
            let mut rt = GoldenRuntime::from_repo_root().unwrap();
            assert!(!rt.has_artifact("gaussian"));
            assert!(rt.run_i32("gaussian", &[1, 2, 3]).is_err());
            assert!(rt.run_i32_2d("resnet", &[0; 4], 2, 2).is_err());
        }
    }
}

#[cfg(not(feature = "golden-pjrt"))]
pub use stub::{GoldenModel, GoldenRuntime, RuntimeUnavailable};
