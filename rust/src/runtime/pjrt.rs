//! PJRT golden-model runtime.
//!
//! Loads the AOT-compiled JAX/Pallas golden models (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them on the PJRT CPU
//! client via the `xla` crate. Python never runs here — the HLO text is the
//! only thing that crosses the language boundary (text, not serialized
//! proto: jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids).
//!
//! The end-to-end example and integration tests use this to cross-check
//! the fabric simulator's outputs against the golden compute graphs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A compiled golden model.
pub struct GoldenModel {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// Runtime holding the PJRT client and a cache of compiled executables.
pub struct GoldenRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, GoldenModel>,
}

impl GoldenRuntime {
    /// Create a CPU PJRT runtime reading artifacts from `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<GoldenRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(GoldenRuntime {
            client,
            artifacts_dir: dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// Default artifacts location relative to the repo root.
    pub fn from_repo_root() -> Result<GoldenRuntime> {
        GoldenRuntime::new("artifacts")
    }

    /// Whether the artifact for `name` exists (lets tests skip gracefully
    /// before `make artifacts` has run).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifacts_dir.join(format!("{name}.hlo.txt"))
    }

    /// Load + compile a model (cached).
    pub fn load(&mut self, name: &str) -> Result<&GoldenModel> {
        if !self.cache.contains_key(name) {
            let path = self.artifact_path(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling golden model '{name}'"))?;
            self.cache
                .insert(name.to_string(), GoldenModel { exe, name: name.to_string() });
        }
        Ok(&self.cache[name])
    }

    /// Execute a single-input i32 model: `f(i32[n]) -> i32[m]`.
    pub fn run_i32(&mut self, name: &str, input: &[i32]) -> Result<Vec<i32>> {
        let model = self.load(name)?;
        let x = xla::Literal::vec1(input);
        let result = model.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1().context("golden models return 1-tuples")?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Execute a 2-D-input i32 model: `f(i32[r, c]) -> i32[p, q]` (row
    /// major; output flattened).
    pub fn run_i32_2d(
        &mut self,
        name: &str,
        input: &[i32],
        rows: usize,
        cols: usize,
    ) -> Result<Vec<i32>> {
        anyhow::ensure!(input.len() == rows * cols, "bad input length");
        let model = self.load(name)?;
        let x = xla::Literal::vec1(input).reshape(&[rows as i64, cols as i64])?;
        let result = model.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<GoldenRuntime> {
        let rt = GoldenRuntime::from_repo_root().ok()?;
        if rt.has_artifact("gaussian") {
            Some(rt)
        } else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn gaussian_golden_runs_and_matches_interp() {
        let Some(mut rt) = runtime() else { return };
        let n = 4096usize;
        let input: Vec<i32> = (0..n as i32).map(|x| (x * 7 + 5) % 31).collect();
        let golden = rt.run_i32("gaussian", &input).unwrap();
        assert_eq!(golden.len(), n);
        // Cross-check against the in-crate DFG interpreter.
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let mut ins = std::collections::BTreeMap::new();
        ins.insert(0u16, input.iter().map(|&v| v as i64).collect::<Vec<i64>>());
        let run = crate::dfg::interp::Interp::run(&app.dfg, &ins, n as u64);
        let interp = &run.outputs[&0];
        for t in 0..n {
            assert_eq!(golden[t] as i64, interp[t], "t={t}");
        }
    }

    #[test]
    fn all_dense_goldens_compile() {
        let Some(mut rt) = runtime() else { return };
        for name in ["gaussian", "unsharp", "camera", "harris"] {
            let out = rt.run_i32(name, &vec![1i32; 4096]).unwrap();
            assert_eq!(out.len(), 4096, "{name}");
        }
        let out = rt.run_i32_2d("resnet", &vec![1i32; 4 * 64 * 18], 4, 64 * 18).unwrap();
        assert_eq!(out.len(), 2 * 64);
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.run_i32("no_such_model", &[0]).is_err());
    }
}
