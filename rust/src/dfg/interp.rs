//! Cycle-accurate functional interpreter for (dense) DFGs.
//!
//! Reference semantics for the statically scheduled fabric: every node
//! produces one value per cycle; registered elements (Delay, Rom, Accum,
//! PE input registers, edge pipeline registers) update at cycle boundaries.
//! The interpreter is the in-crate golden model: integration tests check
//! the bitstream-level fabric simulator against it, and the pipelining
//! passes are verified to preserve function up to a uniform latency shift.

use std::collections::VecDeque;

use crate::arch::canal::Layer;

use super::ir::{AluOp, Dfg, NodeId, Op};

/// Input-port slots per node in the flat edge lookup: 4 ports x 2 layers.
const PORT_SLOTS: usize = 8;

#[inline]
fn slot_of(node: NodeId, port: u8, layer: Layer) -> usize {
    node as usize * PORT_SLOTS + (port as usize) * 2 + layer.index()
}

/// Per-node interpreter state.
enum NodeState {
    None,
    Delay(VecDeque<i64>),
    Rom { counter: u64 },
    /// `start` is the §V-F schedule offset: the added-latency arrival of
    /// the accumulator's input, so reduction windows align with the
    /// pipelined data stream. `out` holds the last completed window total.
    Accum { acc: i64, t: u64, start: u64, out: i64 },
    InRegs([i64; 2]),
}

/// Interpreter over a DFG. Sparse nodes are rejected — use
/// `sim::sparse` for ready-valid graphs.
pub struct Interp<'a> {
    g: &'a Dfg,
    order: Vec<NodeId>,
    state: Vec<NodeState>,
    edge_q: Vec<VecDeque<i64>>,
    /// Flat (node, port, layer) -> edge index lookup (hot path; sentinel
    /// u32::MAX = unconnected).
    edge_of: Vec<u32>,
    /// Current-cycle output value per node.
    value: Vec<i64>,
    cycle: u64,
}

/// Result of running the interpreter.
pub struct InterpRun {
    /// Output samples per output lane (every cycle, pre-decimation trim by
    /// the caller using `Output::decimate`).
    pub outputs: std::collections::BTreeMap<u16, Vec<i64>>,
    pub cycles: u64,
}

impl<'a> Interp<'a> {
    pub fn new(g: &'a Dfg) -> Interp<'a> {
        assert!(
            !g.nodes.iter().any(|n| n.is_sparse()),
            "Interp handles statically scheduled graphs; use sim::sparse for sparse apps"
        );
        // Schedule offsets (§V-F): accumulators begin counting when their
        // (pipelining-delayed) input stream starts.
        let added = crate::pipeline::bdm::added_arrival_cycles(g);
        let accum_start = |i: usize| -> u64 {
            g.edges
                .iter()
                .filter(|e| e.dst == i as NodeId && e.dst_port == 0 && e.layer == Layer::B16)
                .map(|e| added[e.src as usize] + e.regs as u64)
                .max()
                .unwrap_or(0)
        };
        let state = g
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| match &n.op {
                Op::Delay { cycles, .. } => {
                    NodeState::Delay(VecDeque::from(vec![0i64; *cycles as usize]))
                }
                Op::Rom { .. } => NodeState::Rom { counter: 0 },
                Op::Accum { .. } => {
                    NodeState::Accum { acc: 0, t: 0, start: accum_start(i), out: 0 }
                }
                Op::Alu { .. } | Op::Fused { .. } => NodeState::InRegs([0, 0]),
                _ => NodeState::None,
            })
            .collect();
        let edge_q = g
            .edges
            .iter()
            .map(|e| VecDeque::from(vec![0i64; e.regs as usize]))
            .collect();
        let mut edge_of = vec![u32::MAX; g.nodes.len() * PORT_SLOTS];
        for (ei, e) in g.edges.iter().enumerate() {
            edge_of[slot_of(e.dst, e.dst_port, e.layer)] = ei as u32;
        }
        Interp {
            g,
            order: g.topo_order(),
            state,
            edge_q,
            edge_of,
            value: vec![0; g.nodes.len()],
            cycle: 0,
        }
    }

    /// Value arriving at `(dst, port, layer)` this cycle: the edge queue
    /// front if the edge is registered, else the driver's current value.
    fn input_val(&self, dst: NodeId, port: u8, layer: Layer) -> i64 {
        let ei = self.edge_of[slot_of(dst, port, layer)];
        if ei == u32::MAX {
            return 0;
        }
        let e = &self.g.edges[ei as usize];
        if e.regs > 0 {
            *self.edge_q[ei as usize].front().unwrap()
        } else {
            self.value[e.src as usize]
        }
    }

    /// Advance one cycle given the input streams (indexed by lane; cycles
    /// beyond the stream length read 0).
    pub fn step(&mut self, inputs: &std::collections::BTreeMap<u16, Vec<i64>>) {
        let t = self.cycle;
        // Phase 1: compute all node outputs in topo order.
        for &n in &self.order {
            let node = &self.g.nodes[n as usize];
            let v = match &node.op {
                Op::Input { lane } => inputs
                    .get(lane)
                    .and_then(|s| s.get(t as usize))
                    .copied()
                    .unwrap_or(0),
                Op::Output { .. } => self.input_val(n, 0, Layer::B16),
                Op::Const { value } => *value,
                Op::FlushSrc => i64::from(t == 0),
                Op::Alu { op, const_b } => {
                    let (a, b) = if node.input_regs {
                        match &self.state[n as usize] {
                            NodeState::InRegs(r) => (r[0], r[1]),
                            _ => unreachable!(),
                        }
                    } else {
                        (
                            self.input_val(n, 0, Layer::B16),
                            const_b.unwrap_or_else(|| self.input_val(n, 1, Layer::B16)),
                        )
                    };
                    let b = if node.input_regs {
                        const_b.unwrap_or(b)
                    } else {
                        b
                    };
                    let sel = self.input_val(n, 0, Layer::B1);
                    op.eval(a, b, if *op == AluOp::Mux { sel } else { 0 })
                }
                Op::Fused { ops } => {
                    // Same operand plumbing as `Alu` for the head step
                    // (ports / input registers / head immediate), then the
                    // tail folds in combinationally within the same cycle.
                    let head_cb = ops[0].const_b;
                    let (a, b) = if node.input_regs {
                        match &self.state[n as usize] {
                            NodeState::InRegs(r) => (r[0], head_cb.unwrap_or(r[1])),
                            _ => unreachable!(),
                        }
                    } else {
                        (
                            self.input_val(n, 0, Layer::B16),
                            head_cb.unwrap_or_else(|| self.input_val(n, 1, Layer::B16)),
                        )
                    };
                    super::ir::eval_fused(ops, a, b)
                }
                Op::Delay { .. } => match &self.state[n as usize] {
                    NodeState::Delay(q) => q.front().copied().unwrap_or_else(|| {
                        // zero-length delay: combinational pass
                        self.input_val(n, 0, Layer::B16)
                    }),
                    _ => unreachable!(),
                },
                Op::Rom { values } => match &self.state[n as usize] {
                    // The schedule starts the address generator one cycle
                    // early (start_offset = arrival - 1, §V-F) so word k is
                    // on the output during execution cycle k.
                    NodeState::Rom { counter } => values[(*counter as usize) % values.len()],
                    _ => unreachable!(),
                },
                Op::Accum { .. } => match &self.state[n as usize] {
                    // Registered window total (§V-F-aligned).
                    NodeState::Accum { out, .. } => *out,
                    _ => unreachable!(),
                },
                Op::Sparse(_) => unreachable!(),
            };
            self.value[n as usize] = v;
        }
        // Phase 2: update registered state with current-cycle inputs.
        for &n in &self.order {
            let node = &self.g.nodes[n as usize];
            match &node.op {
                Op::Delay { cycles, .. } if *cycles > 0 => {
                    let vin = self.input_val(n, 0, Layer::B16);
                    if let NodeState::Delay(q) = &mut self.state[n as usize] {
                        q.push_back(vin);
                        q.pop_front();
                    }
                }
                Op::Rom { .. } => {
                    if let NodeState::Rom { counter } = &mut self.state[n as usize] {
                        *counter += 1;
                    }
                }
                Op::Accum { period } => {
                    let a = self.input_val(n, 0, Layer::B16);
                    let has_b = self
                        .g
                        .edges
                        .iter()
                        .any(|e| e.dst == n && e.dst_port == 1 && e.layer == Layer::B16);
                    let b = if has_b { self.input_val(n, 1, Layer::B16) } else { 1 };
                    let cycle = self.cycle;
                    if let NodeState::Accum { acc, t: nt, start, out } =
                        &mut self.state[n as usize]
                    {
                        if cycle >= *start {
                            *acc += a * b;
                            *nt += 1;
                            if *period > 0 && *nt % (*period as u64) == 0 {
                                *out = *acc;
                                *acc = 0;
                            }
                        }
                    }
                }
                Op::Alu { .. } | Op::Fused { .. } if node.input_regs => {
                    let a = self.input_val(n, 0, Layer::B16);
                    let b = self.input_val(n, 1, Layer::B16);
                    if let NodeState::InRegs(r) = &mut self.state[n as usize] {
                        *r = [a, b];
                    }
                }
                _ => {}
            }
        }
        // Edge pipeline registers shift (they sample the driver's
        // current-cycle value).
        for (ei, e) in self.g.edges.iter().enumerate() {
            if e.regs > 0 {
                let v = self.value[e.src as usize];
                self.edge_q[ei].push_back(v);
                self.edge_q[ei].pop_front();
            }
        }
        self.cycle += 1;
    }

    /// Current output value of a node.
    pub fn node_value(&self, n: NodeId) -> i64 {
        self.value[n as usize]
    }

    /// Run for `cycles`, recording every Output node's stream.
    pub fn run(
        g: &'a Dfg,
        inputs: &std::collections::BTreeMap<u16, Vec<i64>>,
        cycles: u64,
    ) -> InterpRun {
        let mut it = Interp::new(g);
        let outputs_nodes: Vec<(u16, NodeId)> = g
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n.op {
                Op::Output { lane, .. } => Some((lane, i as NodeId)),
                _ => None,
            })
            .collect();
        let mut outputs: std::collections::BTreeMap<u16, Vec<i64>> =
            outputs_nodes.iter().map(|&(l, _)| (l, Vec::new())).collect();
        for _ in 0..cycles {
            it.step(inputs);
            for &(lane, node) in &outputs_nodes {
                outputs.get_mut(&lane).unwrap().push(it.node_value(node));
            }
        }
        InterpRun { outputs, cycles }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::build::{stencil, stencil_window_delay};
    use crate::dfg::ir::Dfg;
    use std::collections::BTreeMap;

    fn run_lane0(g: &Dfg, input: Vec<i64>, cycles: u64) -> Vec<i64> {
        let mut m = BTreeMap::new();
        m.insert(0u16, input);
        Interp::run(g, &m, cycles).outputs.remove(&0).unwrap()
    }

    #[test]
    fn passthrough_identity() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "out");
        g.connect(i, o, 0);
        let out = run_lane0(&g, vec![1, 2, 3], 3);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn alu_chain_combinational() {
        // out = (in * 2) + 3, zero latency when unpipelined.
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(2) }, "m");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: Some(3) }, "a");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, m, 0);
        g.connect(m, a, 0);
        g.connect(a, o, 0);
        let out = run_lane0(&g, vec![1, 2, 3], 3);
        assert_eq!(out, vec![5, 7, 9]);
    }

    #[test]
    fn input_regs_add_one_cycle() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(2) }, "m");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, m, 0);
        g.connect(m, o, 0);
        g.node_mut(m).input_regs = true;
        let out = run_lane0(&g, vec![1, 2, 3], 4);
        assert_eq!(out, vec![0, 2, 4, 6]);
    }

    #[test]
    fn edge_regs_delay() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        let e = g.connect(i, o, 0);
        g.edge_mut(e).regs = 2;
        let out = run_lane0(&g, vec![5, 6, 7], 5);
        assert_eq!(out, vec![0, 0, 5, 6, 7]);
    }

    #[test]
    fn delay_node_semantics() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let d = g.add_node(Op::Delay { cycles: 3, pipelined: false }, "d");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, d, 0);
        g.connect(d, o, 0);
        let out = run_lane0(&g, vec![1, 2, 3, 4, 5], 5);
        assert_eq!(out, vec![0, 0, 0, 1, 2]);
    }

    #[test]
    fn rom_plays_registered() {
        let mut g = Dfg::new();
        let r = g.add_node(Op::Rom { values: vec![10, 20, 30] }, "rom");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(r, o, 0);
        // The schedule starts the generator one cycle early, so word k is
        // on the output during execution cycle k.
        let out = run_lane0(&g, vec![], 5);
        assert_eq!(out, vec![10, 20, 30, 10, 20]);
    }

    #[test]
    fn accum_mac_with_period() {
        // acc over pairs a*b with period 2.
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "a");
        let r = g.add_node(Op::Rom { values: vec![1, 1] }, "b");
        let acc = g.add_node(Op::Accum { period: 2 }, "acc");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, acc, 0);
        g.connect(r, acc, 1);
        g.connect(acc, o, 0);
        // b stream (schedule-aligned rom) = 1,1,1,...; a = 4,5,6,7.
        // Window totals (period 2): 4+5=9 completed at end of t1, visible
        // from t2; 6+7=13 completed at end of t3.
        let out = run_lane0(&g, vec![4, 5, 6, 7], 5);
        assert_eq!(out, vec![0, 0, 9, 9, 13]);
    }

    // -----------------------------------------------------------------
    // Per-Op semantic pins: the interpreter is the differential-
    // equivalence oracle for the fusion pass (tests/fuse.rs), so every
    // variant's behaviour — including edge values — is pinned here.
    // -----------------------------------------------------------------

    #[test]
    fn const_node_value_every_cycle() {
        let mut g = Dfg::new();
        let c = g.add_node(Op::Const { value: -42 }, "c");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(c, o, 0);
        assert_eq!(run_lane0(&g, vec![], 3), vec![-42, -42, -42]);
    }

    #[test]
    fn flush_src_pulses_only_at_cycle_zero() {
        let mut g = Dfg::new();
        let f = g.add_node(Op::FlushSrc, "flush");
        let p = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, "p");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(f, p, 0);
        g.connect(p, o, 0);
        assert_eq!(run_lane0(&g, vec![], 4), vec![1, 0, 0, 0]);
    }

    #[test]
    fn alu_edge_values_16bit_boundaries() {
        // The reference model is exact i64 arithmetic (no 16-bit wrap):
        // values past the word boundary stay exact, which is what the
        // equivalence harness compares against.
        let unary = |op: AluOp, a: i64| {
            let mut g = Dfg::new();
            let i = g.add_node(Op::Input { lane: 0 }, "in");
            let u = g.add_node(Op::Alu { op, const_b: None }, "u");
            let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
            g.connect(i, u, 0);
            g.connect(u, o, 0);
            run_lane0(&g, vec![a], 1)[0]
        };
        assert_eq!(unary(AluOp::Abs, -32768), 32768);
        assert_eq!(unary(AluOp::Abs, i64::MIN + 1), i64::MAX);
        assert_eq!(unary(AluOp::Pass, -7), -7);

        let binary = |op: AluOp, a: i64, b: i64| {
            let mut g = Dfg::new();
            let i = g.add_node(Op::Input { lane: 0 }, "in");
            let u = g.add_node(Op::Alu { op, const_b: Some(b) }, "u");
            let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
            g.connect(i, u, 0);
            g.connect(u, o, 0);
            run_lane0(&g, vec![a], 1)[0]
        };
        assert_eq!(binary(AluOp::Mul, 32767, 32767), 1073676289); // > 16 bits, exact
        assert_eq!(binary(AluOp::Add, i64::MAX - 1, 1), i64::MAX);
        assert_eq!(binary(AluOp::Sub, -32768, 1), -32769);
        // Shift amounts are masked to 4 bits (the PE barrel shifter).
        assert_eq!(binary(AluOp::Shl, 1, 16), 1); // 16 & 15 == 0
        assert_eq!(binary(AluOp::Shl, 1, 15), 32768);
        // Shr is arithmetic: sign-extends negatives.
        assert_eq!(binary(AluOp::Shr, -8, 1), -4);
        assert_eq!(binary(AluOp::Shr, -1, 15), -1);
        assert_eq!(binary(AluOp::Min, -5, 5), -5);
        assert_eq!(binary(AluOp::Max, -5, 5), 5);
        assert_eq!(binary(AluOp::And, 0b1100, 0b1010), 0b1000);
        assert_eq!(binary(AluOp::Or, 0b1100, 0b1010), 0b1110);
        assert_eq!(binary(AluOp::Xor, 0b1100, 0b1010), 0b0110);
        assert_eq!(binary(AluOp::Gte, 5, 5), 1);
        assert_eq!(binary(AluOp::Gte, 4, 5), 0);
        assert_eq!(binary(AluOp::Lte, 4, 5), 1);
        assert_eq!(binary(AluOp::Eq, -3, -3), 1);
        assert_eq!(binary(AluOp::Eq, -3, 3), 0);
        // Mac as a plain ALU op has no accumulator state: acc input is 0.
        assert_eq!(binary(AluOp::Mac, 6, 7), 42);
    }

    #[test]
    fn mux_selects_via_b1_layer() {
        let mut g = Dfg::new();
        let a = g.add_node(Op::Input { lane: 0 }, "a");
        let b = g.add_node(Op::Input { lane: 1 }, "b");
        let s = g.add_node(Op::Input { lane: 2 }, "sel");
        // Selector feeds the comparator whose B1 output drives the mux.
        let cmp = g.add_node(Op::Alu { op: AluOp::Gte, const_b: Some(1) }, "cmp");
        let mux = g.add_node(Op::Alu { op: AluOp::Mux, const_b: None }, "mux");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(s, cmp, 0);
        g.connect(a, mux, 0);
        g.connect(b, mux, 1);
        g.add_edge(cmp, mux, 0, Layer::B1);
        g.connect(mux, o, 0);
        let mut m = BTreeMap::new();
        m.insert(0u16, vec![10, 10, 10]);
        m.insert(1u16, vec![20, 20, 20]);
        m.insert(2u16, vec![0, 1, 0]);
        let out = Interp::run(&g, &m, 3).outputs.remove(&0).unwrap();
        assert_eq!(out, vec![10, 20, 10]);
    }

    #[test]
    fn zero_length_delay_is_combinational() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let d = g.add_node(Op::Delay { cycles: 0, pipelined: false }, "d0");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, d, 0);
        g.connect(d, o, 0);
        assert_eq!(run_lane0(&g, vec![9, 8, 7], 3), vec![9, 8, 7]);
    }

    #[test]
    fn inputs_past_stream_end_read_zero() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, o, 0);
        assert_eq!(run_lane0(&g, vec![1], 3), vec![1, 0, 0]);
    }

    #[test]
    fn accum_without_b_input_sums_a() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "a");
        let acc = g.add_node(Op::Accum { period: 3 }, "acc");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, acc, 0);
        g.connect(acc, o, 0);
        // Window 1+2+3=6 completes at end of t2, visible from t3.
        assert_eq!(run_lane0(&g, vec![1, 2, 3, 4], 5), vec![0, 0, 0, 6, 6]);
    }

    #[test]
    fn fused_node_matches_unfused_chain() {
        // in -> mul(*3) -> shr(>>1) -> add(+5) as separate ALUs vs one
        // compound: identical streams cycle for cycle.
        let input: Vec<i64> = vec![0, 1, -2, 32767, -32768, 13];
        let mut chain = Dfg::new();
        let i = chain.add_node(Op::Input { lane: 0 }, "in");
        let m = chain.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(3) }, "m");
        let s = chain.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(1) }, "s");
        let a = chain.add_node(Op::Alu { op: AluOp::Add, const_b: Some(5) }, "a");
        let o = chain.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        chain.connect(i, m, 0);
        chain.connect(m, s, 0);
        chain.connect(s, a, 0);
        chain.connect(a, o, 0);

        let mut fused = Dfg::new();
        let fi = fused.add_node(Op::Input { lane: 0 }, "in");
        let f = fused.add_node(
            Op::Fused {
                ops: vec![
                    crate::dfg::FusedStep { op: AluOp::Mul, const_b: Some(3) },
                    crate::dfg::FusedStep { op: AluOp::Shr, const_b: Some(1) },
                    crate::dfg::FusedStep { op: AluOp::Add, const_b: Some(5) },
                ],
            },
            "m+s+a",
        );
        let fo = fused.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        fused.connect(fi, f, 0);
        fused.connect(f, fo, 0);

        let n = input.len() as u64;
        assert_eq!(
            run_lane0(&chain, input.clone(), n),
            run_lane0(&fused, input.clone(), n)
        );

        // With input registers the compound delays one cycle, like an ALU.
        fused.node_mut(f).input_regs = true;
        let reg = run_lane0(&fused, input.clone(), n + 1);
        let plain = run_lane0(&chain, input, n);
        assert_eq!(&reg[1..], &plain[..]);
    }

    #[test]
    fn fused_head_port1_operand() {
        // Head takes a real port-1 operand (no immediate); tail adds 1.
        let mut g = Dfg::new();
        let a = g.add_node(Op::Input { lane: 0 }, "a");
        let b = g.add_node(Op::Input { lane: 1 }, "b");
        let f = g.add_node(
            Op::Fused {
                ops: vec![
                    crate::dfg::FusedStep { op: AluOp::Sub, const_b: None },
                    crate::dfg::FusedStep { op: AluOp::Abs, const_b: None },
                ],
            },
            "sub+abs",
        );
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(a, f, 0);
        g.connect(b, f, 1);
        g.connect(f, o, 0);
        let mut m = BTreeMap::new();
        m.insert(0u16, vec![3, 10]);
        m.insert(1u16, vec![8, 4]);
        let out = Interp::run(&g, &m, 2).outputs.remove(&0).unwrap();
        assert_eq!(out, vec![5, 6]);
    }

    #[test]
    fn stencil_computes_convolution() {
        let width = 8u32;
        let w = vec![vec![1, 2, 1], vec![2, 4, 2], vec![1, 2, 1]];
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let s = stencil(&mut g, i, width, &w, "gauss");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(s, o, 0);
        assert!(g.validate().is_empty());

        let n = 64usize;
        let input: Vec<i64> = (0..n as i64).map(|x| (x * 7 + 3) % 13).collect();
        let out = run_lane0(&g, input.clone(), n as u64);
        // Expected: out(t) = sum w[r][c] * in(t - ((2-r)*width + (2-c)))
        // i.e. the tap at delay r*width+c carries in(t - (r*W+c)); the
        // stencil weight applied to that tap is w[r][c].
        let wd = stencil_window_delay(width, 3) as usize;
        for t in wd..n {
            let mut exp = 0i64;
            for r in 0..3usize {
                for c in 0..3usize {
                    let d = r * width as usize + c;
                    exp += w[r][c] * input[t - d];
                }
            }
            assert_eq!(out[t], exp, "mismatch at t={t}");
        }
    }

    #[test]
    fn pipelining_shifts_output_uniformly() {
        // Adding balanced edge registers must produce the same stream
        // delayed by k cycles.
        let width = 8u32;
        let w = vec![vec![1, 1], vec![1, 1]];
        let build = |regs: u32| {
            let mut g = Dfg::new();
            let i = g.add_node(Op::Input { lane: 0 }, "in");
            let s = stencil(&mut g, i, width, &w, "s");
            let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
            let e = g.connect(s, o, 0);
            g.edge_mut(e).regs = regs;
            g
        };
        let input: Vec<i64> = (0..40).map(|x| x * x % 17).collect();
        let g0 = build(0);
        let g2 = build(2);
        let o0 = run_lane0(&g0, input.clone(), 40);
        let o2 = run_lane0(&g2, input.clone(), 40);
        assert_eq!(&o0[..38], &o2[2..]);
    }
}
