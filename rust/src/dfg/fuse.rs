//! Op fusion: collapse chains of single-fanout ALU ops into compound PE
//! ops ([`Op::Fused`]) before mapping.
//!
//! Every placed node costs placement/routing time in the sweep and
//! pipeline registers in the result, so fusing a chain of cheap ALU steps
//! into one PE shrinks both the PnR problem and the register bill.
//! Legality is strictly structural — the pass must be a pure refinement
//! of the graph's semantics:
//!
//! * both endpoints are plain [`Op::Alu`] nodes — never MEM nodes
//!   (`Delay`/`Rom`), never sparse (ready-valid) nodes, never IO;
//! * neither op is `Mux` or `Mac` (they read extra state — the B1
//!   selector / the accumulator — that the chained core does not carry);
//! * the producer has fanout exactly 1 (fusing across a multi-fanout
//!   edge would duplicate work or change visible values);
//! * the consumer's *only* in-edge is the chain edge on data port 0
//!   (its second operand, if any, is an immediate), so the fused tail
//!   step is self-contained;
//! * the chain edge is a bare B16 wire: no registers, no FIFOs — the
//!   pass runs before pipelining, so this is true by construction and
//!   checked defensively;
//! * at most [`MAX_FUSED_OPS`] steps per compound, matching what the
//!   bitstream encoding of a fused PE can carry.
//!
//! Fusion changes the mapping, not the function: fused and unfused
//! compiles are *semantically* equivalent (identical interpreter and
//! simulator outputs) but not byte-identical — artifacts from the two
//! modes are not interchangeable (see `docs/fusion.md`, in deliberate
//! contrast with the byte-identity contract of `docs/performance.md`).

use super::ir::{AluOp, Dfg, FusedStep, Node, NodeId, Op};
use crate::arch::canal::Layer;

/// Maximum ALU steps per compound op (the fused-PE bitstream encoding
/// carries the tail in MEM-param words; 4 steps fit comfortably).
pub const MAX_FUSED_OPS: usize = 4;

/// What the pass did, for `--profile` / report visibility.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FuseReport {
    /// Number of compound nodes created.
    pub chains: usize,
    /// Total ALU nodes absorbed into compounds (≥ 2 per chain).
    pub nodes_fused: usize,
    /// Net node-count reduction (`nodes_fused - chains`).
    pub nodes_removed: usize,
}

/// Is `op` a plain ALU node whose op may participate in a chain?
fn fusible_alu(node: &Node) -> Option<(AluOp, Option<i64>)> {
    match &node.op {
        Op::Alu { op, const_b } if !matches!(op, AluOp::Mux | AluOp::Mac) => {
            Some((*op, *const_b))
        }
        _ => None,
    }
}

/// Can the single out-edge `e` of `src` be fused into `dst` as a tail
/// step? See the module doc for the rule list.
fn link_fusible(g: &Dfg, fanout: &[u32], e: &super::ir::Edge) -> bool {
    let src = g.node(e.src);
    let dst = g.node(e.dst);
    if fusible_alu(src).is_none() || fusible_alu(dst).is_none() {
        return false;
    }
    if src.input_regs || dst.input_regs {
        return false; // pass runs pre-pipelining; don't move registers
    }
    if fanout[e.src as usize] != 1 {
        return false;
    }
    if e.layer != Layer::B16 || e.dst_port != 0 || e.regs != 0 || e.fifos != 0 {
        return false;
    }
    // dst must take its entire input from the chain: exactly one in-edge.
    g.in_edges(e.dst).len() == 1
}

/// Run the fusion pass in place. Returns a report of what was fused.
pub fn fuse_chains(g: &mut Dfg) -> FuseReport {
    let fanout = g.fanout_counts();
    // next[i] = j if i's single out-edge fuses into j.
    let mut next: Vec<Option<NodeId>> = vec![None; g.nodes.len()];
    let mut prev_fusible = vec![false; g.nodes.len()];
    for e in &g.edges {
        if link_fusible(g, &fanout, e) {
            next[e.src as usize] = Some(e.dst);
            prev_fusible[e.dst as usize] = true;
        }
    }
    // Walk maximal chains from their heads, splitting greedily at
    // MAX_FUSED_OPS; groups of length >= 2 become compounds.
    let mut group_of: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let mut groups: Vec<Vec<NodeId>> = Vec::new();
    for head in 0..g.nodes.len() as NodeId {
        if prev_fusible[head as usize] || next[head as usize].is_none() {
            continue; // not a chain head
        }
        let mut run: Vec<NodeId> = vec![head];
        let mut cur = head;
        while let Some(n) = next[cur as usize] {
            run.push(n);
            cur = n;
        }
        for chunk in run.chunks(MAX_FUSED_OPS) {
            if chunk.len() < 2 {
                continue;
            }
            let gi = groups.len();
            for &m in chunk {
                group_of[m as usize] = Some(gi);
            }
            groups.push(chunk.to_vec());
        }
    }
    if groups.is_empty() {
        return tally(FuseReport::default());
    }

    // Rebuild: one Fused node per group, clones for everything else.
    let mut out = Dfg::new();
    let mut new_id: Vec<Option<NodeId>> = vec![None; g.nodes.len()];
    for (i, node) in g.nodes.iter().enumerate() {
        match group_of[i] {
            // Only the group head materializes the compound.
            Some(gi) if groups[gi][0] == i as NodeId => {
                let members = &groups[gi];
                let ops: Vec<FusedStep> = members
                    .iter()
                    .map(|&m| {
                        let (op, const_b) = fusible_alu(g.node(m)).unwrap();
                        FusedStep { op, const_b }
                    })
                    .collect();
                let name = members
                    .iter()
                    .map(|&m| g.node(m).name.as_str())
                    .collect::<Vec<_>>()
                    .join("+");
                let id = out.add_node(Op::Fused { ops }, name);
                for &m in members {
                    new_id[m as usize] = Some(id);
                }
            }
            // Tail member: its new id is assigned when the head is
            // visited (the head always materializes the compound for all
            // members, whatever the id order).
            Some(_) => {}
            None => {
                let id = out.add_node(node.op.clone(), node.name.clone());
                out.node_mut(id).input_regs = node.input_regs;
                new_id[i] = Some(id);
            }
        }
    }
    for e in &g.edges {
        let internal = matches!(
            (group_of[e.src as usize], group_of[e.dst as usize]),
            (Some(a), Some(b)) if a == b
        );
        if internal {
            continue;
        }
        let src = new_id[e.src as usize].expect("src mapped");
        let dst = new_id[e.dst as usize].expect("dst mapped");
        let id = out.add_edge(src, dst, e.dst_port, e.layer);
        out.edge_mut(id).regs = e.regs;
        out.edge_mut(id).fifos = e.fifos;
    }

    let nodes_fused: usize = groups.iter().map(Vec::len).sum();
    let report = FuseReport {
        chains: groups.len(),
        nodes_fused,
        nodes_removed: nodes_fused - groups.len(),
    };
    *g = out;
    tally(report)
}

/// Mirror a [`FuseReport`] into the kernel-counter sink (a no-op unless
/// one is installed — see `docs/observability.md`). Returns the report
/// unchanged so both exits of [`fuse_chains`] stay one expression.
fn tally(report: FuseReport) -> FuseReport {
    crate::obs::counters::bump("fuse_chains", report.chains as u64);
    crate::obs::counters::bump("fuse_nodes_fused", report.nodes_fused as u64);
    crate::obs::counters::bump("fuse_nodes_removed", report.nodes_removed as u64);
    report
}

/// Inverse of [`fuse_chains`]: expand every compound back into its ALU
/// chain. Node ids differ from the pre-fusion graph, but the node and
/// edge multisets (keyed by name/shape) are identical — the property
/// test relies on this.
pub fn unfuse(g: &Dfg) -> Dfg {
    let mut out = Dfg::new();
    // first/last new id per old node (differ only for compounds).
    let mut first_id: Vec<NodeId> = Vec::with_capacity(g.nodes.len());
    let mut last_id: Vec<NodeId> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        match &node.op {
            Op::Fused { ops } => {
                let names: Vec<&str> = node.name.split('+').collect();
                let mut ids = Vec::with_capacity(ops.len());
                for (k, s) in ops.iter().enumerate() {
                    let name = names.get(k).copied().unwrap_or("fused");
                    let id = out.add_node(
                        Op::Alu { op: s.op, const_b: s.const_b },
                        name.to_string(),
                    );
                    // Only the head inherits input registers.
                    out.node_mut(id).input_regs = k == 0 && node.input_regs;
                    if k > 0 {
                        out.connect(ids[k - 1], id, 0);
                    }
                    ids.push(id);
                }
                first_id.push(ids[0]);
                last_id.push(*ids.last().unwrap());
            }
            _ => {
                let id = out.add_node(node.op.clone(), node.name.clone());
                out.node_mut(id).input_regs = node.input_regs;
                first_id.push(id);
                last_id.push(id);
            }
        }
    }
    for e in &g.edges {
        let id = out.add_edge(
            last_id[e.src as usize],
            first_id[e.dst as usize],
            e.dst_port,
            e.layer,
        );
        out.edge_mut(id).regs = e.regs;
        out.edge_mut(id).fifos = e.fifos;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_dfg() -> Dfg {
        // in -> mul(*3) -> shr(>>1) -> add -> out ; in2 -> add port 1
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let i2 = g.add_node(Op::Input { lane: 1 }, "in2");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(3) }, "mul");
        let s = g.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(1) }, "shr");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: None }, "add");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "out");
        g.connect(i, m, 0);
        g.connect(m, s, 0);
        g.connect(s, a, 0);
        g.connect(i2, a, 1);
        g.connect(a, o, 0);
        g
    }

    #[test]
    fn fuses_simple_chain() {
        let mut g = chain_dfg();
        let before = g.nodes.len();
        let r = fuse_chains(&mut g);
        // mul+shr fuse; add has two in-edges so it stays the compound's
        // consumer rather than a tail step.
        assert_eq!(r.chains, 1);
        assert_eq!(r.nodes_fused, 2);
        assert_eq!(g.nodes.len(), before - 1);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        let fused: Vec<&Node> = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Fused { .. }))
            .collect();
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].name, "mul+shr");
    }

    #[test]
    fn never_fuses_across_multi_fanout() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(2) }, "m");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: Some(1) }, "a");
        let b = g.add_node(Op::Alu { op: AluOp::Sub, const_b: Some(1) }, "b");
        let o1 = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o1");
        let o2 = g.add_node(Op::Output { lane: 1, decimate: 1 }, "o2");
        g.connect(i, m, 0);
        g.connect(m, a, 0); // m has fanout 2
        g.connect(m, b, 0);
        g.connect(a, o1, 0);
        g.connect(b, o2, 0);
        let n = g.nodes.len();
        let r = fuse_chains(&mut g);
        assert_eq!(r, FuseReport::default());
        assert_eq!(g.nodes.len(), n);
    }

    #[test]
    fn never_fuses_across_mem_nodes() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(2) }, "m");
        let d = g.add_node(Op::Delay { cycles: 64, pipelined: false }, "lb");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: Some(1) }, "a");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, m, 0);
        g.connect(m, d, 0);
        g.connect(d, a, 0);
        g.connect(a, o, 0);
        let r = fuse_chains(&mut g);
        assert_eq!(r, FuseReport::default());
    }

    #[test]
    fn never_fuses_mux_or_mac() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(2) }, "m");
        let x = g.add_node(Op::Alu { op: AluOp::Mux, const_b: Some(7) }, "mux");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, m, 0);
        g.connect(m, x, 0);
        g.connect(x, o, 0);
        let r = fuse_chains(&mut g);
        assert_eq!(r, FuseReport::default());
    }

    #[test]
    fn long_chain_splits_at_cap() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let mut prev = i;
        for k in 0..6 {
            let n = g.add_node(
                Op::Alu { op: AluOp::Add, const_b: Some(k) },
                format!("a{k}"),
            );
            g.connect(prev, n, 0);
            prev = n;
        }
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(prev, o, 0);
        let r = fuse_chains(&mut g);
        assert_eq!(r.chains, 2); // 4 + 2
        assert_eq!(r.nodes_fused, 6);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        for n in &g.nodes {
            if let Op::Fused { ops } = &n.op {
                assert!(ops.len() >= 2 && ops.len() <= MAX_FUSED_OPS);
            }
        }
    }

    #[test]
    fn unfuse_round_trips_names_and_shapes() {
        let orig = chain_dfg();
        let mut fused = orig.clone();
        fuse_chains(&mut fused);
        let back = unfuse(&fused);
        let key = |g: &Dfg| {
            let mut nodes: Vec<String> = g
                .nodes
                .iter()
                .map(|n| format!("{}:{:?}:{}", n.name, n.op, n.input_regs))
                .collect();
            nodes.sort();
            let mut edges: Vec<String> = g
                .edges
                .iter()
                .map(|e| {
                    format!(
                        "{}->{}:{}:{:?}:{}:{}",
                        g.node(e.src).name,
                        g.node(e.dst).name,
                        e.dst_port,
                        e.layer,
                        e.regs,
                        e.fifos
                    )
                })
                .collect();
            edges.sort();
            (nodes, edges)
        };
        assert_eq!(key(&orig), key(&back));
    }
}
