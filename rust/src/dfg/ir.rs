//! DFG node/edge types and graph operations.

use crate::arch::canal::Layer;
use crate::arch::delay::OpClass;
use crate::arch::params::TileKind;

/// ALU operations supported by a PE. Encodes into the `PeOp` bitstream
/// feature.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Sub,
    Mul,
    /// Multiply-accumulate with an internal accumulator register; `Accum`
    /// semantics are expressed via [`Op::Accum`], this is the pure op.
    Mac,
    Min,
    Max,
    Abs,
    Shl,
    Shr,
    And,
    Or,
    Xor,
    Gte,
    Lte,
    Eq,
    /// 2:1 select; selector arrives on the 1-bit layer.
    Mux,
    /// Route-through.
    Pass,
}

impl AluOp {
    /// Bitstream encoding.
    pub fn encode(self) -> u32 {
        match self {
            AluOp::Add => 1,
            AluOp::Sub => 2,
            AluOp::Mul => 3,
            AluOp::Mac => 4,
            AluOp::Min => 5,
            AluOp::Max => 6,
            AluOp::Abs => 7,
            AluOp::Shl => 8,
            AluOp::Shr => 9,
            AluOp::And => 10,
            AluOp::Or => 11,
            AluOp::Xor => 12,
            AluOp::Gte => 13,
            AluOp::Lte => 14,
            AluOp::Eq => 15,
            AluOp::Mux => 16,
            AluOp::Pass => 17,
        }
    }

    pub fn decode(v: u32) -> Option<AluOp> {
        Some(match v {
            1 => AluOp::Add,
            2 => AluOp::Sub,
            3 => AluOp::Mul,
            4 => AluOp::Mac,
            5 => AluOp::Min,
            6 => AluOp::Max,
            7 => AluOp::Abs,
            8 => AluOp::Shl,
            9 => AluOp::Shr,
            10 => AluOp::And,
            11 => AluOp::Or,
            12 => AluOp::Xor,
            13 => AluOp::Gte,
            14 => AluOp::Lte,
            15 => AluOp::Eq,
            16 => AluOp::Mux,
            17 => AluOp::Pass,
            _ => return None,
        })
    }

    /// Delay class for the timing model.
    pub fn op_class(self) -> OpClass {
        match self {
            AluOp::Add | AluOp::Sub | AluOp::Min | AluOp::Max | AluOp::Abs => OpClass::Add,
            AluOp::Mul => OpClass::Mul,
            AluOp::Mac => OpClass::Mac,
            AluOp::Shl | AluOp::Shr => OpClass::Shift,
            AluOp::And | AluOp::Or | AluOp::Xor | AluOp::Mux => OpClass::Logic,
            AluOp::Gte | AluOp::Lte | AluOp::Eq => OpClass::Cmp,
            AluOp::Pass => OpClass::Pass,
        }
    }

    /// Evaluate (functional reference semantics; 16-bit word machine
    /// modeled in i64 without overflow for test-sized data).
    pub fn eval(self, a: i64, b: i64, acc: i64) -> i64 {
        match self {
            AluOp::Add => a + b,
            AluOp::Sub => a - b,
            AluOp::Mul => a * b,
            AluOp::Mac => acc + a * b,
            AluOp::Min => a.min(b),
            AluOp::Max => a.max(b),
            AluOp::Abs => a.abs(),
            AluOp::Shl => a << (b & 15),
            AluOp::Shr => a >> (b & 15),
            AluOp::And => a & b,
            AluOp::Or => a | b,
            AluOp::Xor => a ^ b,
            AluOp::Gte => (a >= b) as i64,
            AluOp::Lte => (a <= b) as i64,
            AluOp::Eq => (a == b) as i64,
            AluOp::Mux => {
                if acc != 0 {
                    b
                } else {
                    a
                }
            }
            AluOp::Pass => a,
        }
    }
}

/// One step of a fused compound PE op ([`Op::Fused`]). Step 0 (the head)
/// keeps the compound node's external operand signature — input port 0
/// plus either input port 1 or the immediate; every later step is
/// single-input: it takes the previous step's result as operand `a` and
/// its immediate (or 0 for unary ops) as operand `b`.
#[derive(Debug, Clone, PartialEq)]
pub struct FusedStep {
    pub op: AluOp,
    /// Immediate second operand (the step's `PeConst`).
    pub const_b: Option<i64>,
}

impl FusedStep {
    /// Whether this step's op consumes a second operand at all.
    pub fn needs_b(&self) -> bool {
        !matches!(self.op, AluOp::Abs | AluOp::Pass)
    }
}

/// Evaluate a fused step chain. The head sees external operands `a`/`b`
/// (the caller resolves the head immediate into `b`, mirroring `Op::Alu`
/// evaluation); each tail step folds its own immediate in.
pub fn eval_fused(ops: &[FusedStep], a: i64, b: i64) -> i64 {
    let mut v = ops[0].op.eval(a, b, 0);
    for s in &ops[1..] {
        v = s.op.eval(v, s.const_b.unwrap_or(0), 0);
    }
    v
}

/// Sparse dataflow primitives (paper §VII; the substrate follows the
/// tensor-algebra dataflow style of [18]). Every sparse edge carries a
/// data/valid/ready triple routed together.
#[derive(Debug, Clone, PartialEq)]
pub enum SparseOp {
    /// Fiber coordinate scanner over a compressed level: emits the
    /// coordinate stream of one tensor mode (MEM tile).
    CrdScan { tensor: u8, mode: u8 },
    /// Values-array reader indexed by the scanner's position stream (MEM).
    ValRead { tensor: u8 },
    /// Coordinate intersection of two sorted coordinate streams (PE).
    Intersect,
    /// Coordinate union (PE).
    Union,
    /// Elementwise ALU on matched value streams (PE).
    SpAlu(AluOp),
    /// Reduction over a fiber: accumulates values until the fiber-end token
    /// and emits one result (PE with accumulator).
    Reduce,
    /// Repeat a value stream once per element of a reference stream (PE).
    Repeat,
}

/// DFG node operation.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// 16-bit input stream from an IO tile (`lane` distinguishes parallel
    /// input streams).
    Input { lane: u16 },
    /// 16-bit output stream into an IO tile. `decimate`: sample one of
    /// every `decimate` cycles (used by time-multiplexed reductions).
    Output { lane: u16, decimate: u32 },
    /// Compile-time constant (folded into consumers by the mapper).
    Const { value: i64 },
    /// PE ALU op. `const_b`: optional immediate second operand (PeConst).
    Alu { op: AluOp, const_b: Option<i64> },
    /// Compound PE op produced by the fusion pass ([`crate::dfg::fuse`]):
    /// a chain of single-fanout ALU ops collapsed into one PE. Steps run
    /// in order within a single PE's combinational core; the result of
    /// step `k` feeds operand `a` of step `k+1`. `Mux` and `Mac` never
    /// appear (they read extra state the chained core does not carry).
    Fused { ops: Vec<FusedStep> },
    /// Delay of `cycles` samples, realized as PE register-file shift
    /// registers (short) or MEM line buffers (long). `pipelined = false`
    /// for *algorithmic* delays (stencil row/column taps — part of the
    /// application's function); `pipelined = true` for delay lines created
    /// by the register-chain transform (§V-A), which count as
    /// pipelining-added latency for branch delay matching.
    Delay { cycles: u32, pipelined: bool },
    /// MEM tile in ROM mode: `values[counter % len]` each cycle (weights).
    Rom { values: Vec<i64> },
    /// PE with an internal accumulator: emits the running sum of `a*b`
    /// (or of `a` if one input); the accumulator resets every `period`
    /// cycles. Registered output (latency 1).
    Accum { period: u32 },
    /// Flush broadcast source (1-bit, from an IO tile): synchronizes every
    /// stateful tile at application start (paper §VI).
    FlushSrc,
    /// Sparse primitive.
    Sparse(SparseOp),
}

/// Node id (index into `Dfg::nodes`).
pub type NodeId = u32;
/// Edge id (index into `Dfg::edges`).
pub type EdgeId = u32;

/// A DFG node.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: Op,
    /// Debug name.
    pub name: String,
    /// Whether the PE input registers are enabled (set by compute
    /// pipelining; only meaningful for `Alu` nodes).
    pub input_regs: bool,
}

impl Node {
    /// Which kind of tile this node occupies.
    pub fn tile_kind(&self) -> TileKind {
        match &self.op {
            Op::Input { .. } | Op::Output { .. } | Op::FlushSrc => TileKind::Io,
            Op::Const { .. } => TileKind::Pe, // folded away by mapping; PE if materialized
            Op::Alu { .. } | Op::Fused { .. } | Op::Accum { .. } => TileKind::Pe,
            Op::Rom { .. } => TileKind::Mem,
            Op::Delay { cycles, .. } => {
                if *cycles >= 8 {
                    TileKind::Mem // line buffer
                } else {
                    TileKind::Pe // register-file shift register
                }
            }
            Op::Sparse(s) => match s {
                SparseOp::CrdScan { .. } | SparseOp::ValRead { .. } => TileKind::Mem,
                _ => TileKind::Pe,
            },
        }
    }

    /// Cycle latency through the node (for branch delay matching / the
    /// static schedule). Depends on pipelining state.
    pub fn latency(&self) -> u32 {
        match &self.op {
            Op::Input { .. } | Op::Output { .. } | Op::Const { .. } | Op::FlushSrc => 0,
            Op::Alu { .. } | Op::Fused { .. } => u32::from(self.input_regs),
            Op::Delay { cycles, .. } => *cycles,
            Op::Rom { .. } => 1,    // synchronous SRAM read
            Op::Accum { .. } => 1,  // registered accumulator
            // Sparse nodes are elastic (ready/valid); latency is absorbed
            // by the protocol, not balanced by BDM.
            Op::Sparse(_) => 1,
        }
    }

    /// Latency *added by pipelining*, relative to the unpipelined baseline
    /// graph. Algorithmic latencies (Delay taps, ROM reads, accumulators)
    /// are part of the application's function/schedule and contribute 0 —
    /// branch delay matching must equalize only the added cycles, or it
    /// would destroy stencil window offsets.
    pub fn added_latency(&self) -> u32 {
        match &self.op {
            Op::Alu { .. } | Op::Fused { .. } => u32::from(self.input_regs),
            // Register-file shift registers created by the chain transform
            // carry pipelining latency; stencil taps do not.
            Op::Delay { cycles, pipelined: true } => *cycles,
            _ => 0,
        }
    }

    /// Combinational delay class of the node's core for STA. `None` means
    /// the node's output is driven directly by a register (path restarts).
    pub fn comb_class(&self) -> Option<OpClass> {
        match &self.op {
            Op::Alu { op, .. } => Some(op.op_class()),
            // A compound core's worst member dominates; STA composes the
            // exact chained delay via `DelayLib::fused_core_ps`, this class
            // is the summary used for reporting.
            Op::Fused { ops } => {
                fn rank(c: OpClass) -> u8 {
                    match c {
                        OpClass::Pass => 0,
                        OpClass::Logic => 1,
                        OpClass::Shift => 2,
                        OpClass::Cmp => 3,
                        OpClass::Add => 4,
                        OpClass::Mul => 5,
                        OpClass::Mac => 6,
                    }
                }
                ops.iter().map(|s| s.op.op_class()).max_by_key(|&c| rank(c))
            }
            Op::Const { .. } => Some(OpClass::Pass),
            Op::Sparse(s) => Some(match s {
                SparseOp::Intersect | SparseOp::Union => OpClass::Cmp,
                SparseOp::SpAlu(a) => a.op_class(),
                SparseOp::Reduce => OpClass::Add,
                SparseOp::Repeat => OpClass::Logic,
                SparseOp::CrdScan { .. } | SparseOp::ValRead { .. } => OpClass::Pass,
            }),
            // Registered outputs: ROM/Delay/Accum/IO start a fresh path.
            _ => None,
        }
    }

    /// Whether the node's output comes straight out of a register.
    pub fn output_registered(&self) -> bool {
        matches!(
            &self.op,
            Op::Delay { .. } | Op::Rom { .. } | Op::Accum { .. } | Op::Input { .. } | Op::FlushSrc
        ) || matches!(&self.op, Op::Sparse(SparseOp::CrdScan { .. } | SparseOp::ValRead { .. }))
    }

    /// Is this a synchronous join where branch delay matching must equalize
    /// input arrival cycles? (Everything statically scheduled with >1 input;
    /// sparse nodes are elastic and excluded.)
    pub fn needs_balanced_inputs(&self) -> bool {
        !matches!(&self.op, Op::Sparse(_))
    }

    /// Is this a sparse (ready-valid) node?
    pub fn is_sparse(&self) -> bool {
        matches!(&self.op, Op::Sparse(_))
    }
}

/// A DFG edge: `src` output port 0 -> `dst` input port `dst_port`.
#[derive(Debug, Clone)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    pub dst_port: u8,
    /// Wiring layer (B16 data; B1 for flush/valid/select).
    pub layer: Layer,
    /// Pipeline registers currently assigned to this edge by the
    /// pipelining passes (branch-delay-matching registers, broadcast-tree
    /// registers, post-PnR registers...). Functional semantics: the value
    /// is delayed `regs` cycles.
    pub regs: u32,
    /// FIFO stages on this edge (sparse pipelining inserts FIFOs instead
    /// of registers, §VII). Latency-elastic: does not require BDM.
    pub fifos: u32,
}

/// The dataflow graph.
#[derive(Debug, Clone, Default)]
pub struct Dfg {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
}

impl Dfg {
    pub fn new() -> Dfg {
        Dfg::default()
    }

    pub fn add_node(&mut self, op: Op, name: impl Into<String>) -> NodeId {
        self.nodes.push(Node { op, name: name.into(), input_regs: false });
        (self.nodes.len() - 1) as NodeId
    }

    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, dst_port: u8, layer: Layer) -> EdgeId {
        self.edges.push(Edge { src, dst, dst_port, layer, regs: 0, fifos: 0 });
        (self.edges.len() - 1) as EdgeId
    }

    /// Convenience: 16-bit data edge.
    pub fn connect(&mut self, src: NodeId, dst: NodeId, dst_port: u8) -> EdgeId {
        self.add_edge(src, dst, dst_port, Layer::B16)
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id as usize]
    }

    pub fn edge(&self, id: EdgeId) -> &Edge {
        &self.edges[id as usize]
    }

    pub fn edge_mut(&mut self, id: EdgeId) -> &mut Edge {
        &mut self.edges[id as usize]
    }

    /// Edge ids entering `n`, sorted by destination port.
    pub fn in_edges(&self, n: NodeId) -> Vec<EdgeId> {
        let mut v: Vec<EdgeId> = (0..self.edges.len() as EdgeId)
            .filter(|&e| self.edges[e as usize].dst == n)
            .collect();
        v.sort_by_key(|&e| self.edges[e as usize].dst_port);
        v
    }

    /// Edge ids leaving `n`.
    pub fn out_edges(&self, n: NodeId) -> Vec<EdgeId> {
        (0..self.edges.len() as EdgeId)
            .filter(|&e| self.edges[e as usize].src == n)
            .collect()
    }

    /// Fanout (number of out-edges) of each node.
    pub fn fanout_counts(&self) -> Vec<u32> {
        let mut f = vec![0u32; self.nodes.len()];
        for e in &self.edges {
            f[e.src as usize] += 1;
        }
        f
    }

    /// Topological order. Panics if the graph has a cycle (the IR is a DAG
    /// by construction; feedback is internal to `Accum`).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let n = self.nodes.len();
        let mut indeg = vec![0u32; n];
        for e in &self.edges {
            indeg[e.dst as usize] += 1;
        }
        let mut out_adj: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for e in &self.edges {
            out_adj[e.src as usize].push(e.dst);
        }
        let mut stack: Vec<NodeId> =
            (0..n as NodeId).filter(|&i| indeg[i as usize] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = stack.pop() {
            order.push(u);
            for &v in &out_adj[u as usize] {
                indeg[v as usize] -= 1;
                if indeg[v as usize] == 0 {
                    stack.push(v);
                }
            }
        }
        assert_eq!(order.len(), n, "DFG has a cycle");
        order
    }

    /// Structural validation; returns a list of problems (empty = valid).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        let n = self.nodes.len() as NodeId;
        for (i, e) in self.edges.iter().enumerate() {
            if e.src >= n || e.dst >= n {
                problems.push(format!("edge {i} references missing node"));
            }
        }
        // Each (dst, dst_port, layer) must have at most one driver.
        let mut seen = std::collections::HashSet::new();
        for e in &self.edges {
            if !seen.insert((e.dst, e.dst_port, e.layer)) {
                problems.push(format!(
                    "node {} port {} ({:?}) has multiple drivers",
                    e.dst, e.dst_port, e.layer
                ));
            }
        }
        // Port-count legality per tile kind (2 data in-ports by default).
        for (i, node) in self.nodes.iter().enumerate() {
            let data_ins = self
                .edges
                .iter()
                .filter(|e| e.dst == i as NodeId && e.layer == Layer::B16)
                .count();
            let max = match node.tile_kind() {
                TileKind::Pe => 2,
                TileKind::Mem => 2,
                TileKind::Io => 1,
            };
            if data_ins > max {
                problems.push(format!(
                    "node {i} ({}) has {data_ins} data inputs; max {max}",
                    node.name
                ));
            }
            // Outputs must be consumed (except sinks).
            let has_out = self.edges.iter().any(|e| e.src == i as NodeId);
            let is_sink = matches!(node.op, Op::Output { .. });
            if is_sink && has_out {
                problems.push(format!("output node {i} has fanout"));
            }
        }
        // Inputs of each node must be fully connected for ops that need
        // both operands. A fused compound keeps the head step's operand
        // signature; tail steps must be self-contained (unary or immediate).
        for (i, node) in self.nodes.iter().enumerate() {
            let head = match &node.op {
                Op::Alu { op, const_b } => Some((*op, *const_b)),
                Op::Fused { ops } => {
                    if ops.len() < 2 {
                        problems.push(format!(
                            "fused node {i} ({}) has {} steps; min 2",
                            node.name,
                            ops.len()
                        ));
                    }
                    for (k, s) in ops.iter().enumerate() {
                        if matches!(s.op, AluOp::Mux | AluOp::Mac) {
                            problems.push(format!(
                                "fused node {i} ({}) step {k} is {:?}; Mux/Mac cannot fuse",
                                node.name, s.op
                            ));
                        }
                        if k > 0 && s.needs_b() && s.const_b.is_none() {
                            problems.push(format!(
                                "fused node {i} ({}) tail step {k} needs an immediate",
                                node.name
                            ));
                        }
                    }
                    ops.first().map(|s| (s.op, s.const_b))
                }
                _ => None,
            };
            if let Some((op, const_b)) = head {
                let needs_b = const_b.is_none()
                    && !matches!(op, AluOp::Abs | AluOp::Pass);
                let ports: Vec<u8> = self
                    .edges
                    .iter()
                    .filter(|e| e.dst == i as NodeId && e.layer == Layer::B16)
                    .map(|e| e.dst_port)
                    .collect();
                if !ports.contains(&0) {
                    problems.push(format!("ALU node {i} ({}) missing operand a", node.name));
                }
                if needs_b && !ports.contains(&1) {
                    problems.push(format!("ALU node {i} ({}) missing operand b", node.name));
                }
            }
        }
        problems
    }

    /// Cycle arrival time of each node's output: the branch-delay-matching
    /// quantity (paper §III-B). `arrival(n) = latency(n) + max over in-edges
    /// (arrival(src) + edge.regs)`. Sparse (elastic) edges still contribute
    /// their FIFO latency for reporting purposes, but BDM never needs to
    /// equalize them.
    pub fn arrival_cycles(&self) -> Vec<u64> {
        let mut in_lists: Vec<Vec<usize>> = vec![Vec::new(); self.nodes.len()];
        for (ei, e) in self.edges.iter().enumerate() {
            in_lists[e.dst as usize].push(ei);
        }
        let mut arr = vec![0u64; self.nodes.len()];
        for &n in &self.topo_order() {
            let mut best = 0u64;
            for &ei in &in_lists[n as usize] {
                let e = &self.edges[ei];
                // Flush is a reset distributed before execution; it never
                // contributes to data arrival times.
                if matches!(self.nodes[e.src as usize].op, Op::FlushSrc) {
                    continue;
                }
                let a = arr[e.src as usize] + e.regs as u64 + e.fifos as u64;
                best = best.max(a);
            }
            arr[n as usize] = best + self.nodes[n as usize].latency() as u64;
        }
        arr
    }

    /// Total pipeline registers currently assigned to edges.
    pub fn total_edge_regs(&self) -> u64 {
        self.edges.iter().map(|e| e.regs as u64).sum()
    }

    /// Count nodes by tile kind: (PE, MEM, IO).
    pub fn tile_demand(&self) -> (usize, usize, usize) {
        let mut pe = 0;
        let mut mem = 0;
        let mut io = 0;
        for n in &self.nodes {
            match n.tile_kind() {
                TileKind::Pe => pe += 1,
                TileKind::Mem => mem += 1,
                TileKind::Io => io += 1,
            }
        }
        (pe, mem, io)
    }

    /// Graphviz dump for debugging.
    pub fn to_dot(&self) -> String {
        use std::fmt::Write;
        let mut s = String::from("digraph dfg {\n  rankdir=LR;\n");
        for (i, n) in self.nodes.iter().enumerate() {
            let _ = writeln!(s, "  n{} [label=\"{}\\n{:?}\"];", i, n.name, n.tile_kind());
        }
        for e in &self.edges {
            let style = if e.layer == Layer::B1 { " style=dashed" } else { "" };
            let _ = writeln!(
                s,
                "  n{} -> n{} [label=\"r{}{}\"{}];",
                e.src,
                e.dst,
                e.regs,
                if e.fifos > 0 { format!(" f{}", e.fifos) } else { String::new() },
                style
            );
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dfg() -> Dfg {
        // in -> mul(*2) -> add -> out ; in -> add (port 1)
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(2) }, "mul");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: None }, "add");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "out");
        g.connect(i, m, 0);
        g.connect(m, a, 0);
        g.connect(i, a, 1);
        g.connect(a, o, 0);
        g
    }

    #[test]
    fn validates_clean_graph() {
        let g = small_dfg();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = small_dfg();
        let order = g.topo_order();
        let pos: Vec<usize> =
            (0..g.nodes.len() as NodeId)
                .map(|n| order.iter().position(|&x| x == n).unwrap())
                .collect();
        for e in &g.edges {
            assert!(pos[e.src as usize] < pos[e.dst as usize]);
        }
    }

    #[test]
    fn detects_double_driver() {
        let mut g = small_dfg();
        let i = 0;
        g.connect(i, 2, 1); // add port 1 already driven
        assert!(!g.validate().is_empty());
    }

    #[test]
    fn detects_missing_operand() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: None }, "add");
        g.connect(i, a, 0); // port 1 missing
        assert_eq!(g.validate().len(), 1);
    }

    #[test]
    fn latency_depends_on_input_regs() {
        let mut g = small_dfg();
        assert_eq!(g.node(1).latency(), 0);
        g.node_mut(1).input_regs = true;
        assert_eq!(g.node(1).latency(), 1);
    }

    #[test]
    fn tile_kinds_and_demand() {
        let g = small_dfg();
        let (pe, mem, io) = g.tile_demand();
        assert_eq!((pe, mem, io), (2, 0, 2));
        let mut g2 = Dfg::new();
        g2.add_node(Op::Delay { cycles: 100, pipelined: false }, "lb");
        g2.add_node(Op::Delay { cycles: 2, pipelined: false }, "sr");
        assert_eq!(g2.node(0).tile_kind(), TileKind::Mem);
        assert_eq!(g2.node(1).tile_kind(), TileKind::Pe);
    }

    #[test]
    fn alu_eval_semantics() {
        assert_eq!(AluOp::Add.eval(3, 4, 0), 7);
        assert_eq!(AluOp::Sub.eval(3, 4, 0), -1);
        assert_eq!(AluOp::Mac.eval(3, 4, 10), 22);
        assert_eq!(AluOp::Mux.eval(5, 9, 0), 5);
        assert_eq!(AluOp::Mux.eval(5, 9, 1), 9);
        assert_eq!(AluOp::Gte.eval(4, 4, 0), 1);
    }

    #[test]
    fn fused_node_semantics_and_validation() {
        // (in * 2) then >>1 then +3, as one compound PE.
        let ops = vec![
            FusedStep { op: AluOp::Mul, const_b: Some(2) },
            FusedStep { op: AluOp::Shr, const_b: Some(1) },
            FusedStep { op: AluOp::Add, const_b: Some(3) },
        ];
        assert_eq!(eval_fused(&ops, 5, 2), 8); // (5*2)>>1 + 3
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let f = g.add_node(Op::Fused { ops }, "f");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "out");
        g.connect(i, f, 0);
        g.connect(f, o, 0);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert_eq!(g.node(f).tile_kind(), TileKind::Pe);
        assert_eq!(g.node(f).latency(), 0);
        assert_eq!(g.node(f).added_latency(), 0);
        assert!(!g.node(f).output_registered());
        // Worst member class dominates (Mul here).
        assert_eq!(g.node(f).comb_class(), Some(OpClass::Mul));
        g.node_mut(f).input_regs = true;
        assert_eq!(g.node(f).latency(), 1);
        assert_eq!(g.node(f).added_latency(), 1);
    }

    #[test]
    fn fused_validation_rejects_illegal_steps() {
        // Single-step compound, Mux member, and tail without immediate.
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let f = g.add_node(
            Op::Fused {
                ops: vec![FusedStep { op: AluOp::Mux, const_b: Some(1) }],
            },
            "bad",
        );
        g.connect(i, f, 0);
        let problems = g.validate();
        assert!(problems.iter().any(|p| p.contains("min 2")), "{problems:?}");
        assert!(problems.iter().any(|p| p.contains("Mux/Mac")), "{problems:?}");

        let mut g2 = Dfg::new();
        let i2 = g2.add_node(Op::Input { lane: 0 }, "in");
        let f2 = g2.add_node(
            Op::Fused {
                ops: vec![
                    FusedStep { op: AluOp::Abs, const_b: None },
                    FusedStep { op: AluOp::Add, const_b: None },
                ],
            },
            "tail-needs-imm",
        );
        g2.connect(i2, f2, 0);
        assert!(g2
            .validate()
            .iter()
            .any(|p| p.contains("needs an immediate")));
    }

    #[test]
    fn aluop_encode_roundtrip() {
        for op in [
            AluOp::Add, AluOp::Sub, AluOp::Mul, AluOp::Mac, AluOp::Min, AluOp::Max,
            AluOp::Abs, AluOp::Shl, AluOp::Shr, AluOp::And, AluOp::Or, AluOp::Xor,
            AluOp::Gte, AluOp::Lte, AluOp::Eq, AluOp::Mux, AluOp::Pass,
        ] {
            assert_eq!(AluOp::decode(op.encode()), Some(op));
        }
        assert_eq!(AluOp::decode(0), None);
        assert_eq!(AluOp::decode(99), None);
    }

    #[test]
    fn registered_outputs() {
        let g = {
            let mut g = Dfg::new();
            g.add_node(Op::Rom { values: vec![1, 2] }, "rom");
            g.add_node(Op::Alu { op: AluOp::Add, const_b: Some(1) }, "a");
            g
        };
        assert!(g.node(0).output_registered());
        assert!(!g.node(1).output_registered());
        assert_eq!(g.node(0).comb_class(), None);
        assert!(g.node(1).comb_class().is_some());
    }

    #[test]
    fn dot_output_contains_nodes() {
        let g = small_dfg();
        let dot = g.to_dot();
        assert!(dot.contains("digraph"));
        assert!(dot.contains("n0 -> n1"));
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn topo_panics_on_cycle() {
        let mut g = Dfg::new();
        let a = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, "a");
        let b = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, "b");
        g.connect(a, b, 0);
        g.connect(b, a, 0);
        g.topo_order();
    }
}
