//! Builder utilities for constructing application DFGs (the halide-lite
//! frontend used by `apps::dense`): shared delay-line stencil taps,
//! balanced reduction trees, and weighted-sum (convolution) subgraphs.

use super::ir::{AluOp, Dfg, NodeId, Op};

/// A set of taps on a stream at increasing sample delays, built as a shared
/// delay-line chain (the hardware-realistic structure: row delays become
/// MEM line buffers, column delays become register-file shift registers).
pub struct TapLine {
    /// `taps[i]` produces the source delayed by `delays[i]` samples.
    pub taps: Vec<NodeId>,
    pub delays: Vec<u32>,
}

/// Build taps of `src` at each delay in `delays` (must be sorted,
/// deduplicated). Consecutive taps share the delay chain.
pub fn tap_line(g: &mut Dfg, src: NodeId, delays: &[u32], name: &str) -> TapLine {
    assert!(delays.windows(2).all(|w| w[0] < w[1]), "delays must be strictly increasing");
    let mut taps = Vec::with_capacity(delays.len());
    let mut prev = src;
    let mut prev_delay = 0u32;
    for (i, &d) in delays.iter().enumerate() {
        let step = d - prev_delay;
        let tap = if step == 0 {
            prev
        } else {
            let t =
                g.add_node(Op::Delay { cycles: step, pipelined: false }, format!("{name}_d{i}"));
            g.connect(prev, t, 0);
            t
        };
        taps.push(tap);
        prev = tap;
        prev_delay = d;
    }
    TapLine { taps, delays: delays.to_vec() }
}

/// Balanced binary reduction tree over `inputs` with `op`.
pub fn reduce_tree(g: &mut Dfg, op: AluOp, inputs: &[NodeId], name: &str) -> NodeId {
    assert!(!inputs.is_empty());
    let mut layer: Vec<NodeId> = inputs.to_vec();
    let mut level = 0;
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        for pair in layer.chunks(2) {
            if pair.len() == 2 {
                let n = g.add_node(
                    Op::Alu { op, const_b: None },
                    format!("{name}_l{level}_{}", next.len()),
                );
                g.connect(pair[0], n, 0);
                g.connect(pair[1], n, 1);
                next.push(n);
            } else {
                next.push(pair[0]);
            }
        }
        layer = next;
        level += 1;
    }
    layer[0]
}

/// Multiply each tap by an integer weight (folded constant) and sum with a
/// balanced adder tree. Zero weights are skipped; weight 1 skips the
/// multiplier.
pub fn weighted_sum(g: &mut Dfg, taps: &[NodeId], weights: &[i64], name: &str) -> NodeId {
    assert_eq!(taps.len(), weights.len());
    let mut terms = Vec::new();
    for (i, (&t, &w)) in taps.iter().zip(weights).enumerate() {
        if w == 0 {
            continue;
        }
        if w == 1 {
            terms.push(t);
        } else {
            let m = g.add_node(
                Op::Alu { op: AluOp::Mul, const_b: Some(w) },
                format!("{name}_w{i}"),
            );
            g.connect(t, m, 0);
            terms.push(m);
        }
    }
    assert!(!terms.is_empty(), "all-zero stencil");
    reduce_tree(g, AluOp::Add, &terms, name)
}

/// Build a `k x k` stencil over a row-major stream of row width `width`:
/// returns a node computing `sum_{r,c} weights[r][c] * in(t - (r*width+c))`.
pub fn stencil(
    g: &mut Dfg,
    src: NodeId,
    width: u32,
    weights: &[Vec<i64>],
    name: &str,
) -> NodeId {
    let k = weights.len() as u32;
    let mut delays = Vec::new();
    for r in 0..k {
        for c in 0..weights[r as usize].len() as u32 {
            delays.push(r * width + c);
        }
    }
    delays.sort();
    delays.dedup();
    let line = tap_line(g, src, &delays, name);
    // Map (r, c) -> tap index.
    let mut taps = Vec::new();
    let mut flat_weights = Vec::new();
    for (r, row) in weights.iter().enumerate() {
        for (c, &w) in row.iter().enumerate() {
            let d = r as u32 * width + c as u32;
            let idx = line.delays.iter().position(|&x| x == d).unwrap();
            taps.push(line.taps[idx]);
            flat_weights.push(w);
        }
    }
    weighted_sum(g, &taps, &flat_weights, name)
}

/// The algorithmic (window) delay of a k x k stencil on rows of `width`:
/// the output at time t reflects the input window ending at t.
pub fn stencil_window_delay(width: u32, k: u32) -> u32 {
    (k - 1) * width + (k - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::ir::Op;

    fn input(g: &mut Dfg) -> NodeId {
        g.add_node(Op::Input { lane: 0 }, "in")
    }

    #[test]
    fn tap_line_shares_chain() {
        let mut g = Dfg::new();
        let i = input(&mut g);
        let line = tap_line(&mut g, i, &[0, 1, 2], "t");
        assert_eq!(line.taps[0], i); // delay 0 is the source itself
        // Two Delay nodes of 1 cycle each, chained.
        let delays: Vec<u32> = g
            .nodes
            .iter()
            .filter_map(|n| match n.op {
                Op::Delay { cycles, .. } => Some(cycles),
                _ => None,
            })
            .collect();
        assert_eq!(delays, vec![1, 1]);
    }

    #[test]
    fn tap_line_large_gaps_become_line_buffers() {
        let mut g = Dfg::new();
        let i = input(&mut g);
        let line = tap_line(&mut g, i, &[0, 64, 128], "row");
        assert_eq!(line.taps.len(), 3);
        use crate::arch::params::TileKind;
        let mem_nodes = g.nodes.iter().filter(|n| n.tile_kind() == TileKind::Mem).count();
        assert_eq!(mem_nodes, 2); // two 64-cycle line buffers
    }

    #[test]
    fn reduce_tree_is_balanced() {
        let mut g = Dfg::new();
        let ins: Vec<NodeId> = (0..8).map(|_| input(&mut g)).collect();
        let root = reduce_tree(&mut g, AluOp::Add, &ins, "r");
        // 8 inputs -> 7 adders; depth 3 (checked via longest path).
        let adders = g.nodes.len() - 8;
        assert_eq!(adders, 7);
        let mut depth = vec![0u32; g.nodes.len()];
        for &n in &g.topo_order() {
            for e in g.in_edges(n) {
                let s = g.edge(e).src;
                depth[n as usize] = depth[n as usize].max(depth[s as usize] + 1);
            }
        }
        assert_eq!(depth[root as usize], 3);
    }

    #[test]
    fn weighted_sum_skips_zero_and_one() {
        let mut g = Dfg::new();
        let ins: Vec<NodeId> = (0..3).map(|_| input(&mut g)).collect();
        let _ = weighted_sum(&mut g, &ins, &[0, 1, 2], "w");
        // One multiplier (weight 2), one adder (1-weight tap + product).
        let muls = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Alu { op: AluOp::Mul, .. }))
            .count();
        assert_eq!(muls, 1);
    }

    #[test]
    fn stencil_structure() {
        let mut g = Dfg::new();
        let i = input(&mut g);
        let w = vec![vec![1, 2, 1], vec![2, 4, 2], vec![1, 2, 1]];
        let root = stencil(&mut g, i, 16, &w, "g");
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        // Window delay for 3x3 on width 16.
        assert_eq!(stencil_window_delay(16, 3), 34);
        // The root is reachable from the input.
        let order = g.topo_order();
        assert!(order.contains(&root));
    }
}
