//! Application dataflow-graph IR.
//!
//! Every stage of the compiler (Fig. 2 of the paper) operates on this
//! representation: the frontend builds a DFG of primitive operations, the
//! mapper legalizes it onto PE/MEM/IO tiles, place-and-route assigns tiles
//! and interconnect routes, and the pipelining passes insert registers /
//! FIFOs on its edges. The IR carries everything branch delay matching and
//! STA need: per-node cycle latencies, per-edge pipeline-register counts,
//! and per-node combinational delay classes.
//!
//! * [`ir`] — node/edge types and the graph itself.
//! * [`build`] — builder utilities (stencil taps, reduction trees) used by
//!   the benchmark applications.
//! * [`interp`] — a cycle-accurate functional interpreter: the in-crate
//!   golden reference the fabric simulator is checked against (the
//!   cross-language golden reference is the AOT-compiled JAX/Pallas model
//!   executed through PJRT, see `runtime`).
//! * [`fuse`] — op fusion: collapses single-fanout ALU chains into
//!   compound PE ops ahead of mapping (see `docs/fusion.md`).

pub mod ir;
pub mod build;
pub mod interp;
pub mod fuse;

pub use ir::{AluOp, Dfg, Edge, EdgeId, FusedStep, Node, NodeId, Op, SparseOp};
