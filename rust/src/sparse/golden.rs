//! Direct reference computations for the four sparse kernels (Table II).
//!
//! These compute the same outputs, in the same emission order, as the
//! streaming simulator (`sparse::sim`): nonzero-ordered walks over the
//! fiber trees. Used as the correctness oracle in tests and the end-to-end
//! example (alongside the PJRT golden models on densified inputs).

use crate::apps::sparse::SparseData;

use super::fiber::FiberTree;

/// `a(i) = b(i) + c(i)` over the union of coordinates, in coordinate
/// order.
pub fn vec_elemadd(data: &SparseData) -> Vec<i64> {
    let b = &data.tensors[0];
    let c = &data.tensors[1];
    let mut out = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < b.nnz() || j < c.nnz() {
        let bc = b.coords.get(i).map(|x| x[0]);
        let cc = c.coords.get(j).map(|x| x[0]);
        match (bc, cc) {
            (Some(x), Some(y)) if x == y => {
                out.push(b.values[i] + c.values[j]);
                i += 1;
                j += 1;
            }
            (Some(x), Some(y)) if x < y => {
                out.push(b.values[i]);
                i += 1;
            }
            (Some(_), Some(_)) => {
                out.push(c.values[j]);
                j += 1;
            }
            (Some(_), None) => {
                out.push(b.values[i]);
                i += 1;
            }
            (None, Some(_)) => {
                out.push(c.values[j]);
                j += 1;
            }
            (None, None) => unreachable!(),
        }
    }
    out
}

/// `A(i,j) = B(i,j) * C(i,j)` over the intersection, in coordinate order.
pub fn mat_elemmul(data: &SparseData) -> Vec<i64> {
    let b = &data.tensors[0];
    let c = &data.tensors[1];
    let mut out = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < b.nnz() && j < c.nnz() {
        let bc = &b.coords[i];
        let cc = &c.coords[j];
        match bc.cmp(cc) {
            std::cmp::Ordering::Equal => {
                out.push(b.values[i] * c.values[j]);
                i += 1;
                j += 1;
            }
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
        }
    }
    out
}

/// MTTKRP: for each `i` fiber of B (in order), emit `A(i, j)` for
/// `j = 0..J`: `A(i,j) = sum_{k,l} B(i,k,l) * C(k,j) * D(l,j)`.
pub fn mttkrp(data: &SparseData) -> Vec<i64> {
    let b = &data.tensors[0];
    let cf = FiberTree::from_coo(&data.tensors[1]);
    let df = FiberTree::from_coo(&data.tensors[2]);
    let jdim = data.tensors[1].shape[1] as usize;
    let bf = FiberTree::from_coo(b);
    let mut out = Vec::new();
    let (i_crds, _) = bf.fiber(0, 0);
    for (ie, _i) in i_crds.iter().enumerate() {
        let mut acc = vec![0i64; jdim];
        let (k_crds, k_range) = bf.fiber(1, ie as u32);
        for (kk, &k) in k_crds.iter().enumerate() {
            let ke = k_range.start + kk as u32;
            let (l_crds, l_range) = bf.fiber(2, ke);
            for (ll, &l) in l_crds.iter().enumerate() {
                let le = l_range.start + ll as u32;
                let bv = bf.values[le as usize];
                for j in 0..jdim {
                    acc[j] += bv * cf.dense_get(&[k, j as u32]) * df.dense_get(&[l, j as u32]);
                }
            }
        }
        out.extend_from_slice(&acc);
    }
    out
}

/// TTV: for each nonempty `(i,j)` fiber of B (in order), emit
/// `A(i,j) = sum_k B(i,j,k) * c(k)`.
pub fn ttv(data: &SparseData) -> Vec<i64> {
    let b = FiberTree::from_coo(&data.tensors[0]);
    let cv = FiberTree::from_coo(&data.tensors[1]);
    let mut out = Vec::new();
    let (i_crds, _) = b.fiber(0, 0);
    for (ie, _) in i_crds.iter().enumerate() {
        let (j_crds, j_range) = b.fiber(1, ie as u32);
        for (jj, _) in j_crds.iter().enumerate() {
            let je = j_range.start + jj as u32;
            let (k_crds, k_range) = b.fiber(2, je);
            let mut acc = 0i64;
            for (kk, &k) in k_crds.iter().enumerate() {
                let ke = k_range.start + kk as u32;
                acc += b.values[ke as usize] * cv.dense_get(&[k]);
            }
            out.push(acc);
        }
    }
    out
}

/// Dispatch by app name.
pub fn golden(name: &str, data: &SparseData) -> Vec<i64> {
    match name {
        "vec_elemadd" => vec_elemadd(data),
        "mat_elemmul" => mat_elemmul(data),
        "mttkrp" => mttkrp(data),
        "ttv" => ttv(data),
        _ => panic!("unknown sparse app {name}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::sparse::{data_for, SparseTensor};

    #[test]
    fn vecadd_matches_dense_sum() {
        let data = data_for("vec_elemadd", 3);
        let out = vec_elemadd(&data);
        let total: i64 = out.iter().sum();
        let expect: i64 =
            data.tensors[0].values.iter().sum::<i64>() + data.tensors[1].values.iter().sum::<i64>();
        assert_eq!(total, expect);
        // Length = |union|.
        let mut union: std::collections::BTreeSet<u32> = std::collections::BTreeSet::new();
        for t in &data.tensors {
            union.extend(t.coords.iter().map(|c| c[0]));
        }
        assert_eq!(out.len(), union.len());
    }

    #[test]
    fn elemmul_small_hand_case() {
        let b = SparseTensor {
            ndim: 2,
            shape: vec![3, 3],
            coords: vec![vec![0, 1], vec![1, 2], vec![2, 0]],
            values: vec![2, 3, 4],
        };
        let c = SparseTensor {
            ndim: 2,
            shape: vec![3, 3],
            coords: vec![vec![0, 1], vec![2, 0], vec![2, 2]],
            values: vec![5, 7, 9],
        };
        let data = SparseData { tensors: vec![b, c] };
        assert_eq!(mat_elemmul(&data), vec![10, 28]);
    }

    #[test]
    fn ttv_hand_case() {
        // B(0,0,k): {k=1: 2}, B(0,2,k): {k=0: 3}; c = [10, 100]
        let b = SparseTensor {
            ndim: 3,
            shape: vec![1, 3, 2],
            coords: vec![vec![0, 0, 1], vec![0, 2, 0]],
            values: vec![2, 3],
        };
        let c = SparseTensor {
            ndim: 1,
            shape: vec![2],
            coords: vec![vec![0], vec![1]],
            values: vec![10, 100],
        };
        let data = SparseData { tensors: vec![b, c] };
        assert_eq!(ttv(&data), vec![200, 30]);
    }

    #[test]
    fn mttkrp_hand_case() {
        // B(0,0,0)=2; C(0,j)=[1,10]; D(0,j)=[3,5]
        let b = SparseTensor {
            ndim: 3,
            shape: vec![1, 1, 1],
            coords: vec![vec![0, 0, 0]],
            values: vec![2],
        };
        let dense = |rows: u32, vals: Vec<i64>| {
            let cols = vals.len() as u32 / rows;
            let mut coords = Vec::new();
            for r in 0..rows {
                for c in 0..cols {
                    coords.push(vec![r, c]);
                }
            }
            SparseTensor { ndim: 2, shape: vec![rows, cols], coords, values: vals }
        };
        let c = dense(1, vec![1, 10]);
        let d = dense(1, vec![3, 5]);
        let data = SparseData { tensors: vec![b, c, d] };
        assert_eq!(mttkrp(&data), vec![6, 100]);
    }
}
