//! Cycle-level ready-valid actor simulation of sparse dataflow graphs.
//!
//! One FSM actor per sparse DFG node; one bounded FIFO per DFG edge whose
//! capacity is the architecture's base FIFO depth plus the FIFO stages the
//! sparse pipelining pass inserted on that edge (each inserted stage also
//! adds one cycle of latency, modeled as extra queue slots that must fill).
//! An actor fires at most one token per cycle and only when *all* its
//! output FIFOs have space — full backpressure, the §VII semantics.
//!
//! Token algebra (SAM-style):
//! * `Crd { crd, pos }` — a coordinate with up to two fiber positions
//!   (operand A / operand B; `u32::MAX` = absent after a union miss);
//! * `Val { v, lane }` — a value on dense lane `lane` (the `j` dimension of
//!   MTTKRP factors);
//! * `End(l)` — end of a fiber at nesting level `l`;
//! * `Done` — end of stream.

use std::collections::VecDeque;

use crate::apps::sparse::SparseData;
use crate::dfg::ir::{Dfg, Op, SparseOp};

use super::fiber::FiberTree;

/// Absent position marker.
pub const NOPOS: u32 = u32::MAX;

/// Stream token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tok {
    Crd { crd: u32, pos: [u32; 2] },
    Val { v: i64, lane: u16 },
    End(u8),
    Done,
}

/// Simulation configuration derived from the app.
#[derive(Debug, Clone)]
pub struct SparseSimCfg {
    /// Dense lane dimension J (1 when there are no dense factors).
    pub j_dim: u16,
    /// Fiber-end level at which `Reduce` emits and resets.
    pub reduce_end_level: u8,
    /// Base FIFO depth of compute-unit inputs.
    pub base_fifo: usize,
    /// Safety cap on simulated cycles.
    pub max_cycles: u64,
}

impl SparseSimCfg {
    pub fn for_app(name: &str, data: &SparseData) -> SparseSimCfg {
        let (j_dim, reduce_end_level) = match name {
            "mttkrp" => (data.tensors[1].shape[1] as u16, 1),
            "ttv" => (1, 0),
            _ => (1, 0),
        };
        SparseSimCfg { j_dim, reduce_end_level, base_fifo: 2, max_cycles: 50_000_000 }
    }
}

/// Result of a sparse simulation.
pub struct SparseRun {
    /// Output values per output lane, in emission order.
    pub outputs: Vec<i64>,
    pub cycles: u64,
    /// Tokens processed by the busiest actor (throughput bound).
    pub max_actor_tokens: u64,
}

/// Per-actor state.
enum ActorState {
    /// Root scanner: next entry index.
    ScanRoot { next: u32, done: bool },
    /// Child scanner: pending fiber emission.
    ScanChild { pending: VecDeque<Tok> },
    /// Two-stream combinator lookahead.
    None,
    /// Repeat (hold-repeat): held value token.
    RepeatHold { held: Option<Tok> },
    /// Dense ValRead / Val-expanding Repeat: pending lane tokens.
    Expand { pending: VecDeque<Tok> },
    /// Reduce accumulators.
    Reduce { acc: Vec<i64>, pending: VecDeque<Tok>, nonempty: bool },
}

/// The simulator.
pub struct SparseSim<'a> {
    g: &'a Dfg,
    cfg: SparseSimCfg,
    fibers: Vec<FiberTree>,
    /// FIFO per edge.
    fifo: Vec<VecDeque<Tok>>,
    cap: Vec<usize>,
    state: Vec<ActorState>,
    tokens_processed: Vec<u64>,
    /// in-edges (by port) and out-edges per node.
    ins: Vec<Vec<usize>>,
    outs: Vec<Vec<usize>>,
    outputs: Vec<i64>,
    done_at_output: bool,
}

impl<'a> SparseSim<'a> {
    pub fn new(g: &'a Dfg, data: &SparseData, cfg: SparseSimCfg) -> SparseSim<'a> {
        let fibers = data.tensors.iter().map(FiberTree::from_coo).collect();
        let mut ins: Vec<Vec<usize>> = vec![Vec::new(); g.nodes.len()];
        let mut outs: Vec<Vec<usize>> = vec![Vec::new(); g.nodes.len()];
        let mut cap = Vec::new();
        for (ei, e) in g.edges.iter().enumerate() {
            if matches!(g.node(e.src).op, Op::FlushSrc) {
                cap.push(0);
                continue;
            }
            ins[e.dst as usize].push(ei);
            outs[e.src as usize].push(ei);
            cap.push(cfg.base_fifo + e.fifos as usize);
        }
        for l in ins.iter_mut() {
            l.sort_by_key(|&ei| g.edges[ei].dst_port);
        }
        let state = g
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| match &n.op {
                Op::Sparse(SparseOp::CrdScan { .. }) => {
                    if ins[i].is_empty() {
                        ActorState::ScanRoot { next: 0, done: false }
                    } else {
                        ActorState::ScanChild { pending: VecDeque::new() }
                    }
                }
                Op::Sparse(SparseOp::Repeat) => {
                    if ins[i].len() >= 2 {
                        ActorState::RepeatHold { held: None }
                    } else {
                        ActorState::Expand { pending: VecDeque::new() }
                    }
                }
                Op::Sparse(SparseOp::ValRead { .. }) => {
                    ActorState::Expand { pending: VecDeque::new() }
                }
                Op::Sparse(SparseOp::Reduce) => ActorState::Reduce {
                    acc: vec![0; cfg.j_dim as usize],
                    pending: VecDeque::new(),
                    nonempty: false,
                },
                _ => ActorState::None,
            })
            .collect();
        SparseSim {
            fifo: vec![VecDeque::new(); g.edges.len()],
            cap,
            state,
            tokens_processed: vec![0; g.nodes.len()],
            ins,
            outs,
            outputs: Vec::new(),
            done_at_output: false,
            g,
            cfg,
            fibers,
        }
    }

    fn out_space(&self, n: usize) -> bool {
        self.outs[n].iter().all(|&ei| self.fifo[ei].len() < self.cap[ei])
    }

    fn push_out(&mut self, n: usize, t: Tok) {
        for &ei in &self.outs[n] {
            self.fifo[ei].push_back(t);
        }
        self.tokens_processed[n] += 1;
    }

    fn head(&self, n: usize, port: usize) -> Option<Tok> {
        self.ins[n].get(port).and_then(|&ei| self.fifo[ei].front().copied())
    }

    fn pop(&mut self, n: usize, port: usize) {
        let ei = self.ins[n][port];
        self.fifo[ei].pop_front();
    }

    /// Which `pos` slot a scanner/reader of `tensor` consumes.
    fn slot(tensor: u8) -> usize {
        usize::from(tensor != 0)
    }

    /// Fire one actor if possible. Returns true if it made progress.
    fn fire(&mut self, n: usize) -> bool {
        if !self.out_space(n) {
            return false;
        }
        let node = &self.g.nodes[n];
        match &node.op {
            Op::Sparse(sp) => self.fire_sparse(n, sp.clone()),
            Op::Output { .. } => {
                if let Some(t) = self.head(n, 0) {
                    self.pop(n, 0);
                    self.tokens_processed[n] += 1;
                    match t {
                        Tok::Val { v, .. } => self.outputs.push(v),
                        Tok::Done => self.done_at_output = true,
                        _ => {}
                    }
                    true
                } else {
                    false
                }
            }
            Op::Input { .. } | Op::FlushSrc => false,
            _ => false,
        }
    }

    fn fire_sparse(&mut self, n: usize, sp: SparseOp) -> bool {
        match sp {
            SparseOp::CrdScan { tensor, mode } => {
                let slot = Self::slot(tensor);
                // Child scanners drain pending first.
                let is_child = !self.ins[n].is_empty();
                if is_child {
                    if let ActorState::ScanChild { pending } = &mut self.state[n] {
                        if let Some(t) = pending.pop_front() {
                            self.push_out(n, t);
                            return true;
                        }
                    }
                    let Some(t) = self.head(n, 0) else { return false };
                    self.pop(n, 0);
                    let ft = &self.fibers[tensor as usize];
                    match t {
                        Tok::Crd { pos, .. } => {
                            let parent = pos[slot];
                            let mut toks = VecDeque::new();
                            if parent != NOPOS {
                                let (crds, range) = ft.fiber(mode as usize, parent);
                                for (k, &c) in crds.iter().enumerate() {
                                    let mut p = [NOPOS, NOPOS];
                                    p[slot] = range.start + k as u32;
                                    toks.push_back(Tok::Crd { crd: c, pos: p });
                                }
                            }
                            toks.push_back(Tok::End(0));
                            let first = toks.pop_front().unwrap();
                            if let ActorState::ScanChild { pending } = &mut self.state[n] {
                                *pending = toks;
                            }
                            self.push_out(n, first);
                        }
                        Tok::End(l) => self.push_out(n, Tok::End(l + 1)),
                        Tok::Done => self.push_out(n, Tok::Done),
                        Tok::Val { .. } => panic!("scanner received a value token"),
                    }
                    true
                } else {
                    // Root scanner.
                    let ft = &self.fibers[tensor as usize];
                    let (total, tok) = {
                        let (crds, range) = ft.fiber(0, 0);
                        if let ActorState::ScanRoot { next, done } = &self.state[n] {
                            if *done {
                                return false;
                            }
                            if (*next as usize) < crds.len() {
                                let k = *next as usize;
                                let mut p = [NOPOS, NOPOS];
                                p[slot] = range.start + k as u32;
                                (crds.len(), Some(Tok::Crd { crd: crds[k], pos: p }))
                            } else {
                                (crds.len(), None)
                            }
                        } else {
                            unreachable!()
                        }
                    };
                    match tok {
                        Some(t) => {
                            self.push_out(n, t);
                            if let ActorState::ScanRoot { next, .. } = &mut self.state[n] {
                                *next += 1;
                            }
                            let _ = total;
                        }
                        None => {
                            self.push_out(n, Tok::Done);
                            if let ActorState::ScanRoot { done, .. } = &mut self.state[n] {
                                *done = true;
                            }
                        }
                    }
                    true
                }
            }
            SparseOp::Intersect | SparseOp::Union => {
                let union = matches!(sp, SparseOp::Union);
                let (Some(a), Some(b)) = (self.head(n, 0), self.head(n, 1)) else {
                    return false;
                };
                match (a, b) {
                    (Tok::Crd { crd: ca, pos: pa }, Tok::Crd { crd: cb, pos: pb }) => {
                        if ca == cb {
                            self.pop(n, 0);
                            self.pop(n, 1);
                            self.push_out(n, Tok::Crd { crd: ca, pos: [pa[0], pb[1]] });
                        } else if ca < cb {
                            self.pop(n, 0);
                            if union {
                                self.push_out(n, Tok::Crd { crd: ca, pos: [pa[0], NOPOS] });
                            } else {
                                self.tokens_processed[n] += 1;
                            }
                        } else {
                            self.pop(n, 1);
                            if union {
                                self.push_out(n, Tok::Crd { crd: cb, pos: [NOPOS, pb[1]] });
                            } else {
                                self.tokens_processed[n] += 1;
                            }
                        }
                    }
                    (Tok::Crd { crd, pos }, Tok::End(_) | Tok::Done) => {
                        self.pop(n, 0);
                        if union {
                            self.push_out(n, Tok::Crd { crd, pos: [pos[0], NOPOS] });
                        } else {
                            self.tokens_processed[n] += 1;
                        }
                    }
                    (Tok::End(_) | Tok::Done, Tok::Crd { crd, pos }) => {
                        self.pop(n, 1);
                        if union {
                            self.push_out(n, Tok::Crd { crd, pos: [NOPOS, pos[1]] });
                        } else {
                            self.tokens_processed[n] += 1;
                        }
                    }
                    (Tok::End(la), Tok::End(lb)) => {
                        debug_assert_eq!(la, lb, "misaligned fiber ends");
                        self.pop(n, 0);
                        self.pop(n, 1);
                        self.push_out(n, Tok::End(la));
                    }
                    (Tok::Done, Tok::Done) => {
                        self.pop(n, 0);
                        self.pop(n, 1);
                        self.push_out(n, Tok::Done);
                    }
                    (Tok::Done, Tok::End(_)) | (Tok::End(_), Tok::Done) => {
                        panic!("misaligned streams at combinator");
                    }
                    (Tok::Val { .. }, _) | (_, Tok::Val { .. }) => {
                        panic!("combinator received a value token");
                    }
                }
                true
            }
            SparseOp::ValRead { tensor } => {
                // Drain pending lane expansion first.
                if let ActorState::Expand { pending } = &mut self.state[n] {
                    if let Some(t) = pending.pop_front() {
                        self.push_out(n, t);
                        return true;
                    }
                }
                let Some(t) = self.head(n, 0) else { return false };
                self.pop(n, 0);
                let ft = &self.fibers[tensor as usize];
                match t {
                    Tok::Crd { crd, pos } => {
                        if ft.is_dense() && ft.shape.len() == 2 {
                            // Dense factor: expand across the J lanes.
                            let j = ft.shape[1] as usize;
                            let mut toks: VecDeque<Tok> = (0..j)
                                .map(|jj| Tok::Val {
                                    v: ft.dense_get(&[crd, jj as u32]),
                                    lane: jj as u16,
                                })
                                .collect();
                            let first = toks.pop_front().unwrap();
                            if let ActorState::Expand { pending } = &mut self.state[n] {
                                *pending = toks;
                            }
                            self.push_out(n, first);
                        } else if ft.is_dense() {
                            self.push_out(n, Tok::Val { v: ft.dense_get(&[crd]), lane: 0 });
                        } else {
                            let p = pos[Self::slot(tensor)];
                            let v = if p == NOPOS { 0 } else { ft.values[p as usize] };
                            self.push_out(n, Tok::Val { v, lane: 0 });
                        }
                    }
                    other => self.push_out(n, other),
                }
                true
            }
            SparseOp::Repeat => {
                let two_input = self.ins[n].len() >= 2;
                if two_input {
                    // Hold-repeat: emit held crd once per reference token.
                    let Some(r) = self.head(n, 1) else { return false };
                    match r {
                        Tok::Crd { .. } => {
                            // Need a held value.
                            let have = matches!(
                                &self.state[n],
                                ActorState::RepeatHold { held: Some(_) }
                            );
                            if !have {
                                let Some(h) = self.head(n, 0) else { return false };
                                self.pop(n, 0);
                                match h {
                                    Tok::Crd { .. } => {
                                        if let ActorState::RepeatHold { held } =
                                            &mut self.state[n]
                                        {
                                            *held = Some(h);
                                        }
                                    }
                                    // Ends/Done on the held stream are
                                    // driven by the reference stream; drop.
                                    _ => return true,
                                }
                            }
                            let held = match &self.state[n] {
                                ActorState::RepeatHold { held } => held.unwrap(),
                                _ => unreachable!(),
                            };
                            self.pop(n, 1);
                            self.push_out(n, held);
                        }
                        Tok::End(0) => {
                            // End of one reference fiber: release the held
                            // token and forward the end.
                            self.pop(n, 1);
                            if let ActorState::RepeatHold { held } = &mut self.state[n] {
                                *held = None;
                            }
                            self.push_out(n, Tok::End(0));
                        }
                        Tok::End(l) => {
                            self.pop(n, 1);
                            self.push_out(n, Tok::End(l));
                        }
                        Tok::Done => {
                            self.pop(n, 1);
                            // Drain the held stream's Done if present.
                            if let Some(Tok::Done) = self.head(n, 0) {
                                self.pop(n, 0);
                            }
                            self.push_out(n, Tok::Done);
                        }
                        Tok::Val { .. } => panic!("reference stream carries values"),
                    }
                    true
                } else {
                    // Single input: pass Crd/End/Done through; expand Val
                    // across J lanes.
                    if let ActorState::Expand { pending } = &mut self.state[n] {
                        if let Some(t) = pending.pop_front() {
                            self.push_out(n, t);
                            return true;
                        }
                    }
                    let Some(t) = self.head(n, 0) else { return false };
                    self.pop(n, 0);
                    match t {
                        Tok::Val { v, .. } if self.cfg.j_dim > 1 => {
                            let mut toks: VecDeque<Tok> = (0..self.cfg.j_dim)
                                .map(|j| Tok::Val { v, lane: j })
                                .collect();
                            let first = toks.pop_front().unwrap();
                            if let ActorState::Expand { pending } = &mut self.state[n] {
                                *pending = toks;
                            }
                            self.push_out(n, first);
                        }
                        other => self.push_out(n, other),
                    }
                    true
                }
            }
            SparseOp::SpAlu(op) => {
                let (Some(a), Some(b)) = (self.head(n, 0), self.head(n, 1)) else {
                    return false;
                };
                match (a, b) {
                    (Tok::Val { v: va, lane: la }, Tok::Val { v: vb, lane: lb }) => {
                        debug_assert_eq!(la, lb, "lane-misaligned values at ALU");
                        self.pop(n, 0);
                        self.pop(n, 1);
                        self.push_out(n, Tok::Val { v: op.eval(va, vb, 0), lane: la });
                    }
                    (Tok::End(la), Tok::End(lb)) => {
                        debug_assert_eq!(la, lb);
                        self.pop(n, 0);
                        self.pop(n, 1);
                        self.push_out(n, Tok::End(la));
                    }
                    (Tok::Done, Tok::Done) => {
                        self.pop(n, 0);
                        self.pop(n, 1);
                        self.push_out(n, Tok::Done);
                    }
                    _ => {
                        panic!("misaligned streams at sparse ALU: {a:?} vs {b:?}")
                    }
                }
                true
            }
            SparseOp::Reduce => {
                if let ActorState::Reduce { pending, .. } = &mut self.state[n] {
                    if let Some(t) = pending.pop_front() {
                        self.push_out(n, t);
                        return true;
                    }
                }
                let Some(t) = self.head(n, 0) else { return false };
                self.pop(n, 0);
                let level = self.cfg.reduce_end_level;
                let jd = self.cfg.j_dim as usize;
                if let ActorState::Reduce { acc, pending, nonempty } = &mut self.state[n] {
                    match t {
                        Tok::Val { v, lane } => {
                            acc[lane as usize] += v;
                            *nonempty = true;
                            self.tokens_processed[n] += 1;
                        }
                        Tok::End(l) if l == level => {
                            if *nonempty {
                                let mut toks: VecDeque<Tok> = (0..jd)
                                    .map(|j| Tok::Val { v: acc[j], lane: j as u16 })
                                    .collect();
                                acc.iter_mut().for_each(|a| *a = 0);
                                *nonempty = false;
                                let first = toks.pop_front().unwrap();
                                *pending = toks;
                                self.push_out(n, first);
                            } else {
                                self.tokens_processed[n] += 1;
                            }
                        }
                        Tok::End(l) if l < level => {
                            // Inner fiber end: keep accumulating.
                            self.tokens_processed[n] += 1;
                        }
                        Tok::End(l) => self.push_out(n, Tok::End(l - level - 1)),
                        Tok::Crd { .. } => {
                            self.tokens_processed[n] += 1; // coordinate metadata
                        }
                        Tok::Done => self.push_out(n, Tok::Done),
                    }
                }
                true
            }
        }
    }

    /// Run to completion. Returns outputs + cycle count.
    pub fn run(mut self) -> SparseRun {
        let order: Vec<usize> = self.g.topo_order().into_iter().map(|n| n as usize).collect();
        let mut cycles = 0u64;
        while !self.done_at_output && cycles < self.cfg.max_cycles {
            let mut progress = false;
            // Fire in reverse topo order so downstream drains first
            // (consumer-before-producer within a cycle = registered FIFOs).
            for &n in order.iter().rev() {
                if self.fire(n) {
                    progress = true;
                }
            }
            cycles += 1;
            if !progress && !self.done_at_output {
                panic!("sparse simulation deadlocked at cycle {cycles}");
            }
        }
        assert!(self.done_at_output, "simulation exceeded max_cycles");
        SparseRun {
            outputs: self.outputs,
            cycles,
            max_actor_tokens: self.tokens_processed.iter().copied().max().unwrap_or(0),
        }
    }
}

/// Convenience: simulate an app by name with its data bundle.
pub fn simulate_app(name: &str, g: &Dfg, data: &SparseData) -> SparseRun {
    let cfg = SparseSimCfg::for_app(name, data);
    SparseSim::new(g, data, cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::sparse::{data_for, SparseData, SparseTensor};
    use crate::sparse::golden;

    fn check(name: &str, app: crate::apps::App, data: &SparseData) {
        let run = simulate_app(name, &app.dfg, data);
        let expect = golden::golden(name, data);
        assert_eq!(run.outputs, expect, "{name} outputs mismatch");
        assert!(run.cycles > 0);
    }

    #[test]
    fn vec_elemadd_matches_golden() {
        let data = data_for("vec_elemadd", 7);
        check("vec_elemadd", crate::apps::sparse::vec_elemadd(4096, 0.25), &data);
    }

    #[test]
    fn mat_elemmul_matches_golden() {
        let data = data_for("mat_elemmul", 9);
        check("mat_elemmul", crate::apps::sparse::mat_elemmul(128, 128, 0.1), &data);
    }

    #[test]
    fn ttv_matches_golden() {
        let data = data_for("ttv", 11);
        check("ttv", crate::apps::sparse::tensor_ttv(48, 48, 48, 0.05), &data);
    }

    #[test]
    fn mttkrp_matches_golden() {
        let data = data_for("mttkrp", 13);
        check("mttkrp", crate::apps::sparse::tensor_mttkrp(32, 32, 32, 8, 0.05), &data);
    }

    #[test]
    fn tiny_handmade_union() {
        let b = SparseTensor {
            ndim: 1,
            shape: vec![8],
            coords: vec![vec![1], vec![3]],
            values: vec![10, 30],
        };
        let c = SparseTensor {
            ndim: 1,
            shape: vec![8],
            coords: vec![vec![3], vec![5]],
            values: vec![300, 500],
        };
        let data = SparseData { tensors: vec![b, c] };
        let app = crate::apps::sparse::vec_elemadd(8, 0.3);
        let run = simulate_app("vec_elemadd", &app.dfg, &data);
        assert_eq!(run.outputs, vec![10, 330, 500]);
    }

    #[test]
    fn fifo_stages_increase_latency_not_results() {
        let data = data_for("vec_elemadd", 7);
        let app = crate::apps::sparse::vec_elemadd(4096, 0.25);
        let base = simulate_app("vec_elemadd", &app.dfg, &data);
        let mut g2 = app.dfg.clone();
        for e in &mut g2.edges {
            e.fifos = 2;
        }
        let piped = simulate_app("vec_elemadd", &g2, &data);
        assert_eq!(base.outputs, piped.outputs);
    }

    #[test]
    fn empty_tensors_complete() {
        let empty = SparseTensor { ndim: 1, shape: vec![8], coords: vec![], values: vec![] };
        let data = SparseData { tensors: vec![empty.clone(), empty] };
        let app = crate::apps::sparse::vec_elemadd(8, 0.0);
        let run = simulate_app("vec_elemadd", &app.dfg, &data);
        assert!(run.outputs.is_empty());
    }
}
