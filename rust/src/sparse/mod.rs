//! The sparse (ready-valid) streaming substrate (paper §VII).
//!
//! Sparse tensor applications have data-dependent memory accesses, so they
//! execute as elastic dataflow: every inter-tile connection carries a
//! data/valid/ready triple, and every compute unit has FIFOs at its inputs.
//! This module provides:
//!
//! * [`fiber`] — compressed fiber-tree (CSF) storage built from COO
//!   tensors, the structure the coordinate scanners walk;
//! * [`sim`] — a cycle-level actor simulator: one FSM per sparse DFG node,
//!   bounded FIFOs per edge (depth grows with the FIFO stages the sparse
//!   pipelining pass inserts), full backpressure; measures cycles and
//!   produces output values;
//! * [`golden`] — direct (non-streaming) reference computations for the
//!   four Table II kernels, used to check the simulator's outputs.

pub mod fiber;
pub mod sim;
pub mod golden;
