//! Compressed sparse fiber (CSF) trees.
//!
//! Coordinate scanners (`SparseOp::CrdScan`) walk one level of this
//! structure: level `m` holds, for each parent entry, a fiber of sorted
//! coordinates; leaf entries index the values array.

use crate::apps::sparse::SparseTensor;

/// One compression level.
#[derive(Debug, Clone)]
pub struct Level {
    /// Fiber boundaries: fiber `p` occupies entries `seg[p]..seg[p+1]`.
    pub seg: Vec<u32>,
    /// Coordinates of each entry.
    pub crd: Vec<u32>,
}

/// A CSF tensor: `levels[m]` for each mode, plus leaf values.
#[derive(Debug, Clone)]
pub struct FiberTree {
    pub levels: Vec<Level>,
    pub values: Vec<i64>,
    pub shape: Vec<u32>,
}

impl FiberTree {
    /// Build from a sorted-COO tensor.
    pub fn from_coo(t: &SparseTensor) -> FiberTree {
        let ndim = t.ndim;
        let mut levels: Vec<Level> = Vec::with_capacity(ndim);
        // Level 0: unique prefixes of length 1; level m: unique prefixes of
        // length m+1 grouped under level m-1 entries.
        let mut prev_prefixes: Vec<&[u32]> = vec![&[]];
        let mut prev_entry_of_coord: Vec<usize> = vec![0; t.coords.len()]; // parent entry per nnz
        for m in 0..ndim {
            let mut seg = vec![0u32];
            let mut crd = Vec::new();
            let mut entry_of_coord = vec![0usize; t.coords.len()];
            let mut cur_parent = 0usize;
            let mut last: Option<(usize, u32)> = None; // (parent entry, coord)
            for (ci, c) in t.coords.iter().enumerate() {
                let parent = prev_entry_of_coord[ci];
                // New fibers for skipped parents.
                while cur_parent < parent {
                    seg.push(crd.len() as u32);
                    cur_parent += 1;
                    last = None;
                }
                let coord = c[m];
                if last != Some((parent, coord)) {
                    crd.push(coord);
                    last = Some((parent, coord));
                }
                entry_of_coord[ci] = crd.len() - 1;
            }
            // Close remaining fibers up to the number of parent entries.
            let parent_entries = if m == 0 { 1 } else { levels[m - 1].crd.len() };
            while seg.len() <= parent_entries {
                seg.push(crd.len() as u32);
            }
            levels.push(Level { seg, crd });
            prev_entry_of_coord = entry_of_coord;
        }
        let _ = prev_prefixes;
        prev_prefixes = vec![];
        let _ = prev_prefixes;
        FiberTree { levels, values: t.values.clone(), shape: t.shape.clone() }
    }

    /// Number of entries at a level.
    pub fn entries(&self, mode: usize) -> usize {
        self.levels[mode].crd.len()
    }

    /// The fiber (crd slice + entry index range) of `parent` at `mode`.
    pub fn fiber(&self, mode: usize, parent: u32) -> (&[u32], std::ops::Range<u32>) {
        let l = &self.levels[mode];
        let lo = l.seg[parent as usize];
        let hi = l.seg[parent as usize + 1];
        (&l.crd[lo as usize..hi as usize], lo..hi)
    }

    /// Is the underlying tensor dense (every coordinate present)?
    pub fn is_dense(&self) -> bool {
        let total: u64 = self.shape.iter().map(|&s| s as u64).product();
        self.values.len() as u64 == total
    }

    /// Dense lookup for dense factors: row-major.
    pub fn dense_get(&self, idx: &[u32]) -> i64 {
        debug_assert!(self.is_dense());
        let mut flat = 0u64;
        for (d, &i) in idx.iter().enumerate() {
            flat = flat * self.shape[d] as u64 + i as u64;
        }
        self.values[flat as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coo(shape: &[u32], entries: &[(&[u32], i64)]) -> SparseTensor {
        SparseTensor {
            ndim: shape.len(),
            shape: shape.to_vec(),
            coords: entries.iter().map(|(c, _)| c.to_vec()).collect(),
            values: entries.iter().map(|(_, v)| *v).collect(),
        }
    }

    #[test]
    fn vector_fiber() {
        let t = coo(&[8], &[(&[1], 10), (&[3], 30), (&[7], 70)]);
        let f = FiberTree::from_coo(&t);
        assert_eq!(f.levels.len(), 1);
        let (crds, range) = f.fiber(0, 0);
        assert_eq!(crds, &[1, 3, 7]);
        assert_eq!(range, 0..3);
        assert_eq!(f.values, vec![10, 30, 70]);
    }

    #[test]
    fn matrix_fibers() {
        // Rows: 0 -> {1:5, 2:6}; 2 -> {0:7}
        let t = coo(&[4, 4], &[(&[0, 1], 5), (&[0, 2], 6), (&[2, 0], 7)]);
        let f = FiberTree::from_coo(&t);
        assert_eq!(f.levels[0].crd, vec![0, 2]);
        let (row0, r0) = f.fiber(1, 0);
        assert_eq!(row0, &[1, 2]);
        assert_eq!(r0, 0..2);
        let (row1, r1) = f.fiber(1, 1);
        assert_eq!(row1, &[0]);
        assert_eq!(r1, 2..3);
    }

    #[test]
    fn three_level_tensor() {
        let t = coo(
            &[2, 2, 2],
            &[(&[0, 0, 1], 1), (&[0, 1, 0], 2), (&[0, 1, 1], 3), (&[1, 0, 0], 4)],
        );
        let f = FiberTree::from_coo(&t);
        assert_eq!(f.levels[0].crd, vec![0, 1]);
        assert_eq!(f.levels[1].crd, vec![0, 1, 0]);
        assert_eq!(f.levels[2].crd, vec![1, 0, 1, 0]);
        // Fiber of (i=0, k=1) at level 2: coords {0, 1}.
        let (fib, range) = f.fiber(2, 1);
        assert_eq!(fib, &[0, 1]);
        assert_eq!(range, 1..3);
    }

    #[test]
    fn dense_detection_and_lookup() {
        let mut entries = Vec::new();
        let vals: Vec<i64> = (0..6).collect();
        let mut coords = Vec::new();
        for r in 0..2u32 {
            for c in 0..3u32 {
                coords.push(vec![r, c]);
            }
        }
        for (c, v) in coords.iter().zip(&vals) {
            entries.push((c.clone(), *v));
        }
        let t = SparseTensor {
            ndim: 2,
            shape: vec![2, 3],
            coords,
            values: vals,
        };
        let f = FiberTree::from_coo(&t);
        assert!(f.is_dense());
        assert_eq!(f.dense_get(&[1, 2]), 5);
        let _ = entries;
    }

    #[test]
    fn empty_parent_fibers_are_empty_ranges() {
        let t = coo(&[4, 4], &[(&[0, 1], 5), (&[3, 2], 6)]);
        let f = FiberTree::from_coo(&t);
        assert_eq!(f.levels[0].crd, vec![0, 3]);
        let (fib0, _) = f.fiber(1, 0);
        let (fib1, _) = f.fiber(1, 1);
        assert_eq!(fib0, &[1]);
        assert_eq!(fib1, &[2]);
    }
}
