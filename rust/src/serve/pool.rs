//! Bounded MPMC job queue for the serve worker pool (std-only:
//! `Mutex` + `Condvar`).
//!
//! The queue is the daemon's backpressure point, used at two levels:
//!
//! * **accept queue** — the acceptor [`Bounded::try_push`]es each
//!   incoming connection and *never blocks*: when the queue is full the
//!   push fails, the acceptor answers `busy` inline, and memory stays
//!   bounded no matter how fast clients connect;
//! * **per-connection pipeline** — a connection's reader thread
//!   [`Bounded::push`]es read-ahead request lines and *does* block when
//!   the in-flight bound is reached, which stops the socket reads, which
//!   fills the kernel receive buffer, which stalls the sender: TCP
//!   back-pressure, end to end, with no unbounded buffering anywhere.
//!
//! Workers block in [`Bounded::pop`]; [`Bounded::close`] starts the drain:
//! already-queued jobs are still handed out, then every worker gets
//! `None` and exits — that is the graceful-shutdown contract.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer multi-consumer queue of pending jobs.
pub struct Bounded<T> {
    state: Mutex<State<T>>,
    cap: usize,
    ready: Condvar,
    /// Signalled when a slot frees (pop) or the queue closes — what
    /// [`Bounded::push`] blocks on.
    space: Condvar,
}

impl<T> Bounded<T> {
    /// A queue admitting at most `cap` pending jobs (`cap` is clamped to
    /// at least 1).
    pub fn new(cap: usize) -> Bounded<T> {
        Bounded {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            cap: cap.max(1),
            ready: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Enqueue without blocking. Returns the job back when the queue is
    /// full or closed — the caller owns the rejection response.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.items.len() >= self.cap {
            return Err(item);
        }
        st.items.push_back(item);
        self.ready.notify_one();
        Ok(())
    }

    /// Enqueue, blocking while the queue is full. Returns the job back
    /// only when the queue is closed — the producer's signal to stop.
    /// This is the pipelining back-pressure point: a blocked push is a
    /// stopped socket read, which the sender eventually feels as TCP
    /// flow control.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.cap {
                st.items.push_back(item);
                self.ready.notify_one();
                return Ok(());
            }
            st = self.space.wait(st).unwrap();
        }
    }

    /// Dequeue, blocking while the queue is empty and open. `None` means
    /// closed *and* drained: the worker's signal to exit.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.space.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Stop admitting jobs and wake every blocked worker and producer.
    /// Queued jobs are still popped (drain semantics); idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.ready.notify_all();
        self.space.notify_all();
    }

    /// Pending jobs right now (monitoring only — racy by nature).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn push_pop_fifo() {
        let q = Bounded::new(4);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn full_queue_rejects_without_blocking() {
        let q = Bounded::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3), "over-cap push must bounce the job back");
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok(), "a pop frees a slot");
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = Bounded::new(4);
        assert!(q.try_push(7).is_ok());
        q.close();
        assert_eq!(q.try_push(8), Err(8), "closed queue admits nothing");
        assert_eq!(q.pop(), Some(7), "queued jobs drain after close");
        assert_eq!(q.pop(), None, "drained + closed = worker exit");
        assert_eq!(q.pop(), None, "idempotent");
    }

    #[test]
    fn blocking_push_waits_for_space_and_fails_on_close() {
        let q = Bounded::new(1);
        assert!(q.push(1).is_ok());
        std::thread::scope(|s| {
            let t = s.spawn(|| q.push(2));
            // The producer is parked on a full queue; a pop frees the
            // slot and must wake it.
            std::thread::sleep(std::time::Duration::from_millis(50));
            assert_eq!(q.pop(), Some(1));
            assert!(t.join().unwrap().is_ok());
        });
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert_eq!(q.push(3), Err(3), "closed queue bounces the blocking push too");
    }

    #[test]
    fn close_wakes_blocked_producers() {
        let q = Bounded::new(1);
        assert!(q.push(1).is_ok());
        std::thread::scope(|s| {
            let t = s.spawn(|| q.push(2));
            std::thread::sleep(std::time::Duration::from_millis(50));
            q.close();
            assert_eq!(t.join().unwrap(), Err(2), "close must release a parked producer");
        });
        assert_eq!(q.pop(), Some(1), "queued jobs still drain after close");
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q = Bounded::<usize>::new(2);
        let popped = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while q.pop().is_some() {
                        popped.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
            assert!(q.try_push(1).is_ok());
            assert!(q.try_push(2).is_ok());
            // Workers may still be parked; close must wake all three so
            // the scope can join.
            q.close();
        });
        assert_eq!(popped.load(Ordering::SeqCst), 2);
    }
}
