//! `cascade client` — drive a running `cascade serve` daemon without
//! external tooling (the CI smoke job and shell scripts use this).
//!
//! One invocation = one connection = one request: the op is the first
//! positional (`ping|stat|metrics|compile|encode|shutdown`), point axes
//! use the same flags as `cascade encode`, and the raw response JSON is
//! printed to stdout — except `encode`'s `bitstream` member, which is
//! written to `--out FILE` (default `results/bitstream_<key>.txt`)
//! byte-identically to offline `cascade encode`, so `cmp` against the
//! offline file is the end-to-end check, and `metrics`' `exposition`
//! member, which is printed raw (Prometheus text, scrape-ready).

use std::io::{BufRead as _, BufReader, Write as _};
use std::net::TcpStream;
use std::time::Duration;

use crate::util::cli::Args;
use crate::util::json::Json;

use super::proto::{self, PointQuery, Request};

/// Send one request, await the one response line. The timeout applies to
/// connect-adjacent socket reads/writes, not to the server's compile
/// time budget as a whole — each partial read just has to make progress.
pub fn request(addr: &str, req: &Request, timeout: Duration) -> Result<Json, String> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| format!("client: cannot connect to {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    let mut line = req.to_json().to_string_compact();
    line.push('\n');
    stream.write_all(line.as_bytes()).map_err(|e| format!("client: send failed: {e}"))?;
    let mut reader = BufReader::new(&mut stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).map_err(|e| format!("client: read failed: {e}"))?;
    if resp.trim().is_empty() {
        return Err("client: connection closed without a response".into());
    }
    Json::parse(resp.trim()).map_err(|e| format!("client: unparseable response: {e}"))
}

/// `cascade client <op> [--addr HOST:PORT] [point flags] [--key HEX]
/// [--out FILE] [--timeout SECS]`.
pub fn run_cli(args: &Args) -> Result<(), String> {
    let op = args
        .positionals
        .get(1)
        .map(|s| s.as_str())
        .ok_or("client: expected an op (ping|stat|metrics|compile|encode|shutdown)")?;
    let addr = args.opt_or("addr", "127.0.0.1:7878");
    let timeout = match args.opt("timeout") {
        None => Duration::from_secs(600),
        Some(s) => Duration::from_secs(
            s.parse().map_err(|_| format!("client: bad --timeout '{s}' (seconds)"))?,
        ),
    };
    let req = match op {
        "ping" => Request::Ping,
        "stat" => Request::Stat,
        "metrics" => Request::Metrics,
        "shutdown" => Request::Shutdown,
        "compile" => Request::Compile(PointQuery::from_args(args)?),
        "encode" => match args.opt("key") {
            Some(hex) => {
                let conflict = proto::POINT_MEMBERS
                    .iter()
                    .find(|n| args.opt(n).is_some() || args.flag(n));
                if let Some(n) = conflict {
                    return Err(format!(
                        "client: encode takes --key or point flags, not both (got --{n})"
                    ));
                }
                let key = u64::from_str_radix(hex, 16)
                    .map_err(|_| format!("client: bad --key '{hex}' (hex)"))?;
                Request::Encode { key: Some(key), query: None }
            }
            None => Request::Encode { key: None, query: Some(PointQuery::from_args(args)?) },
        },
        other => {
            return Err(format!(
                "client: unknown op '{other}' (ping|stat|metrics|compile|encode|shutdown)"
            ))
        }
    };
    let resp = request(addr, &req, timeout)?;
    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(format!("client: server error: {}", resp.to_string_compact()));
    }
    if let Some(text) = resp.get("exposition").and_then(Json::as_str) {
        // Scrape-ready: the exposition alone, not its JSON wrapper.
        print!("{text}");
        return Ok(());
    }
    match resp.get("bitstream").and_then(Json::as_str) {
        Some(bs) => {
            let out = args.opt("out").map(std::path::PathBuf::from).unwrap_or_else(|| {
                let key = resp.get("key").and_then(Json::as_str).unwrap_or("served");
                std::path::PathBuf::from(format!("results/bitstream_{key}.txt"))
            });
            if let Some(dir) = out.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            std::fs::write(&out, bs)
                .map_err(|e| format!("client: cannot write {}: {e}", out.display()))?;
            // Print the response minus the (possibly huge) payload, then
            // the human summary line.
            let mut head = resp.clone();
            if let Json::Obj(m) = &mut head {
                m.remove("bitstream");
            }
            println!("{}", head.to_string_compact());
            println!(
                "client: {} configuration word(s) -> {}",
                resp.get("words").and_then(Json::as_u64).unwrap_or(0),
                out.display()
            );
        }
        None => println!("{}", resp.to_string_compact()),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn unknown_op_and_missing_op_error_before_connecting() {
        assert!(run_cli(&parse("client")).is_err());
        assert!(run_cli(&parse("client frobnicate")).is_err());
        // Bad point flags fail locally too (no daemon involved).
        assert!(run_cli(&parse("client compile")).is_err());
        assert!(run_cli(&parse("client encode --key zz")).is_err());
        assert!(run_cli(&parse("client encode --key ff --seed 7")).is_err());
        assert!(run_cli(&parse("client encode --key ff --tiny")).is_err());
    }
}
