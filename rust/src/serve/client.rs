//! The client side of the serve protocol: a keep-alive [`Client`] plus
//! the `cascade client` CLI built on it.
//!
//! [`Client`] holds **one** TCP connection for its whole lifetime and
//! sends any number of requests down it — the protocol is pipelined
//! newline-delimited JSON, so request N+1 never pays connect/teardown
//! again (the v1 free function opened a fresh connection per call, which
//! made every request pay a 3-way handshake and made daemon-side
//! keep-alive accounting untestable). Every consumer goes through it:
//! `cascade client`, `cascade loadgen`, the routing front daemon's
//! backend pool, the CI smoke job and the e2e tests.
//!
//! Transport failures (connect refused, reset, timeout, server gone
//! mid-read) are surfaced as `Err`; [`ClientOpts::retries`] > 0 redials
//! and resends that many extra times. Retries are safe because every
//! wire op is idempotent — `compile`/`encode` are cache-keyed (a repeat
//! is a warm hit), `stat`/`metrics`/`ping` are reads, and a repeated
//! `shutdown` finds the daemon already draining. Structured error
//! *responses* (`busy`, `unauthorized`, ...) are `Ok(json)` — the
//! transport worked; the caller owns the policy.
//!
//! ```no_run
//! use cascade::serve::{Client, ClientOpts};
//!
//! let mut c = Client::connect("127.0.0.1:7878", ClientOpts::default()).unwrap();
//! let pong = c.ping().unwrap();
//! assert_eq!(pong.get("proto").and_then(|v| v.as_u64()), Some(3));
//! let stat = c.stat().unwrap(); // same connection, no reconnect
//! println!("{}", stat.to_string_compact());
//! ```

use std::io::{BufRead as _, BufReader, Write as _};
use std::net::TcpStream;
use std::time::Duration;

use crate::util::cli::Args;
use crate::util::json::Json;

use super::proto::{self, PointQuery, Request};

/// Connection policy for a [`Client`].
#[derive(Debug, Clone)]
pub struct ClientOpts {
    /// Per-socket-operation timeout (each read/write must make progress
    /// within it; a long compile is many progressing reads server-side,
    /// but one blocking read here — size it to the slowest expected
    /// request).
    pub timeout: Duration,
    /// Extra reconnect-and-resend attempts after a transport failure
    /// (0 = fail fast). Safe because every wire op is idempotent.
    pub retries: usize,
    /// Shared secret, attached to every request as `"auth"` (required
    /// by daemons started with `--auth-token`).
    pub auth: Option<String>,
}

impl Default for ClientOpts {
    /// 600 s timeout (full-budget compiles are slow), no retries, no auth.
    fn default() -> ClientOpts {
        ClientOpts { timeout: Duration::from_secs(600), retries: 0, auth: None }
    }
}

/// The live connection: the writing half and a buffered reading half of
/// the same socket.
struct Conn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

/// A keep-alive connection to a `cascade serve` daemon. See the module
/// docs; construct with [`Client::connect`], drop to close.
pub struct Client {
    addr: String,
    opts: ClientOpts,
    conn: Option<Conn>,
}

fn dial(addr: &str, opts: &ClientOpts) -> Result<Conn, String> {
    let stream =
        TcpStream::connect(addr).map_err(|e| format!("client: cannot connect to {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(opts.timeout));
    let _ = stream.set_write_timeout(Some(opts.timeout));
    let reader = BufReader::new(
        stream.try_clone().map_err(|e| format!("client: cannot clone stream to {addr}: {e}"))?,
    );
    Ok(Conn { stream, reader })
}

impl Client {
    /// Dial `addr` and hold the connection open. Fails fast when the
    /// daemon is unreachable — a caller that wants lazy dialing can just
    /// construct on first use.
    pub fn connect(addr: impl Into<String>, opts: ClientOpts) -> Result<Client, String> {
        let addr = addr.into();
        let conn = dial(&addr, &opts)?;
        Ok(Client { addr, opts, conn: Some(conn) })
    }

    /// The address this client dials.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Send one request, await its response line — the primitive every
    /// op method wraps. On a transport failure the connection is dropped
    /// and (under [`ClientOpts::retries`]) redialed; the request object
    /// is serialized once, with the configured auth token attached.
    pub fn request(&mut self, req: &Request) -> Result<Json, String> {
        self.request_traced(req, None)
    }

    /// [`Client::request`] carrying an optional v3 trace context
    /// ([`proto::TraceCtx`]) — the routed front's forwarding primitive.
    /// A backend that received the context echoes its span tree in the
    /// response's `"trace"` member.
    pub fn request_traced(
        &mut self,
        req: &Request,
        ctx: Option<proto::TraceCtx>,
    ) -> Result<Json, String> {
        let mut j = req.to_json();
        if let Some(c) = ctx {
            c.write_json(&mut j);
        }
        if let Some(t) = &self.opts.auth {
            j.set("auth", t.as_str());
        }
        let mut line = j.to_string_compact();
        line.push('\n');
        let mut last_err = String::new();
        for attempt in 0..=self.opts.retries {
            if attempt > 0 {
                self.conn = None; // force a fresh dial
            }
            match self.send_once(&line) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    self.conn = None;
                    last_err = e;
                }
            }
        }
        Err(last_err)
    }

    fn send_once(&mut self, line: &str) -> Result<Json, String> {
        if self.conn.is_none() {
            self.conn = Some(dial(&self.addr, &self.opts)?);
        }
        let conn = self.conn.as_mut().expect("just dialed");
        conn.stream
            .write_all(line.as_bytes())
            .and_then(|()| conn.stream.flush())
            .map_err(|e| format!("client: send to {} failed: {e}", self.addr))?;
        let mut resp = String::new();
        conn.reader
            .read_line(&mut resp)
            .map_err(|e| format!("client: read from {} failed: {e}", self.addr))?;
        if resp.trim().is_empty() {
            return Err(format!("client: {} closed the connection without a response", self.addr));
        }
        Json::parse(resp.trim())
            .map_err(|e| format!("client: unparseable response from {}: {e}", self.addr))
    }

    /// Liveness probe; the response carries `"proto"`.
    pub fn ping(&mut self) -> Result<Json, String> {
        self.request(&Request::Ping)
    }

    /// Cache + server statistics.
    pub fn stat(&mut self) -> Result<Json, String> {
        self.request(&Request::Stat)
    }

    /// The Prometheus-style exposition (in the `"exposition"` member).
    pub fn metrics(&mut self) -> Result<Json, String> {
        self.request(&Request::Metrics)
    }

    /// Compile (or serve from cache) one point.
    pub fn compile(&mut self, q: &PointQuery) -> Result<Json, String> {
        self.request(&Request::Compile(q.clone()))
    }

    /// Emit a point's bitstream through the compile dedup path.
    pub fn encode_point(&mut self, q: &PointQuery) -> Result<Json, String> {
        self.request(&Request::Encode { key: None, query: Some(q.clone()) })
    }

    /// Emit a stored artifact's bitstream by effective key (never
    /// compiles).
    pub fn encode_key(&mut self, key: u64) -> Result<Json, String> {
        self.request(&Request::Encode { key: Some(key), query: None })
    }

    /// Ask the daemon to drain and stop.
    pub fn shutdown(&mut self) -> Result<Json, String> {
        self.request(&Request::Shutdown)
    }
}

impl Drop for Client {
    /// Close cleanly: both directions shut down so the daemon's reader
    /// sees EOF now, not a poll-timeout later.
    fn drop(&mut self) {
        if let Some(c) = self.conn.take() {
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// `cascade client <op> [--addr HOST:PORT] [point flags] [--key HEX]
/// [--out FILE] [--timeout SECS] [--retries N] [--auth-token T]`.
///
/// One invocation = one [`Client`] = one connection; the op is the first
/// positional (`ping|stat|metrics|compile|encode|shutdown`), point axes
/// use the same flags as `cascade encode`, and the raw response JSON is
/// printed to stdout — except `encode`'s `bitstream` member, which is
/// written to `--out FILE` (default `results/bitstream_<key>.txt`)
/// byte-identically to offline `cascade encode`, and `metrics`'
/// `exposition` member, which is printed raw (Prometheus text,
/// scrape-ready; a routed front's per-backend expositions follow under
/// `# backend <addr>` headers).
pub fn run_cli(args: &Args) -> Result<(), String> {
    let op = args
        .positionals
        .get(1)
        .map(|s| s.as_str())
        .ok_or("client: expected an op (ping|stat|metrics|compile|encode|shutdown)")?;
    let addr = args.opt_or("addr", "127.0.0.1:7878");
    let timeout = match args.opt("timeout") {
        None => Duration::from_secs(600),
        Some(s) => Duration::from_secs(
            s.parse().map_err(|_| format!("client: bad --timeout '{s}' (seconds)"))?,
        ),
    };
    let req = match op {
        "ping" => Request::Ping,
        "stat" => Request::Stat,
        "metrics" => Request::Metrics,
        "shutdown" => Request::Shutdown,
        "compile" => Request::Compile(PointQuery::from_args(args)?),
        "encode" => match args.opt("key") {
            Some(hex) => {
                let conflict = proto::POINT_MEMBERS
                    .iter()
                    .find(|n| args.opt(n).is_some() || args.flag(n));
                if let Some(n) = conflict {
                    return Err(format!(
                        "client: encode takes --key or point flags, not both (got --{n})"
                    ));
                }
                let key = u64::from_str_radix(hex, 16)
                    .map_err(|_| format!("client: bad --key '{hex}' (hex)"))?;
                Request::Encode { key: Some(key), query: None }
            }
            None => Request::Encode { key: None, query: Some(PointQuery::from_args(args)?) },
        },
        other => {
            return Err(format!(
                "client: unknown op '{other}' (ping|stat|metrics|compile|encode|shutdown)"
            ))
        }
    };
    let opts = ClientOpts {
        timeout,
        retries: args.opt_usize("retries", 0),
        auth: args.opt("auth-token").map(str::to_string),
    };
    let mut client = Client::connect(addr, opts)?;
    let resp = client.request(&req)?;
    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(format!("client: server error: {}", resp.to_string_compact()));
    }
    if let Some(text) = resp.get("exposition").and_then(Json::as_str) {
        // Scrape-ready: the exposition alone, not its JSON wrapper. A
        // routed front appends each backend's exposition under a comment
        // header, so one scrape shows the whole topology.
        print!("{text}");
        if let Some(backends) = resp.get("backends").and_then(Json::as_arr) {
            for b in backends {
                let baddr = b.get("addr").and_then(Json::as_str).unwrap_or("?");
                println!("# backend {baddr}");
                if let Some(t) = b.get("exposition").and_then(Json::as_str) {
                    print!("{t}");
                }
            }
        }
        return Ok(());
    }
    match resp.get("bitstream").and_then(Json::as_str) {
        Some(bs) => {
            let out = args.opt("out").map(std::path::PathBuf::from).unwrap_or_else(|| {
                let key = resp.get("key").and_then(Json::as_str).unwrap_or("served");
                std::path::PathBuf::from(format!("results/bitstream_{key}.txt"))
            });
            if let Some(dir) = out.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            std::fs::write(&out, bs)
                .map_err(|e| format!("client: cannot write {}: {e}", out.display()))?;
            // Print the response minus the (possibly huge) payload, then
            // the human summary line.
            let mut head = resp.clone();
            if let Json::Obj(m) = &mut head {
                m.remove("bitstream");
            }
            println!("{}", head.to_string_compact());
            println!(
                "client: {} configuration word(s) -> {}",
                resp.get("words").and_then(Json::as_u64).unwrap_or(0),
                out.display()
            );
        }
        None => println!("{}", resp.to_string_compact()),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn unknown_op_and_missing_op_error_before_connecting() {
        assert!(run_cli(&parse("client")).is_err());
        assert!(run_cli(&parse("client frobnicate")).is_err());
        // Bad point flags fail locally too (no daemon involved).
        assert!(run_cli(&parse("client compile")).is_err());
        assert!(run_cli(&parse("client encode --key zz")).is_err());
        assert!(run_cli(&parse("client encode --key ff --seed 7")).is_err());
        assert!(run_cli(&parse("client encode --key ff --tiny")).is_err());
        assert!(run_cli(&parse("client ping --timeout x")).is_err());
    }

    #[test]
    fn connect_to_nothing_fails_fast() {
        // Port 1 on loopback is essentially never listening; either the
        // connect fails (expected) or some exotic environment answers —
        // in which case skip rather than flake.
        if let Ok(mut c) = Client::connect("127.0.0.1:1", ClientOpts::default()) {
            eprintln!("skipping: something is listening on 127.0.0.1:1");
            let _ = c.ping();
        }
    }

    #[test]
    fn retries_redial_then_surface_the_last_error() {
        let opts = ClientOpts { retries: 2, timeout: Duration::from_secs(1), auth: None };
        let err = match Client::connect("127.0.0.1:1", opts) {
            Err(e) => e,
            Ok(_) => {
                eprintln!("skipping: something is listening on 127.0.0.1:1");
                return;
            }
        };
        assert!(err.contains("cannot connect"), "{err}");
    }
}
