//! `cascade serve` — a production compile/encode daemon over the
//! explore artifact store: keep-alive pipelined connections, optional
//! shared-secret auth, and a hash-routing front mode for coordination-free
//! multi-node scale-out.
//!
//! In **local mode** a `TcpListener` accepts newline-delimited-JSON
//! requests ([`proto`]), a bounded queue hands connections to a worker
//! thread pool ([`pool`]), and every `compile`/`encode` request resolves
//! through the same [`SessionCore`] — in-memory in-flight deduplication,
//! the persistent metrics cache, and the fingerprint-verified artifact
//! store — so N clients requesting the same effective point trigger
//! exactly one compile, and everyone else gets a warm answer. Responses
//! carry the point's effective cache key, the cache-hit provenance
//! (`fresh|warm_mem|warm_art|warm_rec`) and per-request timing.
//!
//! **Pipelining (protocol v2).** Connections are keep-alive: a client may
//! write any number of request lines without waiting, and responses come
//! back strictly in request order. Per connection, a reader thread
//! read-aheads lines into a bounded queue (`--pipeline` deep) that the
//! connection's worker drains in order; when the queue is full the reader
//! stops reading the socket, the kernel receive buffer fills, and the
//! sender stalls — TCP back-pressure bounds in-flight work end to end.
//! Each request is charged its *own* dequeue-to-dispatch wait as
//! `queue_ms` (plus, for a connection's first request, its accept-queue
//! time), so the `queue_ms`/`exec_ms` split stays honest under
//! pipelining.
//!
//! **Routing.** `--route addr1,addr2,...` starts the daemon as a *front*
//! ([`route`]): no local compiler, no local cache. `compile`/`encode`
//! requests are hash-routed to the backend that owns the point's
//! effective cache key under the exact N-way partition `cascade explore
//! --shard` uses ([`crate::explore::shard::owner_of`]) — each backend's
//! cache holds a disjoint key range and dedup still collapses concurrent
//! identical requests, with zero coordination between nodes. `stat` and
//! `metrics` fan out and aggregate, `ping` probes every backend, and an
//! unreachable backend yields a structured `backend_down` error after one
//! built-in retry. Routing is transparent: a routed `compile`/`encode`
//! response is byte-identical to a direct single-daemon response apart
//! from the front-measured timing members and the nested `"backend"`
//! object preserving the backend's own `queue_ms`/`exec_ms`/`ms` split.
//!
//! **Auth.** `--auth-token T` requires every request to carry a matching
//! `"auth"` member (checked in constant time, [`proto::ct_eq`]); binding
//! a non-loopback address *requires* a token — the protocol is plaintext
//! and an open compile daemon is free compute for anyone who can reach
//! it. The front attaches its own token when dialing backends.
//!
//! Resource bounds are explicit: the connection queue is bounded (an
//! overloaded daemon answers `busy` in O(1) instead of queueing
//! unboundedly), the per-connection pipeline is bounded, the in-memory
//! artifact cache is ephemeral (artifacts live in RAM only while a
//! compile is in flight; the disk store is the durable layer), and a
//! housekeeping thread periodically runs the artifact-store GC under
//! `--cache-cap` — pinned Pareto/knee survivors are never evicted — and
//! drops idle non-base compile contexts.
//!
//! Shutdown is graceful: a `shutdown` request stops the acceptor,
//! already-queued connections drain, in-flight requests complete and are
//! answered, then a final GC compacts the journal before the process
//! exits (the contract `docs/serve.md` specifies).
//!
//! The daemon is observable ([`crate::obs`], `docs/observability.md`):
//! every request is counted and timed into a per-daemon metrics registry
//! that the `metrics` wire op renders as deterministic Prometheus-style
//! text, compile/encode responses split `ms` into `queue_ms` + `exec_ms`,
//! and a size-bounded JSONL request log (`--log`, `--log-cap`) records
//! one structured line per request plus `start`/`gc`/`drain` lifecycle
//! events. Every successful `compile`/`encode` record also carries the
//! request's span tree (protocol v3 distributed tracing): queue/exec
//! spans, per-stage compile spans with kernel work counters, and — on a
//! routing front — the backend's echoed spans grafted under the forward
//! span, renderable with `cascade trace`. `cascade loadgen`
//! ([`loadgen`]) drives a daemon with a deterministic open-loop schedule
//! and reports p50/p99/p999.
//!
//! ```no_run
//! use cascade::pipeline::CompileCtx;
//! use cascade::serve::{ServeConfig, Server};
//!
//! let mut cfg = ServeConfig::new("127.0.0.1:7878");
//! cfg.workers = 4;
//! let server = Server::bind(cfg).expect("bind");
//! println!("listening on {}", server.addr());
//! let ctx = CompileCtx::paper();
//! server.run(&ctx).expect("serve"); // returns after a `shutdown` request
//! ```
//!
//! Drive it programmatically through the keep-alive [`Client`], or from
//! the shell via the [`client`] subcommand: `cascade client compile
//! --addr HOST:PORT --app gaussian --tiny --fast`.

pub mod client;
pub mod loadgen;
pub mod pool;
pub mod proto;
pub mod route;

pub use client::{Client, ClientOpts};

use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::explore::runner::{Provenance, SessionCore};
use crate::explore::{CacheCap, DiskCache};
use crate::obs::{labeled, now_ms, Registry, RequestLog};
use crate::pipeline::CompileCtx;
use crate::util::cli::Args;
use crate::util::json::Json;

use pool::Bounded;
use proto::{
    key_hex, metrics_json, response_error, response_ok, trace_from_json, trace_json, ErrorCode,
    Request, TraceCtx, TraceSpan, MAX_REQUEST_LINE, PROTO_VERSION,
};

/// How long a reader's socket read blocks before it re-checks the
/// shutdown and connection-done flags — the bound on how long an *idle*
/// connection can delay a drain (in-flight requests always complete
/// regardless).
const READ_POLL: Duration = Duration::from_millis(500);

/// Per-connection write timeout: a client that stops reading its own
/// responses forfeits the connection rather than wedging a worker.
const WRITE_TIMEOUT: Duration = Duration::from_secs(60);

/// Socket-operation timeout the front uses when talking to a backend
/// (same budget the [`ClientOpts`] default gives a slow full compile).
const BACKEND_TIMEOUT: Duration = Duration::from_secs(600);

/// Where the JSONL request log goes (`--log`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LogTarget {
    /// `<cache_dir>/serve_requests.jsonl` (resolved at [`Server::run`]).
    Default,
    /// `--log none`: no request log.
    Disabled,
    /// `--log PATH`: an explicit file.
    Path(PathBuf),
}

/// Daemon configuration (`cascade serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, `HOST:PORT` (`:0` picks an ephemeral port —
    /// [`Server::addr`] reports the real one). Non-loopback binds
    /// require [`ServeConfig::auth_token`].
    pub addr: String,
    /// Worker threads — the compile concurrency bound.
    pub workers: usize,
    /// Pending-connection queue bound; the acceptor answers `busy`
    /// beyond it.
    pub queue_cap: usize,
    /// Per-connection in-flight pipelining bound: how many request lines
    /// the reader may read ahead of the executor before socket reads
    /// stop (TCP back-pressure).
    pub pipeline: usize,
    /// Shared-secret auth: when set, every request must carry a matching
    /// `"auth"` member or is refused `unauthorized`.
    pub auth_token: Option<String>,
    /// Backend addresses (`--route a,b,c`): non-empty turns this daemon
    /// into a hash-routing front with no local compiler or cache.
    pub route: Vec<String>,
    /// The `explore_cache/` directory to serve from (shared with
    /// `cascade explore` / `encode` / `cache`).
    pub cache_dir: PathBuf,
    /// Artifact-store budget for the periodic and final GC (`None` =
    /// never collect).
    pub cache_cap: Option<CacheCap>,
    /// Housekeeping period (GC + context-cache trim).
    pub gc_every: Duration,
    /// Request-log destination (JSONL, one record per request).
    pub log: LogTarget,
    /// Request-log rotation bound in bytes ([`RequestLog`] renames the
    /// full file to `.1` and starts fresh).
    pub log_cap: u64,
}

impl ServeConfig {
    /// Defaults: workers = available parallelism (capped at 8), queue =
    /// 4x workers, pipeline 4, no auth, no routing, the default explore
    /// cache, no cap, 60 s housekeeping.
    pub fn new(addr: impl Into<String>) -> ServeConfig {
        let workers = std::thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(1);
        ServeConfig {
            addr: addr.into(),
            workers,
            queue_cap: workers * 4,
            pipeline: 4,
            auth_token: None,
            route: Vec::new(),
            cache_dir: DiskCache::default_dir(),
            cache_cap: None,
            gc_every: Duration::from_secs(60),
            log: LogTarget::Default,
            log_cap: crate::obs::DEFAULT_LOG_CAP,
        }
    }

    /// Parse `cascade serve --addr HOST:PORT [--workers N] [--queue N]
    /// [--pipeline N] [--auth-token T] [--route A,B,...] [--cache-dir D]
    /// [--cache-cap CAP] [--gc-every SECS] [--log PATH|none]
    /// [--log-cap CAP]`.
    pub fn from_args(args: &Args) -> Result<ServeConfig, String> {
        let mut cfg = ServeConfig::new(args.opt_or("addr", "127.0.0.1:7878"));
        let pos_usize = |name: &str, dflt: usize| -> Result<usize, String> {
            match args.opt(name) {
                None => Ok(dflt),
                Some(s) => s
                    .parse()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| format!("bad --{name} '{s}' (positive integer)")),
            }
        };
        cfg.workers = pos_usize("workers", cfg.workers)?;
        cfg.queue_cap = pos_usize("queue", cfg.workers * 4)?;
        cfg.pipeline = pos_usize("pipeline", cfg.pipeline)?;
        cfg.auth_token = args.opt("auth-token").map(str::to_string);
        if let Some(list) = args.opt("route") {
            cfg.route = list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
            if cfg.route.is_empty() {
                return Err(format!("bad --route '{list}' (comma-separated backend addresses)"));
            }
        }
        if let Some(d) = args.opt("cache-dir") {
            cfg.cache_dir = PathBuf::from(d);
        }
        if let Some(s) = args.opt("cache-cap") {
            cfg.cache_cap = Some(CacheCap::parse(s)?);
        }
        cfg.gc_every = Duration::from_secs(pos_usize("gc-every", 60)? as u64);
        match args.opt("log") {
            None => {}
            Some("none") => cfg.log = LogTarget::Disabled,
            Some(p) => cfg.log = LogTarget::Path(PathBuf::from(p)),
        }
        if let Some(s) = args.opt("log-cap") {
            cfg.log_cap = CacheCap::parse(s)?.max_bytes.ok_or_else(|| {
                format!("bad --log-cap '{s}' (a byte size like 8M, not an entry count)")
            })?;
        }
        Ok(cfg)
    }
}

/// A bound-but-not-yet-running daemon. [`Server::bind`] claims the
/// socket (so callers learn the ephemeral port before spawning clients);
/// [`Server::run`] serves until a `shutdown` request.
pub struct Server {
    listener: TcpListener,
    cfg: ServeConfig,
    addr: SocketAddr,
}

impl Server {
    pub fn bind(cfg: ServeConfig) -> Result<Server, String> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("serve: cannot bind {}: {e}", cfg.addr))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("serve: cannot resolve local addr: {e}"))?;
        if !addr.ip().is_loopback() && cfg.auth_token.is_none() {
            return Err(format!(
                "serve: refusing to bind non-loopback {addr} without --auth-token (the \
                 protocol is plaintext; a shared secret is the minimum bar for an open port)"
            ));
        }
        Ok(Server { listener, cfg, addr })
    }

    /// The actually-bound address (resolves `:0` to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve requests until a `shutdown` request, then drain gracefully:
    /// stop accepting, finish queued connections and in-flight requests,
    /// run the final GC (journal compaction included), and return.
    ///
    /// With a non-empty [`ServeConfig::route`] this delegates to
    /// [`Server::run_front`] — `ctx` is not touched (front daemons never
    /// compile); `cascade serve` skips building it entirely.
    pub fn run(&self, ctx: &CompileCtx) -> Result<(), String> {
        if !self.cfg.route.is_empty() {
            return self.run_front();
        }
        let disk = DiskCache::at(&self.cfg.cache_dir);
        // Key-addressed `encode` loads go through side handles so the
        // shared session's cache statistics stay a pure account of the
        // compile/evaluate path.
        let aux = DiskCache::at(&self.cfg.cache_dir);
        // Per-daemon registry (not [`crate::obs::global`]) so co-resident
        // daemons — the test suite runs several in one process — never
        // share counts; the session core feeds its compile-stage spans
        // into the same registry the `metrics` op renders.
        let reg = Arc::new(Registry::new());
        let mut core = SessionCore::ephemeral(ctx, Some(&disk));
        core.set_obs(reg.clone());
        let engine = Engine::Local(LocalEngine {
            core,
            disk: &disk,
            aux,
            prov: std::array::from_fn(|_| AtomicUsize::new(0)),
        });
        let state = self.make_state(engine, reg);
        println!(
            "serve: listening on {} ({} worker(s), queue {}, pipeline {}, cache {})",
            self.addr,
            self.cfg.workers,
            self.cfg.queue_cap,
            self.cfg.pipeline,
            self.cfg.cache_dir.display()
        );
        self.announce(&state, "local");
        self.serve_loop(&state);

        let Engine::Local(local) = &state.engine else { unreachable!() };
        if let Some(cap) = &self.cfg.cache_cap {
            let r = disk.artifacts().gc(cap);
            println!("serve: final gc: {}", r.summary());
            state.log_gc(&r);
        }
        let stats = local.core.stats();
        println!(
            "serve: drained after {} request(s) ({} fresh compile(s), {} busy rejection(s), \
             {} error(s))",
            state.requests.load(Ordering::SeqCst),
            stats.misses,
            state.busy.load(Ordering::SeqCst),
            state.errors.load(Ordering::SeqCst)
        );
        println!("{}", disk.stat_string());
        let mut drain = Json::obj();
        drain
            .set("ts", now_ms())
            .set("event", "drain")
            .set("requests", state.requests.load(Ordering::SeqCst))
            .set("fresh_compiles", stats.misses)
            .set("busy_rejections", state.busy.load(Ordering::SeqCst))
            .set("errors", state.errors.load(Ordering::SeqCst));
        state.log_event(&drain);
        Ok(())
    }

    /// Serve as a hash-routing front: no compiler, no cache — every
    /// `compile`/`encode` forwards to the backend owning the request's
    /// effective key, `stat`/`metrics`/`ping` aggregate the topology.
    /// Fails fast if a *reachable* backend speaks the wrong protocol
    /// version or refuses the handshake; unreachable backends only warn
    /// (they may come up later; requests meanwhile get `backend_down`).
    pub fn run_front(&self) -> Result<(), String> {
        let reg = Arc::new(Registry::new());
        let front = route::FrontEngine::new(
            &self.cfg.route,
            self.cfg.auth_token.clone(),
            BACKEND_TIMEOUT,
        )?;
        let state = self.make_state(Engine::Front(front), reg);
        println!(
            "serve: front on {} ({} worker(s), queue {}, pipeline {}) routing to {} backend(s): \
             {}",
            self.addr,
            self.cfg.workers,
            self.cfg.queue_cap,
            self.cfg.pipeline,
            self.cfg.route.len(),
            self.cfg.route.join(", ")
        );
        self.announce(&state, "front");
        self.serve_loop(&state);

        let Engine::Front(front) = &state.engine else { unreachable!() };
        let routed = front.drain_summary();
        println!(
            "serve: front drained after {} request(s) ({} busy rejection(s), {} error(s)); \
             forwarded: {routed}",
            state.requests.load(Ordering::SeqCst),
            state.busy.load(Ordering::SeqCst),
            state.errors.load(Ordering::SeqCst)
        );
        let mut drain = Json::obj();
        drain
            .set("ts", now_ms())
            .set("event", "drain")
            .set("requests", state.requests.load(Ordering::SeqCst))
            .set("busy_rejections", state.busy.load(Ordering::SeqCst))
            .set("errors", state.errors.load(Ordering::SeqCst))
            .set("routed", routed);
        state.log_event(&drain);
        Ok(())
    }

    /// Assemble the shared per-run state around an engine.
    fn make_state<'a>(&'a self, engine: Engine<'a>, reg: Arc<Registry>) -> ServeState<'a> {
        let reqlog = match &self.cfg.log {
            LogTarget::Disabled => None,
            LogTarget::Default => Some(RequestLog::open(
                self.cfg.cache_dir.join("serve_requests.jsonl"),
                self.cfg.log_cap,
            )),
            LogTarget::Path(p) => Some(RequestLog::open(p, self.cfg.log_cap)),
        };
        ServeState {
            cfg: &self.cfg,
            addr: self.addr,
            engine,
            reg,
            reqlog,
            shutdown: AtomicBool::new(false),
            requests: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            busy: AtomicUsize::new(0),
            hk_mx: Mutex::new(()),
            hk_cv: Condvar::new(),
        }
    }

    /// Print the request-log location and append the `start` event.
    fn announce(&self, state: &ServeState<'_>, role: &str) {
        if let Some(log) = &state.reqlog {
            println!("serve: request log: {}", log.path().display());
        }
        let mut start = Json::obj();
        start
            .set("ts", now_ms())
            .set("event", "start")
            .set("role", role)
            .set("addr", self.addr.to_string())
            .set("workers", self.cfg.workers)
            .set("queue_cap", self.cfg.queue_cap)
            .set("pipeline", self.cfg.pipeline);
        state.log_event(&start);
    }

    /// The accept/worker/housekeeping loop both flavors share; returns
    /// once the drain completes and every thread has joined.
    fn serve_loop(&self, state: &ServeState<'_>) {
        let queue: Bounded<Job> = Bounded::new(self.cfg.queue_cap);
        // Rejected connections are answered off the accept path: the
        // acceptor's only duty on overflow is an O(1) hand-off (or an
        // O(1) drop when even the rejector is saturated), so a busy storm
        // cannot serialize `accept()` behind socket writes — the daemon
        // stays reachable exactly when it is busiest.
        let rejects: Bounded<TcpStream> = Bounded::new(32);

        std::thread::scope(|s| {
            for _ in 0..self.cfg.workers {
                s.spawn(|| {
                    while let Some(job) = queue.pop() {
                        let waited = job.queued_at.elapsed();
                        state
                            .reg
                            .histogram(
                                "serve_queue_seconds",
                                "connection queue wait before a worker picks it up",
                            )
                            .observe_duration(waited);
                        handle_conn(state, job.stream, waited);
                    }
                });
            }
            s.spawn(|| {
                let busy = response_error(ErrorCode::Busy, "request queue full; retry");
                while let Some(conn) = rejects.pop() {
                    let _ = conn.set_write_timeout(Some(Duration::from_secs(5)));
                    write_final(&conn, &busy, Duration::from_millis(250));
                }
            });
            s.spawn(|| housekeeping(state));

            for conn in self.listener.incoming() {
                if state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                if let Err(job) = queue.try_push(Job { stream, queued_at: Instant::now() }) {
                    state.busy.fetch_add(1, Ordering::SeqCst);
                    state
                        .reg
                        .counter("serve_busy_total", "connections bounced busy at the acceptor")
                        .inc();
                    // Best-effort busy response; a saturated rejector
                    // drops the connection unanswered (bounded memory
                    // beats a polite reply under a flood).
                    let _ = rejects.try_push(job.stream);
                }
            }
            // Drain: queued connections are still served, then workers
            // see `None` and exit; the scope joins everything.
            queue.close();
            rejects.close();
        });
    }
}

/// A connection waiting for a worker, stamped at accept time so its
/// first request reports the real accept-queue wait in `queue_ms`.
struct Job {
    stream: TcpStream,
    queued_at: Instant,
}

/// How requests are answered: locally through the session core, or
/// forwarded to the owning backend.
enum Engine<'a> {
    Local(LocalEngine<'a>),
    Front(route::FrontEngine),
}

/// Shared server state, borrowed by every worker for the scope of
/// [`Server::run`] / [`Server::run_front`].
struct ServeState<'a> {
    cfg: &'a ServeConfig,
    addr: SocketAddr,
    engine: Engine<'a>,
    /// Per-daemon metrics registry; rendered by the `metrics` op.
    reg: Arc<Registry>,
    /// Structured JSONL request/event log (`None` under `--log none`).
    reqlog: Option<RequestLog>,
    shutdown: AtomicBool,
    requests: AtomicUsize,
    errors: AtomicUsize,
    busy: AtomicUsize,
    hk_mx: Mutex<()>,
    hk_cv: Condvar,
}

impl ServeState<'_> {
    /// Append one structured record to the request log (no-op when the
    /// log is disabled).
    fn log_event(&self, rec: &Json) {
        if let Some(log) = &self.reqlog {
            log.append(rec);
        }
    }

    /// Record a GC pass: eviction counter plus a structured `gc` event
    /// (the stdout `serve: gc:` line stays — scripts grep it).
    fn log_gc(&self, r: &crate::explore::GcReport) {
        self.reg
            .counter("cache_gc_evictions_total", "artifacts evicted by the periodic/final GC")
            .add(r.evicted as u64);
        if r.evicted == 0 {
            return;
        }
        let mut rec = Json::obj();
        rec.set("ts", now_ms())
            .set("event", "gc")
            .set("evicted", r.evicted)
            .set("entries", r.entries_after)
            .set("bytes", r.bytes_after)
            .set("pinned", r.pinned);
        self.log_event(&rec);
    }

    /// Per-request bookkeeping, shared by every op (parse failures
    /// included, as op `invalid`): count and time the request, split
    /// successful compile/encode timing into `queue_ms` + `exec_ms`
    /// (`ms` stays their sum for wire compatibility), assemble the
    /// request's span tree, and append the request-log record. On a
    /// routed front the top-level timing members are re-measured — the
    /// client sees end-to-end time at the daemon it actually talked to —
    /// and the backend's own split is preserved under a nested
    /// `"backend"` member instead of being dropped.
    ///
    /// `ctx` is the request's wire trace context (None for untraced
    /// callers) and `kspans` the compile-stage spans the session core
    /// published while executing it. The span tree is numbered from
    /// `ctx.parent` (0 without a context) — `request` at base+1 with
    /// `queue` and `exec`/`forward` children, per-stage spans (kernel
    /// counters attached) under `exec`, and a routed backend's echoed
    /// spans grafted verbatim under `forward`. The tree is echoed in the
    /// response's `"trace"` member *only* when the caller sent a context
    /// (so untraced responses stay byte-identical to v2), and always
    /// written to the request log.
    fn finish_request(
        &self,
        op: &str,
        mut resp: Json,
        queued: Duration,
        exec: Duration,
        ctx: Option<TraceCtx>,
        kspans: &[crate::obs::trace::SpanRecord],
    ) -> Json {
        self.reg
            .counter(
                &labeled("serve_requests_total", "op", op),
                "requests handled, by op (`invalid` = unparseable)",
            )
            .inc();
        self.reg
            .histogram(
                &labeled("serve_request_seconds", "op", op),
                "request execution time (queue wait excluded)",
            )
            .observe_duration(exec);
        let ok = resp.get("ok").and_then(Json::as_bool) == Some(true);
        if !ok {
            self.reg.counter("serve_errors_total", "error responses").inc();
        }
        let traced_op = matches!(op, "compile" | "encode");
        // A response that already carries a timing split came from a
        // backend: keep the backend's measurements under "backend"
        // before stamping this daemon's own.
        let mut backend_timing: Option<Json> = None;
        if ok && traced_op && resp.get("queue_ms").is_some() {
            let mut b = Json::obj();
            for k in ["queue_ms", "exec_ms", "ms"] {
                if let Some(v) = resp.remove(k) {
                    b.set(k, v);
                }
            }
            backend_timing = Some(b);
        }
        // The backend's echoed span tree (routed requests only; the
        // front's forwarder already renamed its root to `backend:<addr>`
        // and numbered it under our forward span).
        let backend_trace = if ok && traced_op {
            resp.remove("trace").and_then(|t| trace_from_json(&t).ok())
        } else {
            None
        };
        let queue_ms = queued.as_secs_f64() * 1e3;
        let exec_ms = exec.as_secs_f64() * 1e3;
        if ok && traced_op {
            if let Some(b) = &backend_timing {
                resp.set("backend", b.clone());
            }
            resp.set("queue_ms", queue_ms)
                .set("exec_ms", exec_ms)
                .set("ms", queue_ms + exec_ms);
        }
        let trace = if ok && traced_op && (ctx.is_some() || self.reqlog.is_some()) {
            let base = ctx.map(|c| c.parent).unwrap_or(0);
            let id = ctx
                .map(|c| c.id)
                .or_else(|| backend_trace.as_ref().map(|(id, _)| *id))
                .unwrap_or_else(crate::obs::trace::gen_trace_id);
            let ns = |d: Duration| d.as_nanos() as u64;
            let work = base + 3;
            let work_name = if backend_trace.is_some() { "forward" } else { "exec" };
            let plain = |id: u64, parent: u64, name: &str, t: Duration| TraceSpan {
                id,
                parent,
                name: name.to_string(),
                ns: ns(t),
                counters: Vec::new(),
            };
            let mut spans = vec![
                plain(base + 1, base, "request", queued + exec),
                plain(base + 2, base + 1, "queue", queued),
                plain(work, base + 1, work_name, exec),
            ];
            for (k, s) in kspans.iter().enumerate() {
                spans.push(TraceSpan {
                    id: work + 1 + k as u64,
                    parent: work,
                    name: format!("stage:{}", s.stage),
                    ns: s.nanos,
                    counters: s
                        .counters
                        .iter()
                        .map(|(name, n)| (name.to_string(), *n))
                        .collect(),
                });
            }
            if let Some((_, bs)) = backend_trace {
                spans.extend(bs);
            }
            if ctx.is_some() {
                resp.set("trace", trace_json(id, &spans));
            }
            Some((id, spans))
        } else {
            None
        };
        if self.reqlog.is_some() {
            let mut rec = Json::obj();
            rec.set("ts", now_ms())
                .set("event", "request")
                .set("op", op)
                .set("queue_ms", queue_ms)
                .set("exec_ms", exec_ms);
            if let Some(b) = backend_timing {
                rec.set("backend", b);
            }
            if let Some(k) = resp.get("key").and_then(Json::as_str) {
                rec.set("key", k);
            }
            if let Some(p) = resp.get("provenance").and_then(Json::as_str) {
                rec.set("provenance", p);
            }
            let outcome =
                if ok { "ok" } else { resp.get("code").and_then(Json::as_str).unwrap_or("error") };
            rec.set("outcome", outcome);
            if let Some((id, spans)) = &trace {
                rec.set("trace", trace_json(*id, spans));
            }
            self.log_event(&rec);
        }
        resp
    }

    /// Begin the drain: raise the flag (under the housekeeping lock so
    /// the sleeper cannot miss the notify), wake the housekeeper, and
    /// poke the acceptor out of `accept()` with a loopback connect. The
    /// wake connect is retried and a failure is logged — the acceptor
    /// only re-checks the flag after `accept()` returns, so a silently
    /// lost wake would leave the drain hanging until the next unrelated
    /// client connects.
    fn trigger_shutdown(&self) {
        {
            let _g = self.hk_mx.lock().unwrap();
            self.shutdown.store(true, Ordering::SeqCst);
            self.hk_cv.notify_all();
        }
        let target = wake_addr(self.addr);
        for _ in 0..3 {
            if TcpStream::connect_timeout(&target, Duration::from_secs(1)).is_ok() {
                return;
            }
        }
        eprintln!(
            "serve: warning: could not self-connect to {target} to unblock the acceptor; \
             the drain completes on the next incoming connection"
        );
    }

    /// Dispatch one parsed request through the engine. The bool asks the
    /// connection handler to trigger the drain after responding;
    /// `shutdown` is engine-agnostic (a front drains itself, never its
    /// backends — stopping a shared backend because one front was asked
    /// to stop would be a topology-wide surprise). `ctx` is the wire
    /// trace context: a routing front propagates it downstream so the
    /// backend's spans land under this request's forward span.
    fn handle_request(&self, req: Request, ctx: Option<TraceCtx>) -> (Json, bool) {
        if matches!(req, Request::Shutdown) {
            return (response_ok("shutdown"), true);
        }
        let resp = match &self.engine {
            Engine::Local(e) => e.handle(self, req),
            Engine::Front(e) => e.handle(self, req, ctx),
        };
        (resp, false)
    }
}

/// The local serving engine: the shared compile session and cache
/// handles behind every non-routed daemon.
struct LocalEngine<'a> {
    core: SessionCore<'a>,
    disk: &'a DiskCache,
    /// Side cache handles for key-addressed loads (see [`Server::run`]).
    aux: DiskCache,
    /// Responses by provenance: fresh, warm_mem, warm_art, warm_rec.
    prov: [AtomicUsize; 4],
}

impl LocalEngine<'_> {
    fn handle(&self, st: &ServeState<'_>, req: Request) -> Json {
        match req {
            Request::Ping => {
                let mut j = response_ok("ping");
                j.set("proto", PROTO_VERSION);
                j
            }
            // Handled engine-agnostically by [`ServeState::handle_request`].
            Request::Shutdown => response_ok("shutdown"),
            Request::Stat => self.stat_response(st),
            Request::Metrics => self.metrics_response(st),
            Request::Compile(q) => self.compile_response(st, &q),
            Request::Encode { key: Some(key), .. } => self.encode_stored(st, key),
            Request::Encode { key: None, query: Some(q) } => self.encode_point(st, &q),
            Request::Encode { key: None, query: None } => {
                response_error(ErrorCode::BadRequest, "encode: need \"key\" or \"app\"")
            }
        }
    }

    fn count_prov(&self, st: &ServeState<'_>, p: Provenance) {
        let i = match p {
            Provenance::Fresh => 0,
            Provenance::WarmMem => 1,
            Provenance::WarmArt => 2,
            Provenance::WarmRec => 3,
        };
        self.prov[i].fetch_add(1, Ordering::SeqCst);
        st.reg
            .counter(
                &labeled("serve_provenance_total", "provenance", p.tag()),
                "compile/encode responses by cache provenance",
            )
            .inc();
    }

    /// `stat`: the shared cache formatter plus server-lifetime counters.
    fn stat_response(&self, st: &ServeState<'_>) -> Json {
        let s = self.core.stats();
        let mut srv = Json::obj();
        srv.set("requests", st.requests.load(Ordering::SeqCst))
            .set("busy_rejections", st.busy.load(Ordering::SeqCst))
            .set("errors", st.errors.load(Ordering::SeqCst))
            .set("fresh_compiles", s.misses)
            .set("memory_hits", s.memory_hits)
            .set("disk_hits", s.disk_hits)
            .set("art_hits", s.art_hits)
            .set("ctx_builds", s.ctx_builds)
            .set("workers", st.cfg.workers)
            .set("queue_cap", st.cfg.queue_cap)
            .set("pipeline", st.cfg.pipeline);
        let mut prov = Json::obj();
        for (i, name) in ["fresh", "warm_mem", "warm_art", "warm_rec"].into_iter().enumerate() {
            prov.set(name, self.prov[i].load(Ordering::SeqCst));
        }
        srv.set("provenance", prov);
        let mut j = response_ok("stat");
        j.set("proto", PROTO_VERSION)
            .set("cache", self.disk.stat_json())
            .set("server", srv);
        j
    }

    /// `metrics`: publish scrape-time cache gauges into the registry,
    /// then render the deterministic text exposition (the response's
    /// `exposition` member; `cascade client metrics` prints it raw).
    fn metrics_response(&self, st: &ServeState<'_>) -> Json {
        self.core.publish_metrics(&st.reg);
        self.disk.publish_metrics(&st.reg);
        let mut j = response_ok("metrics");
        j.set("exposition", st.reg.expose());
        j
    }

    /// `compile`: resolve the point, evaluate through the shared session
    /// (dedup + caches), answer with key, provenance, metrics (timing is
    /// stamped by [`ServeState::finish_request`]).
    fn compile_response(&self, st: &ServeState<'_>, q: &proto::PointQuery) -> Json {
        let (spec, point) = match q.resolve() {
            Ok(sp) => sp,
            Err(e) => return response_error(ErrorCode::BadRequest, &e),
        };
        let (r, prov, key) = self.core.evaluate_with(&spec, &point);
        self.count_prov(st, prov);
        match r.metrics {
            Ok(m) => {
                let mut j = response_ok("compile");
                j.set("key", key_hex(key))
                    .set("provenance", prov.tag())
                    .set("metrics", metrics_json(&m));
                j
            }
            Err(e) => {
                let mut j = response_error(ErrorCode::CompileFailed, &e);
                j.set("key", key_hex(key));
                j
            }
        }
    }

    /// `encode` by point query: same dedup slot as `compile`, so a
    /// concurrent compile of the same key is reused, never repeated.
    fn encode_point(&self, st: &ServeState<'_>, q: &proto::PointQuery) -> Json {
        let (spec, point) = match q.resolve() {
            Ok(sp) => sp,
            Err(e) => return response_error(ErrorCode::BadRequest, &e),
        };
        let (key, res, prov) = self.core.compiled_with(&spec, &point);
        self.count_prov(st, prov);
        match res {
            Ok(c) => self.encode_response(st, key, prov, &c),
            Err(e) => {
                let mut j = response_error(ErrorCode::CompileFailed, &e);
                j.set("key", key_hex(key));
                j
            }
        }
    }

    /// `encode` by stored key: a pure artifact-store load (verified
    /// against the metrics record's fingerprint when one exists) — the
    /// daemon twin of `cascade encode --key HEX`, never compiles.
    fn encode_stored(&self, st: &ServeState<'_>, key: u64) -> Json {
        let expect = self.aux.load(key).map(|m| m.artifact_fp);
        match self.aux.artifacts().load(key, expect) {
            Some(c) => {
                self.count_prov(st, Provenance::WarmArt);
                self.encode_response(st, key, Provenance::WarmArt, &c)
            }
            None => {
                let msg = format!(
                    "no valid compiled artifact for key {} in {} (torn files are rejected, \
                     never trusted)",
                    key_hex(key),
                    self.aux.artifacts().dir().display()
                );
                response_error(ErrorCode::NotFound, &msg)
            }
        }
    }

    /// Assemble an `encode` success response around the bitstream text —
    /// exactly [`crate::arch::bitstream::Bitstream::to_text`], so a
    /// client writing the `bitstream` member to a file gets bytes
    /// identical to offline `cascade encode`.
    fn encode_response(
        &self,
        st: &ServeState<'_>,
        key: u64,
        prov: Provenance,
        c: &crate::pipeline::Compiled,
    ) -> Json {
        let t0 = Instant::now();
        let bs = crate::sim::encode::encode_compiled(c);
        st.reg
            .histogram("encode_seconds", crate::obs::help::ENCODE)
            .observe_duration(t0.elapsed());
        let mut j = response_ok("encode");
        j.set("key", key_hex(key))
            .set("provenance", prov.tag())
            .set("words", bs.len())
            .set("bitstream", bs.to_text());
        j
    }
}

/// Normalize an unspecified bind IP (`0.0.0.0` / `::`) to loopback so
/// the shutdown wake-connect always has a reachable target.
fn wake_addr(addr: SocketAddr) -> SocketAddr {
    let ip = match addr.ip() {
        IpAddr::V4(ip) if ip.is_unspecified() => IpAddr::V4(Ipv4Addr::LOCALHOST),
        IpAddr::V6(ip) if ip.is_unspecified() => IpAddr::V6(Ipv6Addr::LOCALHOST),
        ip => ip,
    };
    SocketAddr::new(ip, addr.port())
}

/// One JSON document, one line, one flush.
fn write_line(mut stream: &TcpStream, j: &Json) -> std::io::Result<()> {
    let mut line = j.to_string_compact();
    line.push('\n');
    stream.write_all(line.as_bytes())?;
    stream.flush()
}

/// Send a terminal response (`busy`, `oversized`, `shutting_down`)
/// without destroying it: closing a socket whose receive buffer still
/// holds unread client bytes makes the kernel send RST, which can flush
/// the in-flight response before the client reads it. So: respond,
/// half-close the send side (client sees data + FIN), then drain what
/// the client already sent — bounded in bytes and by `grace` per read,
/// so a flooding client cannot hold the caller (the acceptor passes a
/// short grace; workers can afford a longer one).
fn write_final(stream: &TcpStream, j: &Json, grace: Duration) {
    if write_line(stream, j).is_err() {
        return;
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(grace));
    let mut sink = [0u8; 4096];
    let mut budget: usize = 256 * 1024;
    let mut reader: &TcpStream = stream;
    loop {
        match reader.read(&mut sink) {
            Ok(0) => return,
            Ok(n) => match budget.checked_sub(n) {
                Some(rest) => budget = rest,
                None => return,
            },
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// The terminal drain refusal.
fn shutting_down() -> Json {
    response_error(ErrorCode::ShuttingDown, "daemon is draining")
}

/// Parse one request line under the daemon's auth policy: JSON first,
/// then the auth check, then trace-context and op decoding — an
/// unauthorized caller learns nothing about which ops exist or what
/// their schema is.
fn parse_authed(
    line: &str,
    token: Option<&str>,
) -> Result<(Request, Option<TraceCtx>), (ErrorCode, String)> {
    let j = Json::parse(line.trim()).map_err(|e| (ErrorCode::BadRequest, e))?;
    proto::check_auth(&j, token)?;
    let ctx = TraceCtx::from_json(&j)?;
    let req = Request::from_json(&j)?;
    Ok((req, ctx))
}

/// What [`LineReader::next`] found.
enum NextLine {
    /// One complete request line (newline stripped; possibly invalid
    /// UTF-8 replaced, which the JSON parser then rejects as a normal
    /// bad request).
    Line(String),
    /// Clean end of stream (a trailing partial line is discarded).
    Eof,
    /// The line exceeded [`MAX_REQUEST_LINE`] — respond and close, the
    /// framing downstream cannot be trusted.
    TooLong,
    /// The daemon began draining while the connection was idle.
    Shutdown,
    /// The connection's executor finished (wrote a terminal response or
    /// hit a write error) while the reader was idle — stop reading.
    Closed,
    /// Unrecoverable I/O error.
    Failed,
}

/// Incremental bounded line reader. Socket reads run under [`READ_POLL`]
/// timeouts so an idle connection re-checks the shutdown and
/// connection-done flags; partial data survives across timeouts (a slow
/// writer is not corrupted by the poll).
struct LineReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
}

impl<R: Read> LineReader<R> {
    fn new(inner: R) -> LineReader<R> {
        LineReader { inner, buf: Vec::new() }
    }

    fn next(&mut self, shutdown: &AtomicBool, done: &AtomicBool) -> NextLine {
        loop {
            if let Some(i) = self.buf.iter().position(|&b| b == b'\n') {
                // `i` is the line length; a terminated-but-over-bound
                // line is just as oversized as an unterminated flood.
                if i > MAX_REQUEST_LINE {
                    return NextLine::TooLong;
                }
                let rest = self.buf.split_off(i + 1);
                let mut line = std::mem::replace(&mut self.buf, rest);
                line.pop(); // the newline
                return NextLine::Line(String::from_utf8_lossy(&line).into_owned());
            }
            if self.buf.len() > MAX_REQUEST_LINE {
                return NextLine::TooLong;
            }
            let mut tmp = [0u8; 4096];
            match self.inner.read(&mut tmp) {
                Ok(0) => return NextLine::Eof,
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) => match e.kind() {
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                        if done.load(Ordering::SeqCst) {
                            return NextLine::Closed;
                        }
                        if shutdown.load(Ordering::SeqCst) {
                            return NextLine::Shutdown;
                        }
                    }
                    std::io::ErrorKind::Interrupted => {}
                    _ => return NextLine::Failed,
                },
            }
        }
    }
}

/// One unit of per-connection work, in strict arrival order.
enum Pending {
    /// A request line, stamped when the reader finished reading it — the
    /// executor charges `dequeue - stamp` to the request as its queue
    /// wait (a stalled blocking push counts: the time *was* spent
    /// waiting on this daemon).
    Req { line: String, enqueued_at: Instant },
    /// A terminal response (`oversized`, `shutting_down`): write it
    /// RST-proof and close. It rides the same ordered queue so it can
    /// never overtake the response to an earlier in-flight request.
    Terminal(Json),
}

/// Serve one connection, pipelined: a reader thread read-aheads request
/// lines into a [`Bounded`] queue (depth `--pipeline`; a full queue
/// blocks the reader, which is the TCP back-pressure point) while this
/// worker executes them strictly in order, so responses always match
/// request order. Malformed requests get a structured error and the
/// connection *stays open*; oversized lines and the drain produce
/// terminal responses that close it. `accept_wait` is the connection's
/// time in the accept queue, charged to its first request on top of that
/// request's own pipeline wait.
fn handle_conn(state: &ServeState<'_>, stream: TcpStream, mut accept_wait: Duration) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let pipeline: Bounded<Pending> = Bounded::new(state.cfg.pipeline);
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut reader = LineReader::new(&stream);
            loop {
                match reader.next(&state.shutdown, &done) {
                    NextLine::Line(line) => {
                        if line.trim().is_empty() {
                            continue;
                        }
                        let item = Pending::Req { line, enqueued_at: Instant::now() };
                        if pipeline.push(item).is_err() {
                            return; // executor closed the queue
                        }
                    }
                    NextLine::TooLong => {
                        let msg = format!(
                            "request line exceeds {MAX_REQUEST_LINE} bytes; closing connection"
                        );
                        let resp = response_error(ErrorCode::Oversized, &msg);
                        let _ = pipeline.push(Pending::Terminal(resp));
                        pipeline.close();
                        return;
                    }
                    NextLine::Shutdown => {
                        let _ = pipeline.push(Pending::Terminal(shutting_down()));
                        pipeline.close();
                        return;
                    }
                    NextLine::Eof | NextLine::Failed | NextLine::Closed => {
                        pipeline.close();
                        return;
                    }
                }
            }
        });

        let mut served_any = false;
        while let Some(item) = pipeline.pop() {
            match item {
                Pending::Req { line, enqueued_at } => {
                    if served_any && state.shutdown.load(Ordering::SeqCst) {
                        // Drain contract: a connection popped from the
                        // queue still gets its first pending request
                        // served, but a draining daemon takes no
                        // *further* requests — without this check a
                        // client that keeps pipelining would hold its
                        // worker, and the drain, hostage forever.
                        write_final(&stream, &shutting_down(), Duration::from_secs(2));
                        break;
                    }
                    served_any = true;
                    state.requests.fetch_add(1, Ordering::SeqCst);
                    let queued = enqueued_at.elapsed() + std::mem::take(&mut accept_wait);
                    state
                        .reg
                        .histogram(
                            "serve_request_queue_seconds",
                            "per-request wait from socket read to dispatch (pipelined \
                             read-ahead; a connection's first request adds its accept-queue \
                             time)",
                        )
                        .observe_duration(queued);
                    let t0 = Instant::now();
                    let auth = state.cfg.auth_token.as_deref();
                    let (op, resp, drain, tctx, kspans) = match parse_authed(&line, auth) {
                        Ok((req, tctx)) => {
                            let op = req.op();
                            // Collect the compile-stage spans the session
                            // core publishes while this request executes.
                            let ((resp, drain), kspans) = crate::obs::trace::with_publish(|| {
                                state.handle_request(req, tctx)
                            });
                            (op, resp, drain, tctx, kspans)
                        }
                        Err((code, msg)) => {
                            let op = match code {
                                ErrorCode::Unauthorized => "unauthorized",
                                _ => "invalid",
                            };
                            (op, response_error(code, &msg), false, None, Vec::new())
                        }
                    };
                    let resp =
                        state.finish_request(op, resp, queued, t0.elapsed(), tctx, &kspans);
                    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
                        state.errors.fetch_add(1, Ordering::SeqCst);
                    }
                    if drain {
                        // The shutdown ack is this connection's last word
                        // and the caller's only confirmation the drain
                        // began — send it RST-proof like every other
                        // terminal response (pipelined junk after
                        // `shutdown` must not clobber it).
                        write_final(&stream, &resp, Duration::from_secs(2));
                        state.trigger_shutdown();
                        break;
                    }
                    if write_line(&stream, &resp).is_err() {
                        break;
                    }
                }
                Pending::Terminal(resp) => {
                    write_final(&stream, &resp, Duration::from_secs(2));
                    break;
                }
            }
        }
        // Release the reader: it may be parked on a full queue (close
        // wakes it) or mid-read (the done flag turns the next poll
        // timeout into `Closed`); drain whatever it already queued.
        done.store(true, Ordering::SeqCst);
        pipeline.close();
        while pipeline.pop().is_some() {}
    });
}

/// Periodic GC (cap honoured, pins respected —
/// [`crate::explore::ArtifactStore::gc`]) plus a trim of idle non-base
/// compile contexts. Sleeps on a condvar so
/// [`ServeState::trigger_shutdown`] wakes it immediately. A routing
/// front has no cache or contexts to keep house for — this returns
/// immediately there.
fn housekeeping(state: &ServeState<'_>) {
    let Engine::Local(local) = &state.engine else { return };
    loop {
        let g = state.hk_mx.lock().unwrap();
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let (g, timeout) = state.hk_cv.wait_timeout(g, state.cfg.gc_every).unwrap();
        drop(g);
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if timeout.timed_out() {
            if let Some(cap) = &state.cfg.cache_cap {
                let r = local.disk.artifacts().gc(cap);
                if r.evicted > 0 {
                    println!("serve: gc: {}", r.summary());
                }
                state.log_gc(&r);
            }
            local.core.drop_arch_contexts();
        }
    }
}

/// `cascade serve` entry point: bind, then serve. A `--route` front
/// never compiles, so the (expensive) compile context is only built for
/// local daemons.
pub fn serve_cli(args: &Args) -> Result<(), String> {
    let cfg = ServeConfig::from_args(args)?;
    let front = !cfg.route.is_empty();
    let server = Server::bind(cfg)?;
    if front {
        server.run_front()
    } else {
        println!("building compile context (32x16 array, timing model)...");
        let ctx = CompileCtx::paper();
        server.run(&ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead as _;

    #[test]
    fn line_reader_splits_and_bounds() {
        let quiet = AtomicBool::new(false);
        let input = b"{\"op\":\"ping\"}\nsecond line\n".to_vec();
        let mut r = LineReader::new(std::io::Cursor::new(input));
        match r.next(&quiet, &quiet) {
            NextLine::Line(l) => assert_eq!(l, "{\"op\":\"ping\"}"),
            _ => panic!("expected a line"),
        }
        match r.next(&quiet, &quiet) {
            NextLine::Line(l) => assert_eq!(l, "second line"),
            _ => panic!("expected a line"),
        }
        assert!(matches!(r.next(&quiet, &quiet), NextLine::Eof));

        // A newline-free flood beyond the bound is TooLong, not a line.
        let flood = vec![b'x'; MAX_REQUEST_LINE + 2];
        let mut r = LineReader::new(std::io::Cursor::new(flood));
        assert!(matches!(r.next(&quiet, &quiet), NextLine::TooLong));

        // Exactly at the bound, with a terminator, still parses.
        let mut fits = vec![b'y'; MAX_REQUEST_LINE];
        fits.push(b'\n');
        let mut r = LineReader::new(std::io::Cursor::new(fits));
        assert!(matches!(r.next(&quiet, &quiet), NextLine::Line(_)));
    }

    #[test]
    fn parse_authed_order_is_json_then_auth_then_op() {
        // Bad JSON beats everything.
        let (code, _) = parse_authed("not json", Some("t")).unwrap_err();
        assert_eq!(code, ErrorCode::BadRequest);
        // Auth beats op decoding: an unauthorized caller cannot probe
        // the op vocabulary.
        let (code, _) = parse_authed("{\"op\":\"frobnicate\"}", Some("t")).unwrap_err();
        assert_eq!(code, ErrorCode::Unauthorized);
        let (code, _) = parse_authed("{\"op\":\"frobnicate\",\"auth\":\"t\"}", Some("t"))
            .unwrap_err();
        assert_eq!(code, ErrorCode::UnknownOp);
        // With auth satisfied (or no token) requests parse normally.
        assert_eq!(
            parse_authed("{\"op\":\"ping\",\"auth\":\"t\"}", Some("t")),
            Ok((Request::Ping, None))
        );
        assert_eq!(parse_authed("{\"op\":\"ping\"}", None), Ok((Request::Ping, None)));
        // A v3 trace context rides any op; garbage trace is bad_request.
        let (req, ctx) = parse_authed(
            "{\"op\":\"ping\",\"trace\":{\"id\":\"00000000000000ff\",\"parent\":3}}",
            None,
        )
        .unwrap();
        assert_eq!(req, Request::Ping);
        assert_eq!(ctx, Some(TraceCtx { id: 0xff, parent: 3 }));
        let (code, _) = parse_authed("{\"op\":\"ping\",\"trace\":7}", None).unwrap_err();
        assert_eq!(code, ErrorCode::BadRequest);
    }

    #[test]
    fn config_parses_v2_flags_and_rejects_junk() {
        let parse = |s: &str| {
            Args::parse(s.split_whitespace().map(|x| x.to_string()))
        };
        let cfg = ServeConfig::from_args(&parse(
            "serve --addr 127.0.0.1:0 --pipeline 8 --auth-token s3cret \
             --route 127.0.0.1:7871,127.0.0.1:7872",
        ))
        .unwrap();
        assert_eq!(cfg.pipeline, 8);
        assert_eq!(cfg.auth_token.as_deref(), Some("s3cret"));
        assert_eq!(cfg.route, vec!["127.0.0.1:7871".to_string(), "127.0.0.1:7872".to_string()]);

        let cfg = ServeConfig::from_args(&parse("serve")).unwrap();
        assert_eq!(cfg.pipeline, 4);
        assert!(cfg.auth_token.is_none());
        assert!(cfg.route.is_empty());

        assert!(ServeConfig::from_args(&parse("serve --pipeline 0")).is_err());
        assert!(ServeConfig::from_args(&parse("serve --pipeline x")).is_err());
        assert!(ServeConfig::from_args(&parse("serve --route ,,")).is_err());
    }

    fn test_config(dir: &std::path::Path, workers: usize) -> ServeConfig {
        let mut cfg = ServeConfig::new("127.0.0.1:0");
        cfg.workers = workers;
        cfg.queue_cap = workers * 4;
        cfg.cache_dir = dir.to_path_buf();
        cfg
    }

    /// Bind a test server, or `None` in environments without loopback
    /// networking (the rest of the suite must still pass there).
    fn bind_or_skip(cfg: ServeConfig) -> Option<Server> {
        match Server::bind(cfg) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("skipping serve test: {e}");
                None
            }
        }
    }

    fn roundtrip(stream: &mut TcpStream, line: &str) -> Json {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reader = std::io::BufReader::new(stream);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    }

    fn send_shutdown(addr: SocketAddr) {
        let mut s = TcpStream::connect(addr).unwrap();
        let r = roundtrip(&mut s, "{\"op\":\"shutdown\"}");
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn malformed_json_gets_error_and_connection_survives() {
        let dir = std::env::temp_dir().join(format!("cascade-serve-mal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let Some(server) = bind_or_skip(test_config(&dir, 1)) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();

            let r = roundtrip(&mut conn, "this is not json");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
            assert_eq!(r.get("code").and_then(Json::as_str), Some("bad_request"));

            // Same connection, next line: still served.
            let r = roundtrip(&mut conn, "{\"op\":\"ping\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            assert_eq!(r.get("proto").and_then(Json::as_u64), Some(PROTO_VERSION));

            // Unknown op: structured, connection still open.
            let r = roundtrip(&mut conn, "{\"op\":\"warp\"}");
            assert_eq!(r.get("code").and_then(Json::as_str), Some("unknown_op"));
            let r = roundtrip(&mut conn, "{\"op\":\"ping\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            drop(conn);
            send_shutdown(addr);
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auth_daemon_rejects_then_accepts_on_same_connection() {
        let dir = std::env::temp_dir().join(format!("cascade-serve-auth-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let mut cfg = test_config(&dir, 1);
        cfg.auth_token = Some("s3cret".to_string());
        let Some(server) = bind_or_skip(cfg) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();
            // Missing auth: structured refusal, connection survives.
            let r = roundtrip(&mut conn, "{\"op\":\"ping\"}");
            assert_eq!(r.get("code").and_then(Json::as_str), Some("unauthorized"));
            // Wrong auth: same.
            let r = roundtrip(&mut conn, "{\"op\":\"ping\",\"auth\":\"wrong\"}");
            assert_eq!(r.get("code").and_then(Json::as_str), Some("unauthorized"));
            // Right auth, same connection: served.
            let r = roundtrip(&mut conn, "{\"op\":\"ping\",\"auth\":\"s3cret\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            // Shutdown needs auth too.
            let r = roundtrip(&mut conn, "{\"op\":\"shutdown\"}");
            assert_eq!(r.get("code").and_then(Json::as_str), Some("unauthorized"));
            let r = roundtrip(&mut conn, "{\"op\":\"shutdown\",\"auth\":\"s3cret\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pipelined_requests_answer_in_order() {
        let dir = std::env::temp_dir().join(format!("cascade-serve-pipe-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let Some(server) = bind_or_skip(test_config(&dir, 1)) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();
            // Write a burst of distinct requests without reading, then
            // collect: responses must come back in request order.
            let burst = "{\"op\":\"ping\"}\n{\"op\":\"stat\"}\n{\"op\":\"ping\"}\n";
            conn.write_all(burst.as_bytes()).unwrap();
            let mut reader = std::io::BufReader::new(conn.try_clone().unwrap());
            let mut ops = Vec::new();
            for _ in 0..3 {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let j = Json::parse(line.trim()).unwrap();
                assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
                ops.push(j.get("op").and_then(Json::as_str).unwrap().to_string());
            }
            assert_eq!(ops, ["ping", "stat", "ping"]);
            drop(reader);
            drop(conn);
            send_shutdown(addr);
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_request_line_is_rejected() {
        let dir = std::env::temp_dir().join(format!("cascade-serve-big-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let Some(server) = bind_or_skip(test_config(&dir, 1)) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();
            let flood = "x".repeat(MAX_REQUEST_LINE + 64);
            let r = roundtrip(&mut conn, &flood);
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
            assert_eq!(r.get("code").and_then(Json::as_str), Some("oversized"));
            send_shutdown(addr);
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stat_reports_shared_cache_formatter() {
        let dir = std::env::temp_dir().join(format!("cascade-serve-stat-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let Some(server) = bind_or_skip(test_config(&dir, 1)) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();
            let r = roundtrip(&mut conn, "{\"op\":\"stat\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            assert_eq!(r.get("proto").and_then(Json::as_u64), Some(PROTO_VERSION));
            let cache = r.get("cache").expect("cache section");
            // Byte-compatible with `cascade cache stat --json` on the
            // same directory: one formatter, two consumers.
            let offline = DiskCache::at(&dir).stat_json();
            assert_eq!(cache, &offline);
            let srv = r.get("server").expect("server section");
            assert_eq!(srv.get("fresh_compiles").and_then(Json::as_u64), Some(0));
            assert_eq!(srv.get("pipeline").and_then(Json::as_u64), Some(4));
            send_shutdown(addr);
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn busy_response_when_queue_overflows() {
        // No workers ever pop (0 is clamped to 1 worker, so park it with
        // a held connection): fill the queue, then expect `busy`.
        let dir = std::env::temp_dir().join(format!("cascade-serve-busy-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let mut cfg = test_config(&dir, 1);
        cfg.queue_cap = 1;
        let Some(server) = bind_or_skip(cfg) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            // Occupy the single worker with an open, idle connection.
            let mut held = TcpStream::connect(addr).unwrap();
            let r = roundtrip(&mut held, "{\"op\":\"ping\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            // Fill the one queue slot with a second idle connection.
            let _parked = TcpStream::connect(addr).unwrap();
            std::thread::sleep(Duration::from_millis(100));
            // The third connection must be bounced with `busy`.
            let mut third = TcpStream::connect(addr).unwrap();
            let mut reader = std::io::BufReader::new(&mut third);
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let r = Json::parse(resp.trim()).unwrap();
            assert_eq!(r.get("code").and_then(Json::as_str), Some("busy"));
            // The queue is saturated, so a fresh shutdown connection
            // would be bounced too — drain via the connection the worker
            // is already serving.
            let r = roundtrip(&mut held, "{\"op\":\"shutdown\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
}
