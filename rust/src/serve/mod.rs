//! `cascade serve` — a concurrent compile/encode daemon over the
//! explore artifact store.
//!
//! The batch flow (`cascade explore`, `cascade encode`) pays a full
//! process start, context build and cache open per invocation. This
//! subsystem keeps all of that warm in one long-running process: a
//! `TcpListener` accepts newline-delimited-JSON requests ([`proto`]),
//! a bounded queue hands connections to a worker thread pool ([`pool`]),
//! and every `compile`/`encode` request resolves through the same
//! [`SessionCore`] — in-memory in-flight deduplication, the persistent
//! metrics cache, and the fingerprint-verified artifact store — so N
//! clients requesting the same effective point trigger exactly one
//! compile, and everyone else gets a warm answer. Responses carry the
//! point's effective cache key, the cache-hit provenance
//! (`fresh|warm_mem|warm_art|warm_rec`) and per-request timing.
//!
//! Resource bounds are explicit: the request queue is bounded (an
//! overloaded daemon answers `busy` in O(1) instead of queueing
//! unboundedly), the in-memory artifact cache is ephemeral (artifacts
//! live in RAM only while a compile is in flight; the disk store is the
//! durable layer), and a housekeeping thread periodically runs the
//! artifact-store GC under `--cache-cap` — pinned Pareto/knee survivors
//! are never evicted — and drops idle non-base compile contexts.
//!
//! Shutdown is graceful: a `shutdown` request stops the acceptor,
//! already-queued connections drain, in-flight requests complete and are
//! answered, then a final GC compacts the journal before the process
//! exits (the contract `docs/serve.md` specifies).
//!
//! The daemon is observable ([`crate::obs`], `docs/observability.md`):
//! every request is counted and timed into a per-daemon metrics registry
//! that the `metrics` wire op renders as deterministic Prometheus-style
//! text, compile/encode responses split `ms` into `queue_ms` + `exec_ms`,
//! and a size-bounded JSONL request log (`--log`, `--log-cap`) records
//! one structured line per request plus `start`/`gc`/`drain` lifecycle
//! events.
//!
//! ```no_run
//! use cascade::pipeline::CompileCtx;
//! use cascade::serve::{ServeConfig, Server};
//!
//! let mut cfg = ServeConfig::new("127.0.0.1:7878");
//! cfg.workers = 4;
//! let server = Server::bind(cfg).expect("bind");
//! println!("listening on {}", server.addr());
//! let ctx = CompileCtx::paper();
//! server.run(&ctx).expect("serve"); // returns after a `shutdown` request
//! ```
//!
//! Drive it without external tooling via the [`client`] subcommand:
//! `cascade client compile --addr HOST:PORT --app gaussian --tiny --fast`.

pub mod client;
pub mod pool;
pub mod proto;

use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::explore::runner::{Provenance, SessionCore};
use crate::explore::{CacheCap, DiskCache};
use crate::obs::{labeled, now_ms, Registry, RequestLog};
use crate::pipeline::CompileCtx;
use crate::util::cli::Args;
use crate::util::json::Json;

use pool::Bounded;
use proto::{
    key_hex, metrics_json, response_error, response_ok, ErrorCode, Request, MAX_REQUEST_LINE,
};

/// How long a worker's socket read blocks before it re-checks the
/// shutdown flag — the bound on how long an *idle* connection can delay
/// a drain (in-flight requests always complete regardless).
const READ_POLL: Duration = Duration::from_millis(500);

/// Per-connection write timeout: a client that stops reading its own
/// responses forfeits the connection rather than wedging a worker.
const WRITE_TIMEOUT: Duration = Duration::from_secs(60);

/// Where the JSONL request log goes (`--log`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LogTarget {
    /// `<cache_dir>/serve_requests.jsonl` (resolved at [`Server::run`]).
    Default,
    /// `--log none`: no request log.
    Disabled,
    /// `--log PATH`: an explicit file.
    Path(PathBuf),
}

/// Daemon configuration (`cascade serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, `HOST:PORT` (`:0` picks an ephemeral port —
    /// [`Server::addr`] reports the real one).
    pub addr: String,
    /// Worker threads — the compile concurrency bound.
    pub workers: usize,
    /// Pending-connection queue bound; the acceptor answers `busy`
    /// beyond it.
    pub queue_cap: usize,
    /// The `explore_cache/` directory to serve from (shared with
    /// `cascade explore` / `encode` / `cache`).
    pub cache_dir: PathBuf,
    /// Artifact-store budget for the periodic and final GC (`None` =
    /// never collect).
    pub cache_cap: Option<CacheCap>,
    /// Housekeeping period (GC + context-cache trim).
    pub gc_every: Duration,
    /// Request-log destination (JSONL, one record per request).
    pub log: LogTarget,
    /// Request-log rotation bound in bytes ([`RequestLog`] renames the
    /// full file to `.1` and starts fresh).
    pub log_cap: u64,
}

impl ServeConfig {
    /// Defaults: workers = available parallelism (capped at 8), queue =
    /// 4x workers, the default explore cache, no cap, 60 s housekeeping.
    pub fn new(addr: impl Into<String>) -> ServeConfig {
        let workers = std::thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(1);
        ServeConfig {
            addr: addr.into(),
            workers,
            queue_cap: workers * 4,
            cache_dir: DiskCache::default_dir(),
            cache_cap: None,
            gc_every: Duration::from_secs(60),
            log: LogTarget::Default,
            log_cap: crate::obs::DEFAULT_LOG_CAP,
        }
    }

    /// Parse `cascade serve --addr HOST:PORT [--workers N] [--queue N]
    /// [--cache-dir D] [--cache-cap CAP] [--gc-every SECS]
    /// [--log PATH|none] [--log-cap CAP]`.
    pub fn from_args(args: &Args) -> Result<ServeConfig, String> {
        let mut cfg = ServeConfig::new(args.opt_or("addr", "127.0.0.1:7878"));
        let pos_usize = |name: &str, dflt: usize| -> Result<usize, String> {
            match args.opt(name) {
                None => Ok(dflt),
                Some(s) => s
                    .parse()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| format!("bad --{name} '{s}' (positive integer)")),
            }
        };
        cfg.workers = pos_usize("workers", cfg.workers)?;
        cfg.queue_cap = pos_usize("queue", cfg.workers * 4)?;
        if let Some(d) = args.opt("cache-dir") {
            cfg.cache_dir = PathBuf::from(d);
        }
        if let Some(s) = args.opt("cache-cap") {
            cfg.cache_cap = Some(CacheCap::parse(s)?);
        }
        cfg.gc_every = Duration::from_secs(pos_usize("gc-every", 60)? as u64);
        match args.opt("log") {
            None => {}
            Some("none") => cfg.log = LogTarget::Disabled,
            Some(p) => cfg.log = LogTarget::Path(PathBuf::from(p)),
        }
        if let Some(s) = args.opt("log-cap") {
            cfg.log_cap = CacheCap::parse(s)?.max_bytes.ok_or_else(|| {
                format!("bad --log-cap '{s}' (a byte size like 8M, not an entry count)")
            })?;
        }
        Ok(cfg)
    }
}

/// A bound-but-not-yet-running daemon. [`Server::bind`] claims the
/// socket (so callers learn the ephemeral port before spawning clients);
/// [`Server::run`] serves until a `shutdown` request.
pub struct Server {
    listener: TcpListener,
    cfg: ServeConfig,
    addr: SocketAddr,
}

impl Server {
    pub fn bind(cfg: ServeConfig) -> Result<Server, String> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("serve: cannot bind {}: {e}", cfg.addr))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("serve: cannot resolve local addr: {e}"))?;
        Ok(Server { listener, cfg, addr })
    }

    /// The actually-bound address (resolves `:0` to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve requests until a `shutdown` request, then drain gracefully:
    /// stop accepting, finish queued connections and in-flight requests,
    /// run the final GC (journal compaction included), and return.
    pub fn run(&self, ctx: &CompileCtx) -> Result<(), String> {
        let disk = DiskCache::at(&self.cfg.cache_dir);
        // Key-addressed `encode` loads go through side handles so the
        // shared session's cache statistics stay a pure account of the
        // compile/evaluate path.
        let aux = DiskCache::at(&self.cfg.cache_dir);
        // Per-daemon registry (not [`crate::obs::global`]) so co-resident
        // daemons — the test suite runs several in one process — never
        // share counts; the session core feeds its compile-stage spans
        // into the same registry the `metrics` op renders.
        let reg = Arc::new(Registry::new());
        let mut core = SessionCore::ephemeral(ctx, Some(&disk));
        core.set_obs(reg.clone());
        let reqlog = match &self.cfg.log {
            LogTarget::Disabled => None,
            LogTarget::Default => Some(RequestLog::open(
                self.cfg.cache_dir.join("serve_requests.jsonl"),
                self.cfg.log_cap,
            )),
            LogTarget::Path(p) => Some(RequestLog::open(p, self.cfg.log_cap)),
        };
        let state = ServeState {
            cfg: &self.cfg,
            addr: self.addr,
            core,
            disk: &disk,
            aux,
            reg,
            reqlog,
            shutdown: AtomicBool::new(false),
            requests: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            busy: AtomicUsize::new(0),
            prov: std::array::from_fn(|_| AtomicUsize::new(0)),
            hk_mx: Mutex::new(()),
            hk_cv: Condvar::new(),
        };
        let queue: Bounded<Job> = Bounded::new(self.cfg.queue_cap);

        println!(
            "serve: listening on {} ({} worker(s), queue {}, cache {})",
            self.addr,
            self.cfg.workers,
            self.cfg.queue_cap,
            self.cfg.cache_dir.display()
        );
        if let Some(log) = &state.reqlog {
            println!("serve: request log: {}", log.path().display());
        }
        let mut start = Json::obj();
        start
            .set("ts", now_ms())
            .set("event", "start")
            .set("addr", self.addr.to_string())
            .set("workers", self.cfg.workers)
            .set("queue_cap", self.cfg.queue_cap);
        state.log_event(&start);

        // Rejected connections are answered off the accept path: the
        // acceptor's only duty on overflow is an O(1) hand-off (or an
        // O(1) drop when even the rejector is saturated), so a busy storm
        // cannot serialize `accept()` behind socket writes — the daemon
        // stays reachable exactly when it is busiest.
        let rejects: Bounded<TcpStream> = Bounded::new(32);

        std::thread::scope(|s| {
            for _ in 0..self.cfg.workers {
                s.spawn(|| {
                    while let Some(job) = queue.pop() {
                        let waited = job.queued_at.elapsed();
                        state
                            .reg
                            .histogram(
                                "serve_queue_seconds",
                                "connection queue wait before a worker picks it up",
                            )
                            .observe_duration(waited);
                        handle_conn(&state, job.stream, waited);
                    }
                });
            }
            s.spawn(|| {
                let busy = response_error(ErrorCode::Busy, "request queue full; retry");
                while let Some(conn) = rejects.pop() {
                    let _ = conn.set_write_timeout(Some(Duration::from_secs(5)));
                    write_final(&conn, &busy, Duration::from_millis(250));
                }
            });
            s.spawn(|| housekeeping(&state));

            for conn in self.listener.incoming() {
                if state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                if let Err(job) = queue.try_push(Job { stream, queued_at: Instant::now() }) {
                    state.busy.fetch_add(1, Ordering::SeqCst);
                    state
                        .reg
                        .counter("serve_busy_total", "connections bounced busy at the acceptor")
                        .inc();
                    // Best-effort busy response; a saturated rejector
                    // drops the connection unanswered (bounded memory
                    // beats a polite reply under a flood).
                    let _ = rejects.try_push(job.stream);
                }
            }
            // Drain: queued connections are still served, then workers
            // see `None` and exit; the scope joins everything.
            queue.close();
            rejects.close();
        });

        if let Some(cap) = &self.cfg.cache_cap {
            let r = disk.artifacts().gc(cap);
            println!("serve: final gc: {}", r.summary());
            state.log_gc(&r);
        }
        let stats = state.core.stats();
        println!(
            "serve: drained after {} request(s) ({} fresh compile(s), {} busy rejection(s), \
             {} error(s))",
            state.requests.load(Ordering::SeqCst),
            stats.misses,
            state.busy.load(Ordering::SeqCst),
            state.errors.load(Ordering::SeqCst)
        );
        println!("{}", disk.stat_string());
        let mut drain = Json::obj();
        drain
            .set("ts", now_ms())
            .set("event", "drain")
            .set("requests", state.requests.load(Ordering::SeqCst))
            .set("fresh_compiles", stats.misses)
            .set("busy_rejections", state.busy.load(Ordering::SeqCst))
            .set("errors", state.errors.load(Ordering::SeqCst));
        state.log_event(&drain);
        Ok(())
    }
}

/// A connection waiting for a worker, stamped at accept time so the
/// first request on it reports its real queue wait as `queue_ms`.
struct Job {
    stream: TcpStream,
    queued_at: Instant,
}

/// Shared server state, borrowed by every worker for the scope of
/// [`Server::run`].
struct ServeState<'a> {
    cfg: &'a ServeConfig,
    addr: SocketAddr,
    core: SessionCore<'a>,
    disk: &'a DiskCache,
    /// Side cache handles for key-addressed loads (see [`Server::run`]).
    aux: DiskCache,
    /// Per-daemon metrics registry; rendered by the `metrics` op.
    reg: Arc<Registry>,
    /// Structured JSONL request/event log (`None` under `--log none`).
    reqlog: Option<RequestLog>,
    shutdown: AtomicBool,
    requests: AtomicUsize,
    errors: AtomicUsize,
    busy: AtomicUsize,
    /// Responses by provenance: fresh, warm_mem, warm_art, warm_rec.
    prov: [AtomicUsize; 4],
    hk_mx: Mutex<()>,
    hk_cv: Condvar,
}

impl ServeState<'_> {
    fn count_prov(&self, p: Provenance) {
        let i = match p {
            Provenance::Fresh => 0,
            Provenance::WarmMem => 1,
            Provenance::WarmArt => 2,
            Provenance::WarmRec => 3,
        };
        self.prov[i].fetch_add(1, Ordering::SeqCst);
        self.reg
            .counter(
                &labeled("serve_provenance_total", "provenance", p.tag()),
                "compile/encode responses by cache provenance",
            )
            .inc();
    }

    /// Append one structured record to the request log (no-op when the
    /// log is disabled).
    fn log_event(&self, rec: &Json) {
        if let Some(log) = &self.reqlog {
            log.append(rec);
        }
    }

    /// Record a GC pass: eviction counter plus a structured `gc` event
    /// (the stdout `serve: gc:` line stays — scripts grep it).
    fn log_gc(&self, r: &crate::explore::GcReport) {
        self.reg
            .counter("cache_gc_evictions_total", "artifacts evicted by the periodic/final GC")
            .add(r.evicted as u64);
        if r.evicted == 0 {
            return;
        }
        let mut rec = Json::obj();
        rec.set("ts", now_ms())
            .set("event", "gc")
            .set("evicted", r.evicted)
            .set("entries", r.entries_after)
            .set("bytes", r.bytes_after)
            .set("pinned", r.pinned);
        self.log_event(&rec);
    }

    /// Per-request bookkeeping, shared by every op (parse failures
    /// included, as op `invalid`): count and time the request, split
    /// successful compile/encode timing into `queue_ms` + `exec_ms`
    /// (`ms` stays their sum for wire compatibility), and append the
    /// request-log record.
    fn finish_request(&self, op: &str, mut resp: Json, queued: Duration, exec: Duration) -> Json {
        self.reg
            .counter(
                &labeled("serve_requests_total", "op", op),
                "requests handled, by op (`invalid` = unparseable)",
            )
            .inc();
        self.reg
            .histogram(
                &labeled("serve_request_seconds", "op", op),
                "request execution time (queue wait excluded)",
            )
            .observe_duration(exec);
        let ok = resp.get("ok").and_then(Json::as_bool) == Some(true);
        if !ok {
            self.reg.counter("serve_errors_total", "error responses").inc();
        }
        let queue_ms = queued.as_secs_f64() * 1e3;
        let exec_ms = exec.as_secs_f64() * 1e3;
        if ok && matches!(op, "compile" | "encode") {
            resp.set("queue_ms", queue_ms)
                .set("exec_ms", exec_ms)
                .set("ms", queue_ms + exec_ms);
        }
        if self.reqlog.is_some() {
            let mut rec = Json::obj();
            rec.set("ts", now_ms())
                .set("event", "request")
                .set("op", op)
                .set("queue_ms", queue_ms)
                .set("exec_ms", exec_ms);
            if let Some(k) = resp.get("key").and_then(Json::as_str) {
                rec.set("key", k);
            }
            if let Some(p) = resp.get("provenance").and_then(Json::as_str) {
                rec.set("provenance", p);
            }
            let outcome =
                if ok { "ok" } else { resp.get("code").and_then(Json::as_str).unwrap_or("error") };
            rec.set("outcome", outcome);
            self.log_event(&rec);
        }
        resp
    }

    /// Begin the drain: raise the flag (under the housekeeping lock so
    /// the sleeper cannot miss the notify), wake the housekeeper, and
    /// poke the acceptor out of `accept()` with a loopback connect. The
    /// wake connect is retried and a failure is logged — the acceptor
    /// only re-checks the flag after `accept()` returns, so a silently
    /// lost wake would leave the drain hanging until the next unrelated
    /// client connects.
    fn trigger_shutdown(&self) {
        {
            let _g = self.hk_mx.lock().unwrap();
            self.shutdown.store(true, Ordering::SeqCst);
            self.hk_cv.notify_all();
        }
        let target = wake_addr(self.addr);
        for _ in 0..3 {
            if TcpStream::connect_timeout(&target, Duration::from_secs(1)).is_ok() {
                return;
            }
        }
        eprintln!(
            "serve: warning: could not self-connect to {target} to unblock the acceptor; \
             the drain completes on the next incoming connection"
        );
    }

    /// Dispatch one parsed request. The bool asks the connection handler
    /// to trigger the drain after responding.
    fn handle_request(&self, req: Request) -> (Json, bool) {
        match req {
            Request::Ping => (response_ok("ping"), false),
            Request::Shutdown => (response_ok("shutdown"), true),
            Request::Stat => (self.stat_response(), false),
            Request::Metrics => (self.metrics_response(), false),
            Request::Compile(q) => (self.compile_response(&q), false),
            Request::Encode { key: Some(key), .. } => (self.encode_stored(key), false),
            Request::Encode { key: None, query: Some(q) } => (self.encode_point(&q), false),
            Request::Encode { key: None, query: None } => {
                (response_error(ErrorCode::BadRequest, "encode: need \"key\" or \"app\""), false)
            }
        }
    }

    /// `stat`: the shared cache formatter plus server-lifetime counters.
    fn stat_response(&self) -> Json {
        let s = self.core.stats();
        let mut srv = Json::obj();
        srv.set("requests", self.requests.load(Ordering::SeqCst))
            .set("busy_rejections", self.busy.load(Ordering::SeqCst))
            .set("errors", self.errors.load(Ordering::SeqCst))
            .set("fresh_compiles", s.misses)
            .set("memory_hits", s.memory_hits)
            .set("disk_hits", s.disk_hits)
            .set("art_hits", s.art_hits)
            .set("ctx_builds", s.ctx_builds)
            .set("workers", self.cfg.workers)
            .set("queue_cap", self.cfg.queue_cap);
        let mut prov = Json::obj();
        for (i, name) in ["fresh", "warm_mem", "warm_art", "warm_rec"].into_iter().enumerate() {
            prov.set(name, self.prov[i].load(Ordering::SeqCst));
        }
        srv.set("provenance", prov);
        let mut j = response_ok("stat");
        j.set("cache", self.disk.stat_json()).set("server", srv);
        j
    }

    /// `metrics`: publish scrape-time cache gauges into the registry,
    /// then render the deterministic text exposition (the response's
    /// `exposition` member; `cascade client metrics` prints it raw).
    fn metrics_response(&self) -> Json {
        self.core.publish_metrics(&self.reg);
        self.disk.publish_metrics(&self.reg);
        let mut j = response_ok("metrics");
        j.set("exposition", self.reg.expose());
        j
    }

    /// `compile`: resolve the point, evaluate through the shared session
    /// (dedup + caches), answer with key, provenance, metrics (timing is
    /// stamped by [`ServeState::finish_request`]).
    fn compile_response(&self, q: &proto::PointQuery) -> Json {
        let (spec, point) = match q.resolve() {
            Ok(sp) => sp,
            Err(e) => return response_error(ErrorCode::BadRequest, &e),
        };
        let (r, prov, key) = self.core.evaluate_with(&spec, &point);
        self.count_prov(prov);
        match r.metrics {
            Ok(m) => {
                let mut j = response_ok("compile");
                j.set("key", key_hex(key))
                    .set("provenance", prov.tag())
                    .set("metrics", metrics_json(&m));
                j
            }
            Err(e) => {
                let mut j = response_error(ErrorCode::CompileFailed, &e);
                j.set("key", key_hex(key));
                j
            }
        }
    }

    /// `encode` by point query: same dedup slot as `compile`, so a
    /// concurrent compile of the same key is reused, never repeated.
    fn encode_point(&self, q: &proto::PointQuery) -> Json {
        let (spec, point) = match q.resolve() {
            Ok(sp) => sp,
            Err(e) => return response_error(ErrorCode::BadRequest, &e),
        };
        let (key, res, prov) = self.core.compiled_with(&spec, &point);
        self.count_prov(prov);
        match res {
            Ok(c) => self.encode_response(key, prov, &c),
            Err(e) => {
                let mut j = response_error(ErrorCode::CompileFailed, &e);
                j.set("key", key_hex(key));
                j
            }
        }
    }

    /// `encode` by stored key: a pure artifact-store load (verified
    /// against the metrics record's fingerprint when one exists) — the
    /// daemon twin of `cascade encode --key HEX`, never compiles.
    fn encode_stored(&self, key: u64) -> Json {
        let expect = self.aux.load(key).map(|m| m.artifact_fp);
        match self.aux.artifacts().load(key, expect) {
            Some(c) => {
                self.count_prov(Provenance::WarmArt);
                self.encode_response(key, Provenance::WarmArt, &c)
            }
            None => {
                let msg = format!(
                    "no valid compiled artifact for key {} in {} (torn files are rejected, \
                     never trusted)",
                    key_hex(key),
                    self.aux.artifacts().dir().display()
                );
                response_error(ErrorCode::NotFound, &msg)
            }
        }
    }

    /// Assemble an `encode` success response around the bitstream text —
    /// exactly [`crate::arch::bitstream::Bitstream::to_text`], so a
    /// client writing the `bitstream` member to a file gets bytes
    /// identical to offline `cascade encode`.
    fn encode_response(&self, key: u64, prov: Provenance, c: &crate::pipeline::Compiled) -> Json {
        let t0 = Instant::now();
        let bs = crate::sim::encode::encode_compiled(c);
        self.reg
            .histogram("encode_seconds", crate::obs::help::ENCODE)
            .observe_duration(t0.elapsed());
        let mut j = response_ok("encode");
        j.set("key", key_hex(key))
            .set("provenance", prov.tag())
            .set("words", bs.len())
            .set("bitstream", bs.to_text());
        j
    }
}

/// Normalize an unspecified bind IP (`0.0.0.0` / `::`) to loopback so
/// the shutdown wake-connect always has a reachable target.
fn wake_addr(addr: SocketAddr) -> SocketAddr {
    let ip = match addr.ip() {
        IpAddr::V4(ip) if ip.is_unspecified() => IpAddr::V4(Ipv4Addr::LOCALHOST),
        IpAddr::V6(ip) if ip.is_unspecified() => IpAddr::V6(Ipv6Addr::LOCALHOST),
        ip => ip,
    };
    SocketAddr::new(ip, addr.port())
}

/// One JSON document, one line, one flush.
fn write_line(mut stream: &TcpStream, j: &Json) -> std::io::Result<()> {
    let mut line = j.to_string_compact();
    line.push('\n');
    stream.write_all(line.as_bytes())?;
    stream.flush()
}

/// Send a terminal response (`busy`, `oversized`, `shutting_down`)
/// without destroying it: closing a socket whose receive buffer still
/// holds unread client bytes makes the kernel send RST, which can flush
/// the in-flight response before the client reads it. So: respond,
/// half-close the send side (client sees data + FIN), then drain what
/// the client already sent — bounded in bytes and by `grace` per read,
/// so a flooding client cannot hold the caller (the acceptor passes a
/// short grace; workers can afford a longer one).
fn write_final(stream: &TcpStream, j: &Json, grace: Duration) {
    if write_line(stream, j).is_err() {
        return;
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(grace));
    let mut sink = [0u8; 4096];
    let mut budget: usize = 256 * 1024;
    let mut reader: &TcpStream = stream;
    loop {
        match reader.read(&mut sink) {
            Ok(0) => return,
            Ok(n) => match budget.checked_sub(n) {
                Some(rest) => budget = rest,
                None => return,
            },
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// The terminal drain refusal.
fn shutting_down() -> Json {
    response_error(ErrorCode::ShuttingDown, "daemon is draining")
}

/// What [`LineReader::next`] found.
enum NextLine {
    /// One complete request line (newline stripped; possibly invalid
    /// UTF-8 replaced, which the JSON parser then rejects as a normal
    /// bad request).
    Line(String),
    /// Clean end of stream (a trailing partial line is discarded).
    Eof,
    /// The line exceeded [`MAX_REQUEST_LINE`] — respond and close, the
    /// framing downstream cannot be trusted.
    TooLong,
    /// The daemon began draining while the connection was idle.
    Shutdown,
    /// Unrecoverable I/O error.
    Failed,
}

/// Incremental bounded line reader. Socket reads run under [`READ_POLL`]
/// timeouts so an idle connection re-checks the shutdown flag; partial
/// data survives across timeouts (a slow writer is not corrupted by the
/// poll).
struct LineReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
}

impl<R: Read> LineReader<R> {
    fn new(inner: R) -> LineReader<R> {
        LineReader { inner, buf: Vec::new() }
    }

    fn next(&mut self, shutdown: &AtomicBool) -> NextLine {
        loop {
            if let Some(i) = self.buf.iter().position(|&b| b == b'\n') {
                // `i` is the line length; a terminated-but-over-bound
                // line is just as oversized as an unterminated flood.
                if i > MAX_REQUEST_LINE {
                    return NextLine::TooLong;
                }
                let rest = self.buf.split_off(i + 1);
                let mut line = std::mem::replace(&mut self.buf, rest);
                line.pop(); // the newline
                return NextLine::Line(String::from_utf8_lossy(&line).into_owned());
            }
            if self.buf.len() > MAX_REQUEST_LINE {
                return NextLine::TooLong;
            }
            let mut tmp = [0u8; 4096];
            match self.inner.read(&mut tmp) {
                Ok(0) => return NextLine::Eof,
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) => match e.kind() {
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                        if shutdown.load(Ordering::SeqCst) {
                            return NextLine::Shutdown;
                        }
                    }
                    std::io::ErrorKind::Interrupted => {}
                    _ => return NextLine::Failed,
                },
            }
        }
    }
}

/// Serve one connection: request lines in, response lines out, until
/// EOF, a fatal framing defect, or the drain. Malformed requests get a
/// structured error and the connection *stays open*. `queue_wait` is the
/// connection's time in the accept queue; it is charged to the first
/// request (later requests on the connection waited in no queue).
fn handle_conn(state: &ServeState<'_>, stream: TcpStream, mut queue_wait: Duration) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = LineReader::new(&stream);
    let mut served_any = false;
    loop {
        match reader.next(&state.shutdown) {
            NextLine::Line(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                if served_any && state.shutdown.load(Ordering::SeqCst) {
                    // Drain contract: a connection popped from the queue
                    // still gets its first pending request served, but a
                    // draining daemon takes no *further* requests —
                    // without this check a client that keeps sending
                    // (faster than the read poll) would hold its worker,
                    // and the drain, hostage forever.
                    write_final(&stream, &shutting_down(), Duration::from_secs(2));
                    return;
                }
                served_any = true;
                state.requests.fetch_add(1, Ordering::SeqCst);
                let queued = std::mem::take(&mut queue_wait);
                let t0 = Instant::now();
                let (op, resp, drain) = match Request::parse_line(&line) {
                    Ok(req) => {
                        let op = req.op();
                        let (resp, drain) = state.handle_request(req);
                        (op, resp, drain)
                    }
                    Err((code, msg)) => ("invalid", response_error(code, &msg), false),
                };
                let resp = state.finish_request(op, resp, queued, t0.elapsed());
                if resp.get("ok").and_then(Json::as_bool) != Some(true) {
                    state.errors.fetch_add(1, Ordering::SeqCst);
                }
                if drain {
                    // The shutdown ack is this connection's last word and
                    // the caller's only confirmation the drain began —
                    // send it RST-proof like every other terminal
                    // response (pipelined junk after `shutdown` must not
                    // clobber it).
                    write_final(&stream, &resp, Duration::from_secs(2));
                    state.trigger_shutdown();
                    return;
                }
                if write_line(&stream, &resp).is_err() {
                    return;
                }
            }
            NextLine::TooLong => {
                let msg =
                    format!("request line exceeds {MAX_REQUEST_LINE} bytes; closing connection");
                write_final(&stream, &response_error(ErrorCode::Oversized, &msg), READ_POLL);
                return;
            }
            NextLine::Shutdown => {
                write_final(&stream, &shutting_down(), Duration::from_secs(2));
                return;
            }
            NextLine::Eof | NextLine::Failed => return,
        }
    }
}

/// Periodic GC (cap honoured, pins respected —
/// [`crate::explore::ArtifactStore::gc`]) plus a trim of idle non-base
/// compile contexts. Sleeps on a condvar so
/// [`ServeState::trigger_shutdown`] wakes it immediately.
fn housekeeping(state: &ServeState<'_>) {
    loop {
        let g = state.hk_mx.lock().unwrap();
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let (g, timeout) = state.hk_cv.wait_timeout(g, state.cfg.gc_every).unwrap();
        drop(g);
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if timeout.timed_out() {
            if let Some(cap) = &state.cfg.cache_cap {
                let r = state.disk.artifacts().gc(cap);
                if r.evicted > 0 {
                    println!("serve: gc: {}", r.summary());
                }
                state.log_gc(&r);
            }
            state.core.drop_arch_contexts();
        }
    }
}

/// `cascade serve` entry point: bind, build the compile context, run.
pub fn serve_cli(args: &Args) -> Result<(), String> {
    let cfg = ServeConfig::from_args(args)?;
    let server = Server::bind(cfg)?;
    println!("building compile context (32x16 array, timing model)...");
    let ctx = CompileCtx::paper();
    server.run(&ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead as _;

    #[test]
    fn line_reader_splits_and_bounds() {
        let quiet = AtomicBool::new(false);
        let input = b"{\"op\":\"ping\"}\nsecond line\n".to_vec();
        let mut r = LineReader::new(std::io::Cursor::new(input));
        match r.next(&quiet) {
            NextLine::Line(l) => assert_eq!(l, "{\"op\":\"ping\"}"),
            _ => panic!("expected a line"),
        }
        match r.next(&quiet) {
            NextLine::Line(l) => assert_eq!(l, "second line"),
            _ => panic!("expected a line"),
        }
        assert!(matches!(r.next(&quiet), NextLine::Eof));

        // A newline-free flood beyond the bound is TooLong, not a line.
        let flood = vec![b'x'; MAX_REQUEST_LINE + 2];
        let mut r = LineReader::new(std::io::Cursor::new(flood));
        assert!(matches!(r.next(&quiet), NextLine::TooLong));

        // Exactly at the bound, with a terminator, still parses.
        let mut fits = vec![b'y'; MAX_REQUEST_LINE];
        fits.push(b'\n');
        let mut r = LineReader::new(std::io::Cursor::new(fits));
        assert!(matches!(r.next(&quiet), NextLine::Line(_)));
    }

    fn test_config(dir: &std::path::Path, workers: usize) -> ServeConfig {
        let mut cfg = ServeConfig::new("127.0.0.1:0");
        cfg.workers = workers;
        cfg.queue_cap = workers * 4;
        cfg.cache_dir = dir.to_path_buf();
        cfg
    }

    /// Bind a test server, or `None` in environments without loopback
    /// networking (the rest of the suite must still pass there).
    fn bind_or_skip(cfg: ServeConfig) -> Option<Server> {
        match Server::bind(cfg) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("skipping serve test: {e}");
                None
            }
        }
    }

    fn roundtrip(stream: &mut TcpStream, line: &str) -> Json {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reader = std::io::BufReader::new(stream);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    }

    fn send_shutdown(addr: SocketAddr) {
        let mut s = TcpStream::connect(addr).unwrap();
        let r = roundtrip(&mut s, "{\"op\":\"shutdown\"}");
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn malformed_json_gets_error_and_connection_survives() {
        let dir = std::env::temp_dir().join(format!("cascade-serve-mal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let Some(server) = bind_or_skip(test_config(&dir, 1)) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();

            let r = roundtrip(&mut conn, "this is not json");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
            assert_eq!(r.get("code").and_then(Json::as_str), Some("bad_request"));

            // Same connection, next line: still served.
            let r = roundtrip(&mut conn, "{\"op\":\"ping\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));

            // Unknown op: structured, connection still open.
            let r = roundtrip(&mut conn, "{\"op\":\"warp\"}");
            assert_eq!(r.get("code").and_then(Json::as_str), Some("unknown_op"));
            let r = roundtrip(&mut conn, "{\"op\":\"ping\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            drop(conn);
            send_shutdown(addr);
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_request_line_is_rejected() {
        let dir = std::env::temp_dir().join(format!("cascade-serve-big-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let Some(server) = bind_or_skip(test_config(&dir, 1)) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();
            let flood = "x".repeat(MAX_REQUEST_LINE + 64);
            let r = roundtrip(&mut conn, &flood);
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
            assert_eq!(r.get("code").and_then(Json::as_str), Some("oversized"));
            send_shutdown(addr);
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stat_reports_shared_cache_formatter() {
        let dir = std::env::temp_dir().join(format!("cascade-serve-stat-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let Some(server) = bind_or_skip(test_config(&dir, 1)) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();
            let r = roundtrip(&mut conn, "{\"op\":\"stat\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            let cache = r.get("cache").expect("cache section");
            // Byte-compatible with `cascade cache stat --json` on the
            // same directory: one formatter, two consumers.
            let offline = DiskCache::at(&dir).stat_json();
            assert_eq!(cache, &offline);
            let srv = r.get("server").expect("server section");
            assert_eq!(srv.get("fresh_compiles").and_then(Json::as_u64), Some(0));
            send_shutdown(addr);
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn busy_response_when_queue_overflows() {
        // No workers ever pop (0 is clamped to 1 worker, so park it with
        // a held connection): fill the queue, then expect `busy`.
        let dir = std::env::temp_dir().join(format!("cascade-serve-busy-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ctx = CompileCtx::paper();
        let mut cfg = test_config(&dir, 1);
        cfg.queue_cap = 1;
        let Some(server) = bind_or_skip(cfg) else { return };
        let addr = server.addr();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            // Occupy the single worker with an open, idle connection.
            let mut held = TcpStream::connect(addr).unwrap();
            let r = roundtrip(&mut held, "{\"op\":\"ping\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            // Fill the one queue slot with a second idle connection.
            let _parked = TcpStream::connect(addr).unwrap();
            std::thread::sleep(Duration::from_millis(100));
            // The third connection must be bounced with `busy`.
            let mut third = TcpStream::connect(addr).unwrap();
            let mut reader = std::io::BufReader::new(&mut third);
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let r = Json::parse(resp.trim()).unwrap();
            assert_eq!(r.get("code").and_then(Json::as_str), Some("busy"));
            // The queue is saturated, so a fresh shutdown connection
            // would be bounced too — drain via the connection the worker
            // is already serving.
            let r = roundtrip(&mut held, "{\"op\":\"shutdown\"}");
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
}
