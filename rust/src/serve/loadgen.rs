//! `cascade loadgen` — a deterministic open-loop load generator for the
//! serve daemon, with latency percentiles and a machine-readable
//! `BENCH_serve.json` snapshot.
//!
//! **Open loop.** Arrivals follow a precomputed schedule and are released
//! on time whether or not earlier requests have finished — so measured
//! latency honestly includes convoying when the daemon falls behind,
//! which is exactly what a closed loop (send → wait → send) hides. The
//! schedule is *deterministic*: inter-arrival gaps are `1/rate` jittered
//! by ±50% from [`Rng`] (splitmix64), so the same `--seed` reproduces
//! the same arrival times, the same request census, and the same
//! effective cache keys — a regression in `BENCH_serve.json` is a server
//! change, never schedule noise.
//!
//! **Request mix.** Each request targets one of `--spread` distinct
//! points (the point-seed axis is drawn from the schedule RNG; `--seed`
//! itself names the *schedule*), cycling round-robin; every
//! `--encode-every`-th request asks for the bitstream (`encode` by
//! point) instead of `compile`. Keys are computed client-side with the
//! same [`effective_key`] the daemon and the shard partition use, so the
//! generator can predict the per-backend split of a routed topology —
//! `--assert-split` checks each backend's `fresh_compiles` against
//! [`owner_of`] and fails loudly on a routing bug (the backends must
//! start cold and unshared for the census to be exact).
//!
//! **Measurement.** Latency is arrival-to-response per op, recorded in
//! [`crate::obs`] log₂ histograms; the report prints p50/p99/p999 and
//! the snapshot (`schema: cascade-bench-v1`, suite `serve`) mirrors the
//! `cascade bench` result fields so existing tooling can diff it.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::arch::params::ArchParams;
use crate::explore::runner::effective_key;
use crate::explore::shard::owner_of;
use crate::obs::metrics::quantile_of;
use crate::obs::{labeled, Registry};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::client::{Client, ClientOpts};
use super::pool::Bounded;
use super::proto::{PointQuery, Request};

/// Help string for the latency histogram family (also used to read the
/// family back, so the registry never sees two competing help texts).
const LATENCY_HELP: &str = "open-loop request latency, arrival to response (queueing included)";

/// Everything `cascade loadgen` needs to plan and drive one run.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Daemon (or routed front) to drive.
    pub addr: String,
    /// Total requests in the schedule.
    pub requests: usize,
    /// Mean arrival rate, requests/second.
    pub rate: f64,
    /// Concurrent keep-alive connections draining the schedule.
    pub conns: usize,
    /// Schedule seed (`--seed`): arrivals, point census and request mix
    /// are all functions of it.
    pub seed: u64,
    /// Distinct points in the mix (distinct point-seed axis values).
    pub spread: usize,
    /// Every Nth request is `encode` by point (0 = compile only).
    pub encode_every: usize,
    /// Per-socket-operation timeout for each connection.
    pub timeout: Duration,
    /// Shared secret for daemons started with `--auth-token`.
    pub auth: Option<String>,
    /// Point template (app/level/axes); its seed axis is overridden by
    /// the plan.
    pub base: PointQuery,
    /// Snapshot destination.
    pub out: PathBuf,
    /// After the run, verify each backend's `fresh_compiles` against the
    /// key partition (requires a routed front and cold backends).
    pub assert_split: bool,
}

/// One scheduled request: when it arrives, what it asks, and the
/// effective key it will hit (known client-side, before any network).
#[derive(Debug, Clone, PartialEq)]
pub struct Planned {
    pub at: Duration,
    pub req: Request,
    pub key: u64,
}

impl LoadSpec {
    /// Parse `cascade loadgen --app NAME [point flags] [--addr HOST:PORT]
    /// [--requests N] [--rate R] [--conns N] [--seed S] [--spread N]
    /// [--encode-every N] [--timeout SECS] [--auth-token T] [--out FILE]
    /// [--assert-split]`.
    ///
    /// `--seed` names the *schedule* seed. The point-seed axis belongs
    /// to the plan (`--spread` distinct values drawn from the schedule
    /// RNG), so a base point seed would be dead configuration — the
    /// template's seed is cleared and its value reused for the schedule.
    pub fn from_args(args: &Args) -> Result<LoadSpec, String> {
        let mut base = PointQuery::from_args(args)?;
        let seed = base.seed.take().unwrap_or(1);
        let timeout = match args.opt("timeout") {
            None => Duration::from_secs(600),
            Some(s) => Duration::from_secs(
                s.parse().map_err(|_| format!("loadgen: bad --timeout '{s}' (seconds)"))?,
            ),
        };
        Ok(LoadSpec {
            addr: args.opt_or("addr", "127.0.0.1:7878").to_string(),
            requests: args.opt_usize("requests", 64),
            rate: args.opt_f64("rate", 32.0),
            conns: args.opt_usize("conns", 4),
            seed,
            spread: args.opt_usize("spread", 4),
            encode_every: args.opt_usize("encode-every", 4),
            timeout,
            auth: args.opt("auth-token").map(str::to_string),
            base,
            out: PathBuf::from(args.opt_or("out", "BENCH_serve.json")),
            assert_split: args.flag("assert-split"),
        })
    }

    /// Materialize the deterministic schedule: same spec, same plan,
    /// byte for byte. Points are resolved (and validated) here, so a bad
    /// template fails before a single connection is opened.
    pub fn plan(&self) -> Result<Vec<Planned>, String> {
        if !self.rate.is_finite() || self.rate <= 0.0 {
            return Err(format!("loadgen: bad rate {} (positive requests/second)", self.rate));
        }
        if self.requests == 0 || self.conns == 0 || self.spread == 0 {
            return Err("loadgen: --requests, --conns and --spread must be >= 1".to_string());
        }
        if self.spread > 10_000 {
            return Err(format!("loadgen: --spread {} is absurd (max 10000)", self.spread));
        }
        let arch = ArchParams::paper();
        let mut rng = Rng::new(self.seed);
        // The distinct point-seed census, in draw order (duplicates
        // redrawn — the census size is part of the contract).
        let mut seeds: Vec<u64> = Vec::with_capacity(self.spread);
        while seeds.len() < self.spread {
            let s = rng.next_u64() % 100_000;
            if !seeds.contains(&s) {
                seeds.push(s);
            }
        }
        let gap = 1.0 / self.rate;
        let mut at = 0.0f64;
        let mut plan = Vec::with_capacity(self.requests);
        for i in 0..self.requests {
            at += gap * rng.gen_f64_range(0.5, 1.5);
            let mut q = self.base.clone();
            q.seed = Some(seeds[i % seeds.len()]);
            let (spec, point) = q.resolve()?;
            let key = effective_key(&spec, &arch, &point);
            let req = if self.encode_every > 0 && (i + 1) % self.encode_every == 0 {
                Request::Encode { key: None, query: Some(q) }
            } else {
                Request::Compile(q)
            };
            plan.push(Planned { at: Duration::from_secs_f64(at), req, key });
        }
        Ok(plan)
    }
}

/// How many *distinct* keys of `plan` each of `n` backends owns under
/// the shard partition — the predicted per-backend `fresh_compiles`
/// census for a cold topology (fresh compiles count distinct keys, not
/// requests: the session core dedups repeats).
pub fn expected_split(plan: &[Planned], n: usize) -> Vec<usize> {
    let mut split = vec![0usize; n.max(1)];
    let mut seen = BTreeSet::new();
    for p in plan {
        if seen.insert(p.key) {
            split[owner_of(p.key, n) - 1] += 1;
        }
    }
    split
}

/// What one run measured.
pub struct LoadReport {
    pub requests: usize,
    pub ok: usize,
    /// Failures by kind: a structured error's [`super::proto::ErrorCode`]
    /// tag (`bad_request`, `backend_down`, ...), `transport` (send/recv
    /// died even after the client's retry) or `connect`. Surfaced
    /// per-code in the printed summary and in the snapshot's `serve`
    /// totals (`errors_by_code`), next to the aggregate `errors` count.
    pub errors: BTreeMap<String, usize>,
    pub wall: Duration,
    pub distinct_keys: usize,
    /// Latency histograms, one family per op.
    reg: Registry,
}

impl LoadReport {
    /// p50/p99/p999 of one op's latency, in microseconds (`None` when
    /// the op never ran).
    pub fn percentiles_us(&self, op: &str) -> Option<(u64, u64, u64)> {
        let h = self.reg.histogram(&labeled("loadgen_latency_seconds", "op", op), LATENCY_HELP);
        if h.count() == 0 {
            return None;
        }
        Some((h.p50().unwrap_or(0), h.p99().unwrap_or(0), h.p999().unwrap_or(0)))
    }

    /// The `BENCH_serve.json` document: `cascade-bench-v1` result rows
    /// (one per op, same fields as `cascade bench --json` plus
    /// p50/p99/p999) and a `serve` section with run totals.
    pub fn to_json(&self, spec: &LoadSpec) -> Json {
        let mut j = Json::obj();
        j.set("schema", "cascade-bench-v1").set("suite", "serve");
        let mut results = Json::Arr(vec![]);
        for op in ["compile", "encode"] {
            let h =
                self.reg.histogram(&labeled("loadgen_latency_seconds", "op", op), LATENCY_HELP);
            if h.count() == 0 {
                continue;
            }
            let snap = h.snapshot();
            let ns = |q: f64| quantile_of(&snap, q).unwrap_or(0) * 1000;
            let mut r = Json::obj();
            r.set("name", format!("serve/{op}"))
                .set("iters", h.count())
                .set("median_ns", ns(0.50))
                .set("mean_ns", h.sum_nanos() / h.count().max(1))
                .set("p10_ns", ns(0.10))
                .set("p90_ns", ns(0.90))
                .set("p50_ns", ns(0.50))
                .set("p99_ns", ns(0.99))
                .set("p999_ns", ns(0.999));
            results.push(r);
        }
        j.set("results", results);
        let mut s = Json::obj();
        s.set("addr", spec.addr.as_str())
            .set("requests", self.requests)
            .set("ok", self.ok)
            .set("errors", self.errors.values().sum::<usize>())
            .set("errors_by_code", {
                let mut by = Json::obj();
                for (kind, n) in &self.errors {
                    by.set(kind, *n);
                }
                by
            })
            .set("wall_ms", self.wall.as_secs_f64() * 1e3)
            .set("throughput_rps", self.requests as f64 / self.wall.as_secs_f64().max(1e-9))
            .set("distinct_keys", self.distinct_keys)
            .set("conns", spec.conns)
            .set("rate", spec.rate)
            .set("seed", spec.seed)
            .set("spread", spec.spread);
        j.set("serve", s);
        j
    }
}

/// Drive one planned run: an open-loop dispatcher releases requests on
/// schedule into a queue that `spec.conns` keep-alive [`Client`]s drain.
/// Transport failures cost the worker its connection (redialed on the
/// next request) and are counted, never fatal — a load generator that
/// dies mid-run measures nothing.
pub fn run(spec: &LoadSpec, plan: &[Planned]) -> LoadReport {
    let reg = Registry::new();
    let queue: Bounded<usize> = Bounded::new(plan.len().max(1));
    let ok = AtomicUsize::new(0);
    let errors: Mutex<BTreeMap<String, usize>> = Mutex::new(BTreeMap::new());
    let record = |kind: &str| {
        *errors.lock().unwrap().entry(kind.to_string()).or_insert(0) += 1;
    };
    let start = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..spec.conns {
            s.spawn(|| {
                let opts =
                    ClientOpts { timeout: spec.timeout, retries: 1, auth: spec.auth.clone() };
                let mut client: Option<Client> = None;
                while let Some(i) = queue.pop() {
                    let p = &plan[i];
                    if client.is_none() {
                        match Client::connect(spec.addr.as_str(), opts.clone()) {
                            Ok(c) => client = Some(c),
                            Err(_) => {
                                record("connect");
                                continue;
                            }
                        }
                    }
                    let resp = client.as_mut().expect("just connected").request(&p.req);
                    let lat = start.elapsed().saturating_sub(p.at);
                    reg.histogram(&labeled("loadgen_latency_seconds", "op", p.req.op()),
                        LATENCY_HELP)
                        .observe_duration(lat);
                    match resp {
                        Ok(r) if r.get("ok").and_then(Json::as_bool) == Some(true) => {
                            ok.fetch_add(1, Ordering::SeqCst);
                        }
                        Ok(r) => {
                            record(r.get("code").and_then(Json::as_str).unwrap_or("error"));
                        }
                        Err(_) => {
                            client = None;
                            record("transport");
                        }
                    }
                }
            });
        }
        // Open-loop dispatcher: release each request at its scheduled
        // arrival whether or not the workers keep up — under overload
        // the convoy lands in the latency numbers, where it belongs.
        for (i, p) in plan.iter().enumerate() {
            let now = start.elapsed();
            if p.at > now {
                std::thread::sleep(p.at - now);
            }
            let _ = queue.try_push(i); // cap == plan.len(): never full
        }
        queue.close();
    });
    let distinct: BTreeSet<u64> = plan.iter().map(|p| p.key).collect();
    LoadReport {
        requests: plan.len(),
        ok: ok.load(Ordering::SeqCst),
        errors: errors.into_inner().unwrap(),
        wall: start.elapsed(),
        distinct_keys: distinct.len(),
        reg,
    }
}

/// Verify a routed front's per-backend `fresh_compiles` against the key
/// partition. Valid only when the backends started cold and nothing else
/// compiled into them — CI sets exactly that up.
fn assert_split(spec: &LoadSpec, plan: &[Planned]) -> Result<(), String> {
    let opts = ClientOpts { timeout: spec.timeout, retries: 1, auth: spec.auth.clone() };
    let mut c = Client::connect(spec.addr.as_str(), opts)?;
    let stat = c.stat()?;
    let backends = stat.get("backends").and_then(Json::as_arr).ok_or_else(|| {
        "loadgen: --assert-split needs a routed front (stat reports no backends)".to_string()
    })?;
    let expect = expected_split(plan, backends.len());
    for (i, b) in backends.iter().enumerate() {
        let addr = b.get("addr").and_then(Json::as_str).unwrap_or("?");
        let got = b
            .get("stat")
            .and_then(|s| s.get("server"))
            .and_then(|s| s.get("fresh_compiles"))
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("loadgen: backend {addr} is unreachable or reported no stats"))?;
        if got != expect[i] as u64 {
            return Err(format!(
                "loadgen: fresh-compile split mismatch at backend {addr}: got {got}, the key \
                 partition expects {} (full split {expect:?})",
                expect[i]
            ));
        }
        println!("loadgen: backend {addr}: fresh_compiles {got} matches the partition");
    }
    Ok(())
}

/// `cascade loadgen` entry point: plan, drive, report, snapshot.
pub fn run_cli(args: &Args) -> Result<(), String> {
    let spec = LoadSpec::from_args(args)?;
    let plan = spec.plan()?;
    println!(
        "loadgen: {} request(s) at ~{}/s over {} connection(s) to {} (schedule seed {}, {} \
         distinct point(s), encode every {})",
        spec.requests, spec.rate, spec.conns, spec.addr, spec.seed, spec.spread,
        spec.encode_every
    );
    let report = run(&spec, &plan);
    for op in ["compile", "encode"] {
        if let Some((p50, p99, p999)) = report.percentiles_us(op) {
            println!(
                "loadgen: {op}: p50 {:.1} ms, p99 {:.1} ms, p999 {:.1} ms",
                p50 as f64 / 1e3,
                p99 as f64 / 1e3,
                p999 as f64 / 1e3
            );
        }
    }
    if !report.errors.is_empty() {
        // One line per failure kind: protocol ErrorCode tags as the
        // daemon reported them, plus the client-side transport/connect
        // buckets. The same census lands in the snapshot's
        // serve.errors_by_code member.
        let parts: Vec<String> =
            report.errors.iter().map(|(kind, n)| format!("{kind}={n}")).collect();
        println!("loadgen: errors by code: {}", parts.join(" "));
    }
    let errs: usize = report.errors.values().sum();
    println!(
        "loadgen: {}/{} ok in {:.2} s ({:.1} req/s)",
        report.ok,
        report.requests,
        report.wall.as_secs_f64(),
        report.requests as f64 / report.wall.as_secs_f64().max(1e-9)
    );
    let doc = report.to_json(&spec);
    if let Some(dir) = spec.out.parent() {
        if !dir.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(dir);
        }
    }
    let mut text = doc.to_string_compact();
    text.push('\n');
    std::fs::write(&spec.out, text)
        .map_err(|e| format!("loadgen: cannot write {}: {e}", spec.out.display()))?;
    println!("loadgen: wrote {}", spec.out.display());
    if spec.assert_split {
        assert_split(&spec, &plan)?;
    }
    if errs > 0 {
        return Err(format!("loadgen: {errs} request(s) failed (census above)"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_for(seed: u64) -> LoadSpec {
        LoadSpec {
            addr: "127.0.0.1:0".into(),
            requests: 24,
            rate: 1000.0,
            conns: 2,
            seed,
            spread: 3,
            encode_every: 4,
            timeout: Duration::from_secs(1),
            auth: None,
            base: PointQuery {
                app: "gaussian".into(),
                tiny: true,
                fast: true,
                ..PointQuery::default()
            },
            out: PathBuf::from("BENCH_serve.json"),
            assert_split: false,
        }
    }

    #[test]
    fn plan_is_deterministic_per_seed() {
        let a = spec_for(7).plan().unwrap();
        let b = spec_for(7).plan().unwrap();
        assert_eq!(a, b, "same seed must reproduce the schedule exactly");
        let c = spec_for(8).plan().unwrap();
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.at != y.at || x.key != y.key),
            "different schedule seeds must produce different plans"
        );
    }

    #[test]
    fn plan_mixes_ops_and_arrivals_increase() {
        let plan = spec_for(1).plan().unwrap();
        assert_eq!(plan.len(), 24);
        let encodes = plan.iter().filter(|p| matches!(p.req, Request::Encode { .. })).count();
        assert_eq!(encodes, 24 / 4, "every 4th request is an encode");
        let mut prev = Duration::ZERO;
        for p in &plan {
            assert!(p.at > prev, "arrivals must be strictly increasing");
            prev = p.at;
        }
        let distinct: BTreeSet<u64> = plan.iter().map(|p| p.key).collect();
        assert_eq!(distinct.len(), 3, "--spread controls the distinct-point census");
    }

    #[test]
    fn expected_split_covers_every_distinct_key_once() {
        let plan = spec_for(1).plan().unwrap();
        for n in [1usize, 2, 3] {
            let split = expected_split(&plan, n);
            assert_eq!(split.len(), n);
            assert_eq!(split.iter().sum::<usize>(), 3, "distinct keys, partitioned totally");
        }
    }

    #[test]
    fn plan_validates_inputs() {
        let mut s = spec_for(1);
        s.rate = 0.0;
        assert!(s.plan().is_err());
        let mut s = spec_for(1);
        s.rate = f64::NAN;
        assert!(s.plan().is_err());
        let mut s = spec_for(1);
        s.requests = 0;
        assert!(s.plan().is_err());
        let mut s = spec_for(1);
        s.spread = 0;
        assert!(s.plan().is_err());
    }

    #[test]
    fn report_json_breaks_out_errors_by_code() {
        let mut errors = BTreeMap::new();
        errors.insert("bad_request".to_string(), 2usize);
        errors.insert("transport".to_string(), 1usize);
        let report = LoadReport {
            requests: 3,
            ok: 0,
            errors,
            wall: Duration::from_millis(5),
            distinct_keys: 1,
            reg: Registry::new(),
        };
        let j = report.to_json(&spec_for(1));
        let s = j.get("serve").expect("serve totals");
        assert_eq!(s.get("errors").and_then(Json::as_u64), Some(3));
        let by = s.get("errors_by_code").expect("per-code census");
        assert_eq!(by.get("bad_request").and_then(Json::as_u64), Some(2));
        assert_eq!(by.get("transport").and_then(Json::as_u64), Some(1));
        // BTreeMap ordering makes the member byte-deterministic.
        assert!(
            j.to_string_compact()
                .contains("\"errors_by_code\":{\"bad_request\":2,\"transport\":1}"),
            "{}",
            j.to_string_compact()
        );
    }

    #[test]
    fn from_args_reuses_seed_for_the_schedule() {
        let parse = |s: &str| Args::parse(s.split_whitespace().map(|x| x.to_string()));
        let spec =
            LoadSpec::from_args(&parse("loadgen --app gaussian --tiny --fast --seed 9")).unwrap();
        assert_eq!(spec.seed, 9, "--seed names the schedule seed");
        assert_eq!(spec.base.seed, None, "the point-seed axis belongs to the plan");
        assert_eq!(spec.requests, 64);
        assert_eq!(spec.conns, 4);
        assert!(LoadSpec::from_args(&parse("loadgen")).is_err(), "--app is required");
    }
}
