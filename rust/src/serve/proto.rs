//! Wire protocol of `cascade serve`: newline-delimited JSON over TCP.
//!
//! Every request and every response is exactly one JSON object on one
//! line (LF-terminated). Requests name their operation in `"op"`:
//! `ping`, `stat`, `metrics`, `compile`, `encode`, `shutdown`. Success responses
//! carry `"ok": true` plus per-op payload; failures carry `"ok": false`,
//! a machine-readable `"code"` (see [`ErrorCode`]) and a human `"error"`.
//! A malformed or unknown request gets a structured error response — the
//! connection stays open and usable. The one fatal request defect is a
//! line exceeding [`MAX_REQUEST_LINE`], after which the server cannot
//! trust the stream's framing and closes it (the error response is still
//! sent first). The full schema is specified in `docs/serve.md`.
//!
//! This is protocol **version 3** ([`PROTO_VERSION`], reported on `ping`
//! and `stat`). Version 2 made connections keep-alive and pipelined (any
//! number of request lines may be in flight, answered strictly in
//! order), let requests carry an `"auth"` shared secret (required when
//! the daemon was started with `--auth-token`, checked in constant time
//! — [`ct_eq`]), and gave the `--route` front daemon the
//! `backend_down`/`proto_mismatch` error codes. Version 3 adds the
//! optional distributed-trace context: a request may carry a `"trace"`
//! member ([`TraceCtx`]) naming the caller's trace id and parent span,
//! and a daemon that received one echoes a `"trace"` object
//! ([`TraceSpan`], [`trace_json`]) on `compile`/`encode` responses so a
//! routing front can graft the backend's span tree under its own.
//! Requests without `"trace"` get byte-identical v2 responses, so v3 is
//! wire-compatible with v2 clients.
//!
//! Request construction and parsing round-trip exactly, so the `cascade
//! client` subcommand and the daemon share one vocabulary:
//!
//! ```
//! use cascade::serve::proto::{PointQuery, Request};
//!
//! let q = PointQuery {
//!     app: "gaussian".into(),
//!     level: Some("compute".into()),
//!     seed: Some(1),
//!     tiny: true,
//!     fast: true,
//!     ..PointQuery::default()
//! };
//! let line = Request::Compile(q.clone()).to_json().to_string_compact();
//! assert!(line.contains("\"op\":\"compile\""));
//! assert_eq!(Request::parse_line(&line), Ok(Request::Compile(q)));
//! ```

use crate::explore::cache::PointMetrics;
use crate::explore::space::{ExplorePoint, ExploreSpec, Scale};
use crate::util::json::Json;

/// Upper bound on one request line's content (bytes, excluding the
/// terminating newline). Requests are small (an op plus a handful of
/// point fields); a line beyond this is a broken or hostile client and
/// the connection is closed after an [`ErrorCode::Oversized`] response.
/// Responses have no such bound — `encode` responses carry whole
/// bitstreams.
pub const MAX_REQUEST_LINE: usize = 64 * 1024;

/// Protocol version, carried as `"proto"` on `ping` and `stat`
/// responses. Version 2 added keep-alive pipelining, `auth`, the routed
/// front-daemon mode and the `unauthorized`/`backend_down`/
/// `proto_mismatch` error codes; version 3 added the optional `"trace"`
/// request member and echoed span trees ([`TraceCtx`]). A front daemon
/// refuses to talk to a backend reporting a version outside
/// [`COMPAT_PROTO_VERSIONS`] ([`ErrorCode::ProtoMismatch`]) —
/// mixed-version topologies would silently disagree on semantics. v2 is
/// accepted because every v3 addition is optional on the wire: a v2
/// backend simply never echoes a trace, and the front degrades to a
/// front-only span tree.
pub const PROTO_VERSION: u64 = 3;

/// Backend protocol versions a routing front will talk to.
pub const COMPAT_PROTO_VERSIONS: [u64; 2] = [2, PROTO_VERSION];

/// Machine-readable failure categories, carried in the `"code"` member
/// of error responses — the single source of truth for every code the
/// daemon (or a routing front) can emit; `docs/serve.md` tabulates them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Unparseable JSON, a missing/ill-typed member, or a point that
    /// fails spec validation.
    BadRequest,
    /// A well-formed request whose `"op"` the server does not implement.
    UnknownOp,
    /// The request line exceeded [`MAX_REQUEST_LINE`]; the connection is
    /// closed after this response.
    Oversized,
    /// The bounded request queue is full — retry later. Sent by the
    /// acceptor itself, so an overloaded daemon answers in O(1) instead
    /// of queueing unboundedly.
    Busy,
    /// `encode` by key found no valid artifact in the store.
    NotFound,
    /// The requested compile ran and failed (the message carries the
    /// compiler error).
    CompileFailed,
    /// The daemon is draining for shutdown and takes no new requests.
    ShuttingDown,
    /// The daemon requires `--auth-token` and the request's `"auth"`
    /// member is missing or wrong (compared in constant time).
    Unauthorized,
    /// A routing front could not reach the owning backend (connect,
    /// send or receive failed twice — the retry is built in). The
    /// message names the backend address.
    BackendDown,
    /// A routing front found a backend speaking a different
    /// [`PROTO_VERSION`]; the front refuses to route to it.
    ProtoMismatch,
}

impl ErrorCode {
    pub fn tag(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::Oversized => "oversized",
            ErrorCode::Busy => "busy",
            ErrorCode::NotFound => "not_found",
            ErrorCode::CompileFailed => "compile_failed",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Unauthorized => "unauthorized",
            ErrorCode::BackendDown => "backend_down",
            ErrorCode::ProtoMismatch => "proto_mismatch",
        }
    }
}

/// Constant-time string equality for the shared-secret comparison: the
/// run time depends only on the *presented* token's length, never on how
/// many leading bytes happen to match, so response timing leaks nothing
/// about the secret's content.
pub fn ct_eq(secret: &str, presented: &str) -> bool {
    let a = secret.as_bytes();
    let b = presented.as_bytes();
    let mut diff = a.len() ^ b.len();
    for (i, &pb) in b.iter().enumerate() {
        // Cycle over the secret so every presented byte costs one
        // comparison regardless of the secret's length.
        let sb = if a.is_empty() { 0 } else { a[i % a.len()] };
        diff |= (sb ^ pb) as usize;
    }
    diff == 0
}

/// Enforce the daemon's shared-secret policy on one request object:
/// with no configured token everything passes (and any presented
/// `"auth"` member is simply ignored); with a token, every op must
/// present a matching `"auth"` string.
pub fn check_auth(j: &Json, token: Option<&str>) -> Result<(), (ErrorCode, String)> {
    let Some(tok) = token else { return Ok(()) };
    match j.get("auth").and_then(Json::as_str) {
        Some(presented) if ct_eq(tok, presented) => Ok(()),
        Some(_) => Err((ErrorCode::Unauthorized, "bad auth token".to_string())),
        None => Err((
            ErrorCode::Unauthorized,
            "auth required: this daemon was started with --auth-token".to_string(),
        )),
    }
}

/// The v3 distributed-trace context, carried as an optional `"trace"`
/// request member: `{"trace":{"id":"<16 hex>","parent":N}}`. `id` is the
/// 64-bit trace id minted by the hop that started the trace (a routing
/// front, or a daemon tracing its own direct requests); `parent` is the
/// caller's span id under which the callee must hang its whole tree. A
/// callee numbers its spans from `parent + 1`, so the caller can graft
/// the echoed spans verbatim — no renumbering on either side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub id: u64,
    pub parent: u64,
}

impl TraceCtx {
    /// Read the optional `"trace"` member of a request object. Absent →
    /// `Ok(None)`; present but ill-formed → `bad_request`, because a
    /// caller that asked for tracing deserves to learn its context was
    /// dropped rather than silently losing the span tree.
    pub fn from_json(j: &Json) -> Result<Option<TraceCtx>, (ErrorCode, String)> {
        let Some(t) = j.get("trace") else { return Ok(None) };
        let bad = |msg: &str| (ErrorCode::BadRequest, format!("bad \"trace\": {msg}"));
        let hex = t
            .get("id")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing or non-string \"id\""))?;
        let id = u64::from_str_radix(hex, 16)
            .map_err(|_| bad(&format!("non-hex \"id\" '{hex}'")))?;
        let parent = t
            .get("parent")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad("missing or non-integer \"parent\""))?;
        Ok(Some(TraceCtx { id, parent }))
    }

    /// Write the `"trace"` member into a request object.
    pub fn write_json(&self, j: &mut Json) {
        let mut t = Json::obj();
        t.set("id", key_hex(self.id)).set("parent", self.parent);
        j.set("trace", t);
    }
}

/// One span of a trace tree on the wire (inside a response's or a
/// request-log record's `"trace"` object). Span ids are per-trace and
/// dense enough to stay within f64's exact-integer range; `parent` is
/// `0` only for the root. `counters` carries the kernel work tallies of
/// the span's own lap (`docs/observability.md`), empty for pure
/// queue/transport spans.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSpan {
    pub id: u64,
    pub parent: u64,
    pub name: String,
    pub ns: u64,
    pub counters: Vec<(String, u64)>,
}

impl TraceSpan {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id).set("parent", self.parent);
        j.set("name", self.name.as_str()).set("ns", self.ns);
        if !self.counters.is_empty() {
            let mut c = Json::obj();
            for (k, v) in &self.counters {
                c.set(k, *v);
            }
            j.set("counters", c);
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<TraceSpan, String> {
        let id = j.get("id").and_then(Json::as_u64).ok_or("span: bad \"id\"")?;
        let parent = j.get("parent").and_then(Json::as_u64).ok_or("span: bad \"parent\"")?;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("span: bad \"name\"")?
            .to_string();
        let ns = j.get("ns").and_then(Json::as_u64).ok_or("span: bad \"ns\"")?;
        let mut counters = Vec::new();
        if let Some(Json::Obj(m)) = j.get("counters") {
            for (k, v) in m {
                counters.push((k.clone(), v.as_u64().ok_or("span: non-integer counter")?));
            }
        }
        Ok(TraceSpan { id, parent, name, ns, counters })
    }
}

/// Assemble the `"trace"` object of a response or request-log record:
/// `{"id":"<16 hex>","spans":[...]}`.
pub fn trace_json(id: u64, spans: &[TraceSpan]) -> Json {
    let mut j = Json::obj();
    j.set("id", key_hex(id));
    j.set("spans", Json::Arr(spans.iter().map(TraceSpan::to_json).collect()));
    j
}

/// Parse a `"trace"` object back into its id and spans (the inverse of
/// [`trace_json`] — the front and `cascade trace` both consume this).
pub fn trace_from_json(t: &Json) -> Result<(u64, Vec<TraceSpan>), String> {
    let hex = t.get("id").and_then(Json::as_str).ok_or("trace: bad \"id\"")?;
    let id = u64::from_str_radix(hex, 16).map_err(|_| format!("trace: non-hex id '{hex}'"))?;
    let Some(Json::Arr(arr)) = t.get("spans") else {
        return Err("trace: missing \"spans\" array".into());
    };
    let spans = arr.iter().map(TraceSpan::from_json).collect::<Result<Vec<_>, _>>()?;
    Ok((id, spans))
}

/// Every request member (and `cascade encode`/`client` flag) that names
/// part of a point — what `encode` by `key` must *not* also receive.
pub const POINT_MEMBERS: [&str; 11] = [
    "app", "level", "seed", "alpha", "iters", "tracks", "regwords", "fifo", "fuse", "fast", "tiny",
];

/// One exploration point, as named by a client: the same axis vocabulary
/// as `cascade explore` / `cascade encode`, single-valued. Unset members
/// take the CLI defaults (`level=full`, `seed=3`, axis defaults from the
/// level and base architecture).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PointQuery {
    pub app: String,
    pub level: Option<String>,
    pub seed: Option<u64>,
    pub alpha: Option<f64>,
    pub iters: Option<usize>,
    pub tracks: Option<usize>,
    pub regwords: Option<usize>,
    pub fifo: Option<usize>,
    pub fuse: Option<bool>,
    pub fast: bool,
    pub tiny: bool,
}

impl PointQuery {
    /// Parse the point flags from CLI arguments — **the** single parser
    /// behind `cascade encode`, `cascade client compile|encode` and the
    /// daemon's request schema, so the three can never drift apart on an
    /// axis or a default (drift would silently change effective keys and
    /// break the daemon/CLI byte-identity contract).
    pub fn from_args(args: &crate::util::cli::Args) -> Result<PointQuery, String> {
        let app = args.opt("app").ok_or("--app <name> required")?;
        let opt_usize = |name: &str| -> Result<Option<usize>, String> {
            match args.opt(name) {
                None => Ok(None),
                Some(s) => s.parse().map(Some).map_err(|_| format!("bad --{name} '{s}'")),
            }
        };
        let seed = match args.opt("seed") {
            None => None,
            Some(s) => Some(s.parse().map_err(|_| format!("bad --seed '{s}'"))?),
        };
        let alpha = match args.opt("alpha") {
            None => None,
            Some(s) => Some(s.parse().map_err(|_| format!("bad --alpha '{s}'"))?),
        };
        let fuse = match args.opt("fuse") {
            None => None,
            Some("on") => Some(true),
            Some("off") => Some(false),
            Some(s) => return Err(format!("bad --fuse '{s}' (use on|off)")),
        };
        Ok(PointQuery {
            app: app.to_string(),
            level: args.opt("level").map(str::to_string),
            seed,
            alpha,
            iters: opt_usize("iters")?,
            tracks: opt_usize("tracks")?,
            regwords: opt_usize("regwords")?,
            fifo: opt_usize("fifo")?,
            fuse,
            fast: args.flag("fast"),
            tiny: args.flag("tiny"),
        })
    }

    /// Resolve to the single-point [`ExploreSpec`] + [`ExplorePoint`] the
    /// evaluation layer consumes — identical to how `cascade encode`
    /// resolves its flags, so a daemon-served point hits the same cache
    /// key as the offline CLI.
    pub fn resolve(&self) -> Result<(ExploreSpec, ExplorePoint), String> {
        let mut spec = ExploreSpec::default()
            .with_apps([self.app.as_str()])
            .with_levels([self.level.as_deref().unwrap_or("full")])
            .with_seeds([self.seed.unwrap_or(3)]);
        if let Some(a) = self.alpha {
            spec = spec.with_alphas([a]);
        }
        if let Some(v) = self.iters {
            spec = spec.with_iters([v]);
        }
        if let Some(v) = self.tracks {
            spec = spec.with_tracks([v]);
        }
        if let Some(v) = self.regwords {
            spec = spec.with_regwords([v]);
        }
        if let Some(v) = self.fifo {
            spec = spec.with_fifos([v]);
        }
        if let Some(f) = self.fuse {
            spec = spec.with_fuses([f]);
        }
        spec = spec.with_fast(self.fast);
        if self.tiny {
            spec = spec.with_scale(Scale::Tiny);
        }
        spec.validate()?;
        let point = spec.points().into_iter().next().ok_or("empty point spec")?;
        Ok((spec, point))
    }

    /// Read the point members out of a request object. Absent members are
    /// defaults; present members must have the right type.
    fn from_json(j: &Json) -> Result<PointQuery, String> {
        let app = j
            .get("app")
            .and_then(Json::as_str)
            .ok_or("missing or non-string \"app\"")?
            .to_string();
        let opt_usize = |name: &str| -> Result<Option<usize>, String> {
            match j.get(name) {
                None => Ok(None),
                Some(v) => {
                    v.as_usize().map(Some).ok_or_else(|| format!("non-integer \"{name}\""))
                }
            }
        };
        let level = match j.get("level") {
            None => None,
            Some(v) => Some(v.as_str().ok_or("non-string \"level\"")?.to_string()),
        };
        let seed = match j.get("seed") {
            None => None,
            Some(v) => Some(seed_u64(v)?),
        };
        let alpha = match j.get("alpha") {
            None => None,
            Some(v) => Some(v.as_f64().ok_or("non-number \"alpha\"")?),
        };
        let flag = |name: &str| -> Result<bool, String> {
            match j.get(name) {
                None => Ok(false),
                Some(v) => v.as_bool().ok_or_else(|| format!("non-boolean \"{name}\"")),
            }
        };
        let fuse = match j.get("fuse") {
            None => None,
            Some(v) => Some(v.as_bool().ok_or("non-boolean \"fuse\"")?),
        };
        Ok(PointQuery {
            app,
            level,
            seed,
            alpha,
            iters: opt_usize("iters")?,
            tracks: opt_usize("tracks")?,
            regwords: opt_usize("regwords")?,
            fifo: opt_usize("fifo")?,
            fuse,
            fast: flag("fast")?,
            tiny: flag("tiny")?,
        })
    }

    /// Write the point members into `j` (only the set ones — the wire
    /// form round-trips through [`PointQuery::from_json`] exactly).
    fn write_json(&self, j: &mut Json) {
        j.set("app", self.app.as_str());
        if let Some(l) = &self.level {
            j.set("level", l.as_str());
        }
        if let Some(s) = self.seed {
            // Seeds are full u64s; beyond f64's exact-integer range they
            // travel as decimal strings (the same policy as the artifact
            // serializer), so the daemon accepts every seed the offline
            // CLI accepts.
            if s < crate::util::json::EXACT_INT_BOUND as u64 {
                j.set("seed", s);
            } else {
                j.set("seed", s.to_string());
            }
        }
        if let Some(a) = self.alpha {
            j.set("alpha", a);
        }
        if let Some(v) = self.iters {
            j.set("iters", v);
        }
        if let Some(v) = self.tracks {
            j.set("tracks", v);
        }
        if let Some(v) = self.regwords {
            j.set("regwords", v);
        }
        if let Some(v) = self.fifo {
            j.set("fifo", v);
        }
        if let Some(f) = self.fuse {
            j.set("fuse", f);
        }
        if self.fast {
            j.set("fast", true);
        }
        if self.tiny {
            j.set("tiny", true);
        }
    }
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Liveness probe; the response carries no payload.
    Ping,
    /// Cache + server statistics (shares [`crate::explore::DiskCache::stat_json`]
    /// with `cascade cache stat --json`).
    Stat,
    /// The metrics exposition: deterministic Prometheus-style text in the
    /// response's `"exposition"` member (`docs/observability.md`).
    Metrics,
    /// Compile (or serve from cache) one point; responds with the
    /// effective key, provenance, timing and measured metrics.
    Compile(PointQuery),
    /// Emit the bitstream of one point (by point query, through the same
    /// dedup path as `compile`) or of a stored artifact (`key`, hex —
    /// pure store load, never compiles).
    Encode { key: Option<u64>, query: Option<PointQuery> },
    /// Drain in-flight work and stop the daemon.
    Shutdown,
}

impl Request {
    /// Parse one request line. Errors come pre-categorized so the server
    /// can answer with a structured error response.
    pub fn parse_line(line: &str) -> Result<Request, (ErrorCode, String)> {
        let j = Json::parse(line.trim()).map_err(|e| (ErrorCode::BadRequest, e))?;
        Request::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Request, (ErrorCode, String)> {
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| (ErrorCode::BadRequest, "missing or non-string \"op\"".to_string()))?;
        match op {
            "ping" => Ok(Request::Ping),
            "stat" => Ok(Request::Stat),
            "metrics" => Ok(Request::Metrics),
            "shutdown" => Ok(Request::Shutdown),
            "compile" => {
                let q = PointQuery::from_json(j).map_err(|e| (ErrorCode::BadRequest, e))?;
                Ok(Request::Compile(q))
            }
            "encode" => {
                if let Some(k) = j.get("key") {
                    if let Some(m) = POINT_MEMBERS.iter().find(|m| j.get(m).is_some()) {
                        // Silently ignoring the point member would serve
                        // a different point than the client named.
                        return Err((
                            ErrorCode::BadRequest,
                            format!(
                                "encode: \"key\" and point members are mutually \
                                 exclusive (got \"{m}\")"
                            ),
                        ));
                    }
                    let hex = k.as_str().ok_or_else(|| {
                        (ErrorCode::BadRequest, "non-string \"key\"".to_string())
                    })?;
                    let key = u64::from_str_radix(hex, 16).map_err(|_| {
                        (ErrorCode::BadRequest, format!("bad \"key\" '{hex}' (hex)"))
                    })?;
                    Ok(Request::Encode { key: Some(key), query: None })
                } else {
                    let q = PointQuery::from_json(j).map_err(|e| (ErrorCode::BadRequest, e))?;
                    Ok(Request::Encode { key: None, query: Some(q) })
                }
            }
            other => Err((
                ErrorCode::UnknownOp,
                format!("unknown op '{other}' (ping|stat|metrics|compile|encode|shutdown)"),
            )),
        }
    }

    /// The op tag this request serializes under.
    pub fn op(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Stat => "stat",
            Request::Metrics => "metrics",
            Request::Compile(_) => "compile",
            Request::Encode { .. } => "encode",
            Request::Shutdown => "shutdown",
        }
    }

    /// Serialize to the wire object (the client side of the round-trip).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("op", self.op());
        match self {
            Request::Ping | Request::Stat | Request::Metrics | Request::Shutdown => {}
            Request::Compile(q) => q.write_json(&mut j),
            Request::Encode { key, query } => {
                if let Some(k) = key {
                    j.set("key", key_hex(*k));
                }
                if let Some(q) = query {
                    q.write_json(&mut j);
                }
            }
        }
        j
    }
}

/// Keys travel as 16-digit hex strings (u64 exceeds JSON's exact-integer
/// number range) — the same rendering the shard manifests use.
pub fn key_hex(key: u64) -> String {
    format!("{key:016x}")
}

/// A wire seed: a JSON number within f64's exact-integer range, or a
/// decimal string for the full u64 range.
fn seed_u64(v: &Json) -> Result<u64, String> {
    if let Some(n) = v.as_u64() {
        return Ok(n);
    }
    if let Some(s) = v.as_str() {
        if let Ok(n) = s.parse::<u64>() {
            return Ok(n);
        }
    }
    Err("non-integer \"seed\" (number or decimal string)".into())
}

/// A success response skeleton: `{"ok":true,"op":...}`.
pub fn response_ok(op: &str) -> Json {
    let mut j = Json::obj();
    j.set("ok", true).set("op", op);
    j
}

/// An error response: `{"ok":false,"code":...,"error":...}`.
pub fn response_error(code: ErrorCode, msg: &str) -> Json {
    let mut j = Json::obj();
    j.set("ok", false).set("code", code.tag()).set("error", msg);
    j
}

/// The measured-metrics payload of a `compile` response — the same field
/// names the explore reports and partial log use.
pub fn metrics_json(m: &PointMetrics) -> Json {
    let mut j = Json::obj();
    j.set("crit_ns", m.crit_ns)
        .set("fmax_mhz", m.fmax_mhz)
        .set("runtime_ms", m.runtime_ms)
        .set("power_mw", m.power_mw)
        .set("energy_mj", m.energy_mj)
        .set("edp", m.edp)
        .set("pipe_regs", m.pipe_regs)
        .set("util_pct", m.util_pct);
    if m.cycles > 0 {
        j.set("cycles", m.cycles);
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips_every_op() {
        let q = PointQuery {
            app: "gaussian".into(),
            level: Some("compute".into()),
            seed: Some(7),
            alpha: Some(1.35),
            iters: Some(50),
            tracks: Some(3),
            regwords: Some(32),
            fifo: Some(4),
            fuse: Some(true),
            fast: true,
            tiny: true,
        };
        let reqs = [
            Request::Ping,
            Request::Stat,
            Request::Metrics,
            Request::Shutdown,
            Request::Compile(q.clone()),
            Request::Encode { key: None, query: Some(q) },
            Request::Encode { key: Some(0xDEADBEEF12345678), query: None },
        ];
        for r in reqs {
            let line = r.to_json().to_string_compact();
            assert_eq!(Request::parse_line(&line), Ok(r), "round-trip failed for {line}");
        }
    }

    #[test]
    fn sparse_point_query_serializes_only_set_members() {
        let q = PointQuery { app: "harris".into(), ..PointQuery::default() };
        let line = Request::Compile(q).to_json().to_string_compact();
        assert_eq!(line, "{\"app\":\"harris\",\"op\":\"compile\"}");
    }

    #[test]
    fn seeds_beyond_f64_exact_range_round_trip_as_strings() {
        for seed in [0u64, 3, (1 << 53) - 1, 1 << 53, u64::MAX] {
            let q = PointQuery { app: "gaussian".into(), seed: Some(seed), ..Default::default() };
            let line = Request::Compile(q.clone()).to_json().to_string_compact();
            match Request::parse_line(&line) {
                Ok(Request::Compile(back)) => assert_eq!(back.seed, Some(seed), "{line}"),
                other => panic!("seed {seed} failed to round-trip: {other:?} ({line})"),
            }
        }
        assert_eq!(seed_u64(&Json::Str("18446744073709551615".into())), Ok(u64::MAX));
        assert!(seed_u64(&Json::Str("not a number".into())).is_err());
        assert!(seed_u64(&Json::Bool(true)).is_err());
    }

    #[test]
    fn encode_rejects_key_and_point_members_together() {
        for line in [
            "{\"op\":\"encode\",\"key\":\"00000000000000ff\",\"app\":\"gaussian\"}",
            "{\"op\":\"encode\",\"key\":\"00000000000000ff\",\"seed\":7}",
            "{\"op\":\"encode\",\"key\":\"00000000000000ff\",\"tiny\":true}",
        ] {
            match Request::parse_line(line) {
                Err((ErrorCode::BadRequest, msg)) => {
                    assert!(msg.contains("mutually exclusive"), "{msg}")
                }
                other => panic!("expected bad_request for {line}, got {other:?}"),
            }
        }
    }

    #[test]
    fn malformed_and_illtyped_requests_are_bad_request() {
        for line in [
            "not json at all",
            "{\"op\":",
            "{}",
            "{\"op\":42}",
            "{\"op\":\"compile\"}",
            "{\"op\":\"compile\",\"app\":7}",
            "{\"op\":\"compile\",\"app\":\"gaussian\",\"seed\":\"x\"}",
            "{\"op\":\"compile\",\"app\":\"gaussian\",\"fast\":\"yes\"}",
            "{\"op\":\"encode\",\"key\":\"zz\"}",
            "{\"op\":\"encode\",\"key\":123}",
        ] {
            match Request::parse_line(line) {
                Err((ErrorCode::BadRequest, _)) => {}
                other => panic!("expected bad_request for {line}, got {other:?}"),
            }
        }
    }

    #[test]
    fn unknown_op_is_its_own_code() {
        match Request::parse_line("{\"op\":\"frobnicate\"}") {
            Err((ErrorCode::UnknownOp, msg)) => assert!(msg.contains("frobnicate")),
            other => panic!("expected unknown_op, got {other:?}"),
        }
    }

    #[test]
    fn from_args_parses_the_full_encode_vocabulary() {
        let parse = |s: &str| {
            crate::util::cli::Args::parse(s.split_whitespace().map(|x| x.to_string()))
        };
        let args = parse(
            "encode --app gaussian --level compute --seed 7 --alpha 1.35 \
             --iters 50 --tracks 3 --regwords 32 --fifo 4 --fuse on --fast --tiny",
        );
        let q = PointQuery::from_args(&args).unwrap();
        assert_eq!(q.app, "gaussian");
        assert_eq!(q.level.as_deref(), Some("compute"));
        assert_eq!(q.seed, Some(7));
        assert_eq!(q.alpha, Some(1.35));
        assert_eq!(q.iters, Some(50));
        assert_eq!(q.tracks, Some(3));
        assert_eq!(q.regwords, Some(32));
        assert_eq!(q.fifo, Some(4));
        assert_eq!(q.fuse, Some(true));
        assert!(q.fast && q.tiny);
        assert_eq!(
            PointQuery::from_args(&parse("encode --app g --fuse off")).unwrap().fuse,
            Some(false)
        );

        assert!(PointQuery::from_args(&parse("encode")).is_err(), "--app is required");
        assert!(PointQuery::from_args(&parse("encode --app g --seed x")).is_err());
        assert!(PointQuery::from_args(&parse("encode --app g --iters x")).is_err());
        assert!(PointQuery::from_args(&parse("encode --app g --fuse maybe")).is_err());
    }

    #[test]
    fn resolve_matches_cli_defaults_and_validates() {
        let q = PointQuery { app: "gaussian".into(), ..PointQuery::default() };
        let (spec, point) = q.resolve().unwrap();
        assert_eq!(spec.levels, vec!["full".to_string()]);
        assert_eq!(spec.seeds, vec![3]);
        assert_eq!(point.id, 0);
        assert_eq!(point.app, "gaussian");

        let bad = PointQuery { app: "no-such-app".into(), ..PointQuery::default() };
        assert!(bad.resolve().is_err());
        let bad_level = PointQuery {
            app: "gaussian".into(),
            level: Some("mystery".into()),
            ..PointQuery::default()
        };
        assert!(bad_level.resolve().is_err());
    }

    #[test]
    fn error_response_shape() {
        let j = response_error(ErrorCode::Busy, "request queue full");
        let s = j.to_string_compact();
        assert_eq!(s, "{\"code\":\"busy\",\"error\":\"request queue full\",\"ok\":false}");
    }

    #[test]
    fn error_code_tags_are_distinct_snake_case() {
        let all = [
            ErrorCode::BadRequest,
            ErrorCode::UnknownOp,
            ErrorCode::Oversized,
            ErrorCode::Busy,
            ErrorCode::NotFound,
            ErrorCode::CompileFailed,
            ErrorCode::ShuttingDown,
            ErrorCode::Unauthorized,
            ErrorCode::BackendDown,
            ErrorCode::ProtoMismatch,
        ];
        let tags: Vec<&str> = all.iter().map(|c| c.tag()).collect();
        let unique: std::collections::BTreeSet<&&str> = tags.iter().collect();
        assert_eq!(unique.len(), tags.len(), "duplicate error-code tag: {tags:?}");
        for t in &tags {
            assert!(
                t.chars().all(|c| c.is_ascii_lowercase() || c == '_'),
                "tag '{t}' is not snake_case"
            );
        }
    }

    #[test]
    fn trace_ctx_round_trips_and_rejects_garbage() {
        let ctx = TraceCtx { id: 0xDEADBEEF12345678, parent: 3 };
        let mut j = Json::obj();
        j.set("op", "compile");
        ctx.write_json(&mut j);
        let line = j.to_string_compact();
        assert!(line.contains("\"trace\":{\"id\":\"deadbeef12345678\",\"parent\":3}"), "{line}");
        let back = Json::parse(&line).unwrap();
        assert_eq!(TraceCtx::from_json(&back), Ok(Some(ctx)));
        // Absent trace is None, not an error.
        assert_eq!(TraceCtx::from_json(&Json::parse("{\"op\":\"ping\"}").unwrap()), Ok(None));
        // Ill-formed trace members are bad_request, not silent drops.
        for bad in [
            "{\"trace\":{\"parent\":3}}",
            "{\"trace\":{\"id\":\"zz\",\"parent\":3}}",
            "{\"trace\":{\"id\":\"00ff\"}}",
            "{\"trace\":{\"id\":\"00ff\",\"parent\":\"x\"}}",
        ] {
            let j = Json::parse(bad).unwrap();
            match TraceCtx::from_json(&j) {
                Err((ErrorCode::BadRequest, msg)) => assert!(msg.contains("trace"), "{msg}"),
                other => panic!("expected bad_request for {bad}, got {other:?}"),
            }
        }
    }

    #[test]
    fn trace_spans_round_trip_with_and_without_counters() {
        let spans = vec![
            TraceSpan { id: 1, parent: 0, name: "request".into(), ns: 5000, counters: vec![] },
            TraceSpan {
                id: 2,
                parent: 1,
                name: "stage:place".into(),
                ns: 4000,
                counters: vec![
                    ("place_moves_accepted".into(), 7),
                    ("place_moves_proposed".into(), 10),
                ],
            },
        ];
        let j = trace_json(0xff, &spans);
        let s = j.to_string_compact();
        assert!(s.starts_with("{\"id\":\"00000000000000ff\""), "{s}");
        let (id, back) = trace_from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(id, 0xff);
        assert_eq!(back, spans);
        // Counter maps serialize in key order (BTreeMap), so the parsed
        // vec comes back sorted regardless of insertion order.
        assert!(s.contains("\"counters\":{\"place_moves_accepted\":7,\"place_moves_proposed\":10}"));
        assert!(trace_from_json(&Json::parse("{\"id\":\"ff\"}").unwrap()).is_err());
        assert!(trace_from_json(&Json::parse("{\"spans\":[]}").unwrap()).is_err());
    }

    #[test]
    fn front_accepts_v2_and_v3_backends_only() {
        assert!(COMPAT_PROTO_VERSIONS.contains(&2));
        assert!(COMPAT_PROTO_VERSIONS.contains(&PROTO_VERSION));
        assert!(!COMPAT_PROTO_VERSIONS.contains(&1));
        assert!(!COMPAT_PROTO_VERSIONS.contains(&4));
    }

    #[test]
    fn constant_time_compare_agrees_with_equality() {
        assert!(ct_eq("secret", "secret"));
        assert!(ct_eq("", ""));
        assert!(!ct_eq("secret", "secreT"));
        assert!(!ct_eq("secret", "secret2"));
        assert!(!ct_eq("secret", "sec"));
        assert!(!ct_eq("", "x"));
        assert!(!ct_eq("x", ""));
    }

    #[test]
    fn check_auth_policy() {
        let with = Json::parse("{\"op\":\"ping\",\"auth\":\"t0k3n\"}").unwrap();
        let wrong = Json::parse("{\"op\":\"ping\",\"auth\":\"wrong\"}").unwrap();
        let without = Json::parse("{\"op\":\"ping\"}").unwrap();
        // No configured token: everything passes, presented auth ignored.
        assert!(check_auth(&with, None).is_ok());
        assert!(check_auth(&without, None).is_ok());
        // Configured token: exact match required, structured code on miss.
        assert!(check_auth(&with, Some("t0k3n")).is_ok());
        let (code, _) = check_auth(&wrong, Some("t0k3n")).unwrap_err();
        assert_eq!(code, ErrorCode::Unauthorized);
        let (code, msg) = check_auth(&without, Some("t0k3n")).unwrap_err();
        assert_eq!(code, ErrorCode::Unauthorized);
        assert!(msg.contains("--auth-token"));
        // A non-string auth member is unauthorized, not a crash.
        let bad_type = Json::parse("{\"op\":\"ping\",\"auth\":7}").unwrap();
        assert_eq!(check_auth(&bad_type, Some("t")).unwrap_err().0, ErrorCode::Unauthorized);
    }
}
