//! The hash-routing front engine behind `cascade serve --route`.
//!
//! A front daemon owns no compiler and no cache. Every `compile`/`encode`
//! request is resolved *only* as far as its effective cache key, then
//! forwarded whole to the backend that owns that key under the same
//! N-way partition `cascade explore --shard K/N` uses
//! ([`crate::explore::shard::owner_of`]). That partition is the whole
//! coordination story: each backend's cache holds a disjoint key range,
//! identical concurrent requests always land on the same backend (where
//! the session core dedups them to one compile), and adding a front in
//! front of N backends needs no shared state, locks, or gossip — the
//! key arithmetic *is* the routing table.
//!
//! Aggregation ops fan out instead: `stat` collects every backend's
//! statistics plus cross-backend totals, `metrics` collects every
//! backend's exposition next to the front's own, and `ping` probes all
//! backends (the front is only as alive as its topology).
//!
//! Failure policy: each forward gets one built-in retry on a fresh
//! connection (a parked keep-alive connection may have died idle); a
//! backend that still cannot be reached yields a structured
//! [`ErrorCode::BackendDown`] naming the address. A *reachable* backend
//! that answers the handshake with the wrong [`PROTO_VERSION`] is
//! refused — at startup as a hard error, per-request as
//! [`ErrorCode::ProtoMismatch`] — because mixed-version topologies would
//! silently disagree on request semantics.
//!
//! The front authenticates to backends with its own `--auth-token` (the
//! usual deployment shares one secret across the topology); a client's
//! presented token never travels past the front.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::arch::params::ArchParams;
use crate::explore::runner::effective_key;
use crate::explore::shard::owner_of;
use crate::obs::labeled;
use crate::util::json::Json;

use super::client::{Client, ClientOpts};
use super::proto::{
    response_error, response_ok, trace_from_json, trace_json, ErrorCode, PointQuery, Request,
    TraceCtx, COMPAT_PROTO_VERSIONS, PROTO_VERSION,
};
use super::ServeState;

/// One backend daemon: its address, a parked keep-alive connection, and
/// a forward count for the drain summary.
struct Backend {
    addr: String,
    /// At most one connection parks here between requests; concurrent
    /// workers dial extras and the surplus simply closes after use
    /// (first healthy connection back wins the slot).
    slot: Mutex<Option<Client>>,
    forwarded: AtomicUsize,
}

/// Why a forward could not produce a backend response.
enum RouteError {
    /// Transport-level failure, after the built-in retry.
    Down(String),
    /// The backend answered the handshake with the wrong protocol
    /// version (or refused it outright) — configuration, not weather.
    Mismatch(String),
}

/// The front's routing state: the backend table and the key arithmetic.
pub(crate) struct FrontEngine {
    backends: Vec<Backend>,
    auth: Option<String>,
    timeout: Duration,
    /// Base architecture for effective-key computation — the same
    /// [`ArchParams::paper`] the backends compile under, so front and
    /// backend always agree on what a point's key is.
    arch: ArchParams,
}

impl FrontEngine {
    /// Build the table and handshake every backend once. A reachable
    /// backend speaking the wrong protocol (or refusing the handshake,
    /// e.g. `unauthorized`) fails construction — that is a broken
    /// deployment, not a transient. An *unreachable* backend only warns:
    /// it may come up later, and requests it owns answer `backend_down`
    /// until it does.
    pub(crate) fn new(
        addrs: &[String],
        auth: Option<String>,
        timeout: Duration,
    ) -> Result<FrontEngine, String> {
        if addrs.is_empty() {
            return Err("route: need at least one backend address".to_string());
        }
        let eng = FrontEngine {
            backends: addrs
                .iter()
                .map(|a| Backend {
                    addr: a.clone(),
                    slot: Mutex::new(None),
                    forwarded: AtomicUsize::new(0),
                })
                .collect(),
            auth,
            timeout,
            arch: ArchParams::paper(),
        };
        for b in &eng.backends {
            match eng.dial(b) {
                Ok(c) => *b.slot.lock().unwrap() = Some(c),
                Err(RouteError::Mismatch(e)) => {
                    return Err(format!("route: backend {}: {e}", b.addr));
                }
                Err(RouteError::Down(e)) => {
                    eprintln!("serve: warning: backend {} unreachable at startup: {e}", b.addr);
                }
            }
        }
        Ok(eng)
    }

    /// Dial one backend and verify the protocol handshake.
    fn dial(&self, b: &Backend) -> Result<Client, RouteError> {
        let opts = ClientOpts { timeout: self.timeout, retries: 0, auth: self.auth.clone() };
        let mut c = Client::connect(b.addr.as_str(), opts).map_err(RouteError::Down)?;
        let pong = c.ping().map_err(RouteError::Down)?;
        if pong.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(RouteError::Mismatch(format!(
                "handshake refused: {}",
                pong.to_string_compact()
            )));
        }
        let got = pong.get("proto").and_then(Json::as_u64);
        if !got.is_some_and(|v| COMPAT_PROTO_VERSIONS.contains(&v)) {
            return Err(RouteError::Mismatch(format!(
                "backend speaks protocol {} where this front requires {COMPAT_PROTO_VERSIONS:?} \
                 (every v3 addition is optional on the wire, so v2 backends interoperate; \
                 anything else is refused)",
                got.map_or_else(|| "1 (none reported)".to_string(), |v| v.to_string())
            )));
        }
        Ok(c)
    }

    /// One checkout–use–park cycle against backend `b`, with one built-in
    /// retry on a *fresh* connection (a parked keep-alive connection may
    /// have died while idle — that is weather, not an error the client
    /// should see).
    fn try_forward(
        &self,
        b: &Backend,
        req: &Request,
        ctx: Option<TraceCtx>,
    ) -> Result<Json, RouteError> {
        let mut conn = b.slot.lock().unwrap().take();
        let mut last = String::new();
        for _attempt in 0..2 {
            let mut c = match conn.take() {
                Some(c) => c,
                None => match self.dial(b) {
                    Ok(c) => c,
                    Err(RouteError::Down(e)) => {
                        last = e;
                        continue;
                    }
                    Err(m) => return Err(m),
                },
            };
            match c.request_traced(req, ctx) {
                Ok(resp) => {
                    let mut slot = b.slot.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(c);
                    }
                    return Ok(resp);
                }
                // Drop the dead connection; the next loop iteration
                // dials (and handshakes) fresh.
                Err(e) => last = e,
            }
        }
        Err(RouteError::Down(last))
    }

    /// Forward `req` to backend `idx` and account for it: the forward
    /// counter feeds the drain summary, the provenance counter keeps the
    /// front's `serve_provenance_total` meaningful even though the cache
    /// lives backend-side.
    ///
    /// Every routed `compile`/`encode` travels with a trace context: the
    /// caller's (so one trace spans client → front → backend) or a fresh
    /// front-minted id. The backend hangs its span tree under this
    /// request's forward span (numbered `base + 3` by
    /// [`ServeState::finish_request`]); its echoed root span is renamed
    /// `backend:<addr>` here so the grafted tree names the hop.
    fn forward(&self, st: &ServeState<'_>, idx: usize, req: &Request, ctx: Option<TraceCtx>) -> Json {
        let b = &self.backends[idx];
        let child = TraceCtx {
            id: ctx.map(|c| c.id).unwrap_or_else(crate::obs::trace::gen_trace_id),
            parent: ctx.map(|c| c.parent).unwrap_or(0) + 3,
        };
        match self.try_forward(b, req, Some(child)) {
            Ok(mut resp) => {
                name_backend_hop(&mut resp, child.parent + 1, &b.addr);
                b.forwarded.fetch_add(1, Ordering::SeqCst);
                st.reg
                    .counter(
                        &labeled("route_forward_total", "backend", &b.addr),
                        "requests forwarded, by owning backend",
                    )
                    .inc();
                if let Some(p) = resp.get("provenance").and_then(Json::as_str) {
                    st.reg
                        .counter(
                            &labeled("serve_provenance_total", "provenance", p),
                            "compile/encode responses by cache provenance",
                        )
                        .inc();
                }
                resp
            }
            Err(RouteError::Mismatch(e)) => response_error(ErrorCode::ProtoMismatch, &e),
            Err(RouteError::Down(e)) => {
                st.reg
                    .counter(
                        &labeled("route_backend_down_total", "backend", &b.addr),
                        "forwards that failed with an unreachable backend",
                    )
                    .inc();
                response_error(
                    ErrorCode::BackendDown,
                    &format!("backend {} unreachable after retry: {e}", b.addr),
                )
            }
        }
    }

    /// Dispatch one request through the routing table. `ctx` is the
    /// caller's trace context, propagated on routed `compile`/`encode`.
    pub(crate) fn handle(&self, st: &ServeState<'_>, req: Request, ctx: Option<TraceCtx>) -> Json {
        match req {
            Request::Ping => self.ping_all(),
            Request::Stat => self.stat_fanout(st),
            Request::Metrics => self.metrics_fanout(st),
            // Handled engine-agnostically upstream — the front drains
            // itself, never its (possibly shared) backends.
            Request::Shutdown => response_ok("shutdown"),
            Request::Compile(ref q) => self.route_query(st, q, &req, ctx),
            Request::Encode { key: Some(key), .. } => self.route_key(st, key, &req, ctx),
            Request::Encode { key: None, query: Some(ref q) } => {
                self.route_query(st, q, &req, ctx)
            }
            Request::Encode { key: None, query: None } => {
                response_error(ErrorCode::BadRequest, "encode: need \"key\" or \"app\"")
            }
        }
    }

    /// Route a point-addressed request: resolve the point exactly as a
    /// backend would, compute its effective key, forward to the owner.
    /// A point that fails validation is refused here — no backend ever
    /// sees it.
    fn route_query(
        &self,
        st: &ServeState<'_>,
        q: &PointQuery,
        req: &Request,
        ctx: Option<TraceCtx>,
    ) -> Json {
        let (spec, point) = match q.resolve() {
            Ok(sp) => sp,
            Err(e) => return response_error(ErrorCode::BadRequest, &e),
        };
        let key = effective_key(&spec, &self.arch, &point);
        self.forward(st, owner_of(key, self.backends.len()) - 1, req, ctx)
    }

    /// Route a key-addressed request (`encode` by key): the key *is* the
    /// routing input.
    fn route_key(
        &self,
        st: &ServeState<'_>,
        key: u64,
        req: &Request,
        ctx: Option<TraceCtx>,
    ) -> Json {
        self.forward(st, owner_of(key, self.backends.len()) - 1, req, ctx)
    }

    /// `ping`: probe every backend; the front is alive only if the whole
    /// topology is. The first failing backend's structured error is the
    /// response (its message names the address).
    fn ping_all(&self) -> Json {
        let mut addrs = Vec::new();
        for b in &self.backends {
            match self.try_forward(b, &Request::Ping, None) {
                Ok(resp) if resp.get("ok").and_then(Json::as_bool) == Some(true) => {
                    addrs.push(Json::from(b.addr.as_str()));
                }
                Ok(resp) => return resp,
                Err(RouteError::Mismatch(e)) => {
                    return response_error(ErrorCode::ProtoMismatch, &e);
                }
                Err(RouteError::Down(e)) => {
                    return response_error(
                        ErrorCode::BackendDown,
                        &format!("backend {} unreachable after retry: {e}", b.addr),
                    );
                }
            }
        }
        let mut j = response_ok("ping");
        j.set("proto", PROTO_VERSION).set("role", "front").set("backends", Json::Arr(addrs));
        j
    }

    /// `stat`: the front's own counters plus every backend's full stat
    /// response and cross-backend cache totals. Unreachable backends are
    /// reported per-entry (`ok:false`), never hidden — a monitoring
    /// scrape must see the hole, not a smaller topology.
    fn stat_fanout(&self, st: &ServeState<'_>) -> Json {
        const SUMMED: [&str; 4] = ["fresh_compiles", "memory_hits", "disk_hits", "art_hits"];
        let mut backends = Vec::new();
        let mut sums = [0u64; 4];
        let mut reachable = 0usize;
        for b in &self.backends {
            let mut entry = Json::obj();
            entry
                .set("addr", b.addr.as_str())
                .set("forwarded", b.forwarded.load(Ordering::SeqCst));
            match self.try_forward(b, &Request::Stat, None) {
                Ok(resp) if resp.get("ok").and_then(Json::as_bool) == Some(true) => {
                    if let Some(srv) = resp.get("server") {
                        for (i, name) in SUMMED.into_iter().enumerate() {
                            sums[i] += srv.get(name).and_then(Json::as_u64).unwrap_or(0);
                        }
                    }
                    reachable += 1;
                    entry.set("ok", true).set("stat", resp);
                }
                Ok(resp) => {
                    entry.set("ok", false).set("error", resp.to_string_compact());
                }
                Err(RouteError::Mismatch(e) | RouteError::Down(e)) => {
                    entry.set("ok", false).set("error", e);
                }
            }
            backends.push(entry);
        }
        let mut srv = Json::obj();
        srv.set("requests", st.requests.load(Ordering::SeqCst))
            .set("busy_rejections", st.busy.load(Ordering::SeqCst))
            .set("errors", st.errors.load(Ordering::SeqCst))
            .set("workers", st.cfg.workers)
            .set("queue_cap", st.cfg.queue_cap)
            .set("pipeline", st.cfg.pipeline)
            .set("backends", self.backends.len())
            .set("backends_reachable", reachable);
        let mut totals = Json::obj();
        for (i, name) in SUMMED.into_iter().enumerate() {
            totals.set(name, sums[i]);
        }
        let mut j = response_ok("stat");
        j.set("proto", PROTO_VERSION)
            .set("role", "front")
            .set("server", srv)
            .set("totals", totals)
            .set("backends", Json::Arr(backends));
        j
    }

    /// `metrics`: the front's own exposition plus one entry per backend
    /// (`cascade client metrics` prints them under `# backend <addr>`
    /// headers — one scrape shows the whole topology).
    fn metrics_fanout(&self, st: &ServeState<'_>) -> Json {
        let mut backends = Vec::new();
        for b in &self.backends {
            let mut entry = Json::obj();
            entry.set("addr", b.addr.as_str());
            match self.try_forward(b, &Request::Metrics, None) {
                Ok(resp) => match resp.get("exposition").and_then(Json::as_str) {
                    Some(t) => {
                        entry.set("exposition", t);
                    }
                    None => {
                        entry.set("error", resp.to_string_compact());
                    }
                },
                Err(RouteError::Mismatch(e) | RouteError::Down(e)) => {
                    entry.set("error", e);
                }
            }
            backends.push(entry);
        }
        let mut j = response_ok("metrics");
        j.set("exposition", st.reg.expose()).set("backends", Json::Arr(backends));
        j
    }

    /// `addr=count` per backend, for the drain log line.
    pub(crate) fn drain_summary(&self) -> String {
        self.backends
            .iter()
            .map(|b| format!("{}={}", b.addr, b.forwarded.load(Ordering::SeqCst)))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Rename the root of a backend's echoed span tree (its `request` span,
/// numbered `forward + 1`) to `backend:<addr>`, so the grafted tree
/// attributes the hop. A response without a trace (v2 backend, or a
/// trace this front cannot parse) passes through untouched.
fn name_backend_hop(resp: &mut Json, root_id: u64, addr: &str) {
    let Some(t) = resp.remove("trace") else { return };
    match trace_from_json(&t) {
        Ok((id, mut spans)) => {
            for s in &mut spans {
                if s.id == root_id {
                    s.name = format!("backend:{addr}");
                }
            }
            resp.set("trace", trace_json(id, &spans));
        }
        Err(_) => {
            resp.set("trace", t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_backend_list_is_refused() {
        let err = FrontEngine::new(&[], None, Duration::from_millis(100)).unwrap_err();
        assert!(err.contains("at least one backend"), "{err}");
    }

    #[test]
    fn unreachable_backends_warn_but_construct() {
        // Port 1 on loopback is essentially never listening; if some
        // exotic environment answers, the handshake ping times out fast.
        let addrs = vec!["127.0.0.1:1".to_string()];
        let eng = FrontEngine::new(&addrs, None, Duration::from_millis(100))
            .expect("down backends must not fail construction");
        assert_eq!(eng.backends.len(), 1);
        assert_eq!(eng.drain_summary(), "127.0.0.1:1=0");
    }

    #[test]
    fn backend_root_span_is_renamed_to_the_hop() {
        use crate::serve::proto::TraceSpan;
        let spans = vec![
            TraceSpan { id: 4, parent: 3, name: "request".into(), ns: 100, counters: vec![] },
            TraceSpan { id: 5, parent: 4, name: "queue".into(), ns: 10, counters: vec![] },
        ];
        let mut resp = response_ok("compile");
        resp.set("trace", trace_json(0xab, &spans));
        name_backend_hop(&mut resp, 4, "127.0.0.1:7871");
        let (id, back) = trace_from_json(resp.get("trace").unwrap()).unwrap();
        assert_eq!(id, 0xab);
        assert_eq!(back[0].name, "backend:127.0.0.1:7871");
        assert_eq!(back[1].name, "queue");
        // A traceless (v2) response passes through untouched.
        let mut plain = response_ok("compile");
        name_backend_hop(&mut plain, 4, "x");
        assert!(plain.get("trace").is_none());
    }

    #[test]
    fn routing_is_the_shard_partition() {
        // The front must route key K to backend `owner_of(K, N)` — the
        // 1-based shard index, 0-based in the table.
        for n in [1usize, 2, 3, 5] {
            for key in [0u64, 1, 41, 0xdead_beef, u64::MAX] {
                let idx = owner_of(key, n) - 1;
                assert!(idx < n, "owner_of must be 1..=n");
                assert_eq!(idx, (key % n as u64) as usize);
            }
        }
    }
}
