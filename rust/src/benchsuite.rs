//! Library home of the benchmark suites.
//!
//! The `cargo bench` targets (`benches/bench_{pnr,sta,sim,tables}.rs`,
//! `harness = false`) are thin mains over the `run_*` functions here, so
//! the same kernels are reachable without a bench build: `cascade bench`
//! drives them from the CLI and — with `--json` — writes a
//! machine-readable `BENCH_<suite>.json` snapshot (schema below) that CI
//! uploads as an artifact.
//!
//! ```json
//! {
//!   "schema": "cascade-bench-v1",
//!   "suite": "compile",
//!   "results": [
//!     {"name": "compile/gaussian_64x64_compute", "iters": 12,
//!      "median_ns": 1.2e7, "mean_ns": 1.3e7, "p10_ns": 1.1e7, "p90_ns": 1.5e7}
//!   ]
//! }
//! ```
//!
//! Budgets come from `CASCADE_BENCH_WARMUP_MS` / `CASCADE_BENCH_BUDGET_MS`
//! (see [`crate::util::bench::Bencher`]); `--fast` presets them small for
//! smoke runs.

use crate::pipeline::{compile, CompileCtx, PipelineConfig};
use crate::util::bench::Bencher;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Suites runnable by name (CLI `--suite`, default first).
pub const SUITE_NAMES: &[&str] = &["compile", "pnr", "sta", "sim", "tables", "fuse"];

/// CI-sized end-to-end suite: small-frame compiles through every pipeline
/// stage plus STA and bitstream encoding in isolation. This is the suite
/// `cascade bench` runs by default — minutes, not tens of minutes, even
/// at the default budget.
pub fn run_compile(b: &mut Bencher) {
    let ctx = CompileCtx::paper();
    let app = crate::apps::dense::gaussian(64, 64, 2);
    b.bench("compile/gaussian_64x64_compute", || {
        compile(&app, &ctx, &PipelineConfig::compute_only(), 3).unwrap().fmax_mhz()
    });
    b.bench("compile/gaussian_64x64_postpnr", || {
        compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap().fmax_mhz()
    });

    let c = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap();
    b.bench("sta/gaussian_64x64", || {
        crate::timing::sta::analyze(&c.design, &ctx.graph).period_ps
    });
    b.bench("encode/gaussian_64x64", || crate::sim::encode::encode_compiled(&c).len());

    let sp = crate::apps::sparse::vec_elemadd(4096, 0.25);
    b.bench("compile/vec_elemadd_sparse", || {
        compile(&sp, &ctx, &PipelineConfig::compute_only(), 3).unwrap().fmax_mhz()
    });
}

/// Place-and-route: SA placement and PathFinder routing on the
/// paper-scale array (the compile-time hot paths).
pub fn run_pnr(b: &mut Bencher) {
    use crate::pnr::{build_nets, place, route, PlaceParams, RouteParams};
    let ctx = CompileCtx::paper();
    let arch = crate::arch::params::ArchParams::paper();

    let app = crate::apps::dense::gaussian(6400, 4800, 16);
    let nets = build_nets(&app.dfg, &arch);
    b.bench("place/gaussian_u16", || {
        place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3)).cost
    });
    b.bench("place/gaussian_u16_alpha", || {
        place(&app.dfg, &nets, &arch, &PlaceParams::cascade(3)).cost
    });
    // Full-recompute reference (`--no-incremental` mode): same moves, same
    // cost bits; the delta vs `place/gaussian_u16` is the incremental win.
    let pp_scratch = PlaceParams { incremental: false, ..PlaceParams::baseline(3) };
    b.bench("place/gaussian_u16_scratch", || {
        place(&app.dfg, &nets, &arch, &pp_scratch).cost
    });

    let placement = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3));
    b.bench("route/gaussian_u16", || {
        route(&app.dfg, &nets, &placement, &arch, &ctx.graph, &RouteParams::default())
            .unwrap()
            .len()
    });
    let rp_scratch = RouteParams { incremental: false, ..RouteParams::default() };
    b.bench("route/gaussian_u16_scratch", || {
        route(&app.dfg, &nets, &placement, &arch, &ctx.graph, &rp_scratch).unwrap().len()
    });

    let harris = crate::apps::dense::harris(1530, 2554, 4);
    let hnets = build_nets(&harris.dfg, &arch);
    b.bench("place/harris_u4", || {
        place(&harris.dfg, &hnets, &arch, &PlaceParams::baseline(5)).cost
    });
}

/// STA hot paths: the analysis runs once per post-PnR pipelining
/// iteration, so its latency bounds compile time.
pub fn run_sta(b: &mut Bencher) {
    use crate::arch::canal::NodeKind;
    use crate::timing::sta::{analyze, StaEngine};
    let ctx = CompileCtx::paper();

    let gauss = compile(
        &crate::apps::dense::gaussian(6400, 4800, 16),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/gaussian_u16", || analyze(&gauss.design, &ctx.graph).period_ps);

    // Incremental engine (post-PnR loop hot path). `noop` bounds the fixed
    // per-call diff cost on an unchanged design; `perturb` toggles one
    // pipelining register per call and re-propagates only downstream of it.
    // Compare both against `analyze/gaussian_u16` for the memoization win.
    let mut d = gauss.design;
    let mut engine = StaEngine::new(&d);
    b.bench("engine/noop_gaussian_u16", || engine.analyze(&d, &ctx.graph).period_ps);
    let toggle = d
        .routes
        .iter()
        .flat_map(|r| r.sink_paths.iter().flatten())
        .copied()
        .find(|&n| matches!(ctx.graph.decode(n).kind, NodeKind::SbOut { .. }))
        .expect("routed design crosses a switch-box output");
    b.bench("engine/perturb_gaussian_u16", || {
        if !d.sb_regs.remove(&toggle) {
            d.sb_regs.insert(toggle);
        }
        engine.analyze(&d, &ctx.graph).period_ps
    });

    let harris = compile(
        &crate::apps::dense::harris(1530, 2554, 4),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/harris_u4", || analyze(&harris.design, &ctx.graph).period_ps);

    let sp = compile(
        &crate::apps::sparse::mat_elemmul(128, 128, 0.1),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/sparse_elemmul", || analyze(&sp.design, &ctx.graph).period_ps);
}

/// Simulators: fabric cycle simulation and the sparse ready-valid actor
/// simulation.
pub fn run_sim(b: &mut Bencher) {
    use std::collections::BTreeMap;

    use crate::sim::dense::FabricSim;
    use crate::sparse::sim::simulate_app;

    let ctx = CompileCtx::paper();
    let c = compile(
        &crate::apps::dense::gaussian(64, 64, 1),
        &ctx,
        &PipelineConfig::with_postpnr(),
        3,
    )
    .unwrap();
    let mut ins = BTreeMap::new();
    ins.insert(0u16, (0..4096).map(|x| (x * 7 + 5) % 31).collect::<Vec<i64>>());
    b.bench("fabric/gaussian_64x64_frame", || {
        FabricSim::run(&c.design, &ins, 4096).outputs.len()
    });

    let interp_g = c.design.dfg.clone();
    b.bench("interp/gaussian_64x64_frame", || {
        crate::dfg::interp::Interp::run(&interp_g, &ins, 4096).outputs.len()
    });

    let app = crate::apps::sparse::mat_elemmul(128, 128, 0.1);
    let data = crate::apps::sparse::data_for("mat_elemmul", 42);
    b.bench("sparse/mat_elemmul_128", || simulate_app("mat_elemmul", &app.dfg, &data).cycles);

    let tt = crate::apps::sparse::tensor_ttv(48, 48, 48, 0.05);
    let tdata = crate::apps::sparse::data_for("ttv", 42);
    b.bench("sparse/ttv_48", || simulate_app("ttv", &tt.dfg, &tdata).cycles);
}

/// End-to-end table regeneration: one measurement per paper table/figure
/// pipeline (compile + pipelining + STA for a representative app of each
/// experiment).
pub fn run_tables(b: &mut Bencher) {
    use crate::timing::gatelevel::{gate_level_period_ps, GateLevelParams};
    let ctx = CompileCtx::paper();

    b.bench("fig6/gaussian_point", || {
        let c = compile(
            &crate::apps::dense::gaussian(64, 64, 2),
            &ctx,
            &PipelineConfig::compute_only(),
            3,
        )
        .unwrap();
        gate_level_period_ps(&c.design, &ctx.graph, &GateLevelParams::default())
    });

    b.bench("table1/unsharp_full", || {
        compile(
            &crate::apps::dense::unsharp(1536, 2560, 4),
            &ctx,
            &PipelineConfig::with_postpnr(),
            3,
        )
        .unwrap()
        .fmax_mhz()
    });

    b.bench("table2/vec_elemadd_all", || {
        let app = crate::apps::sparse::vec_elemadd(4096, 0.25);
        let cfg = PipelineConfig::sparse_ladder().pop().unwrap().1;
        let c = compile(&app, &ctx, &cfg, 11).unwrap();
        let data = crate::apps::sparse::data_for("vec_elemadd", 42);
        crate::sparse::sim::simulate_app("vec_elemadd", &c.design.dfg, &data).cycles
    });
}

/// Paired unfused/fused measurements: the same app compiled through the
/// identical flow with `fusion` off and on, so CI's `BENCH_fuse.json`
/// shows the fusion pass's cost (the extra stage) next to its payoff
/// (fewer placed nodes → smaller PnR problem). Entries come in
/// `<name>_unfused` / `<name>_fused` pairs over the same config.
pub fn run_fuse(b: &mut Bencher) {
    let ctx = CompileCtx::paper();
    let unfused = PipelineConfig::with_postpnr();
    let fused = PipelineConfig { fusion: true, ..PipelineConfig::with_postpnr() };
    for (name, app) in [
        ("unsharp", crate::apps::dense::unsharp(256, 256, 1)),
        ("harris", crate::apps::dense::harris(256, 256, 1)),
    ] {
        b.bench(&format!("compile/{name}_unfused"), || {
            compile(&app, &ctx, &unfused, 3).unwrap().design.dfg.nodes.len()
        });
        b.bench(&format!("compile/{name}_fused"), || {
            compile(&app, &ctx, &fused, 3).unwrap().design.dfg.nodes.len()
        });
    }
}

/// Run one suite by name into the given bencher.
pub fn run_suite(name: &str, b: &mut Bencher) -> Result<(), String> {
    match name {
        "compile" => run_compile(b),
        "pnr" => run_pnr(b),
        "sta" => run_sta(b),
        "sim" => run_sim(b),
        "tables" => run_tables(b),
        "fuse" => run_fuse(b),
        other => {
            return Err(format!(
                "unknown bench suite '{other}' (one of: {})",
                SUITE_NAMES.join(" ")
            ))
        }
    }
    Ok(())
}

/// Machine-readable snapshot of a finished bencher run.
pub fn to_json(suite: &str, b: &Bencher) -> Json {
    let mut j = Json::obj();
    j.set("schema", "cascade-bench-v1").set("suite", suite);
    let mut arr = Json::Arr(vec![]);
    for r in b.results() {
        arr.push(r.to_json());
    }
    j.set("results", arr);
    j
}

// ---------------------------------------------------------------------------
// Snapshot comparison (`cascade bench --compare`, ISSUE 10)
// ---------------------------------------------------------------------------

/// Outcome of one benchmark's old-vs-new comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Present in both, within tolerance either way.
    Ok,
    /// New median slower than old by more than the tolerance. Fails the run.
    Regression,
    /// New median faster than old by more than the tolerance (informational).
    Improved,
    /// Only in the new snapshot (informational — coverage grew).
    New,
    /// Only in the old snapshot. Fails the run: a silently vanished
    /// benchmark is lost regression coverage, not a pass.
    Gone,
}

impl Verdict {
    pub fn label(self) -> &'static str {
        match self {
            Verdict::Ok => "OK",
            Verdict::Regression => "REGRESSION",
            Verdict::Improved => "IMPROVED",
            Verdict::New => "NEW",
            Verdict::Gone => "GONE",
        }
    }

    /// Does this verdict fail the comparison?
    pub fn fails(self) -> bool {
        matches!(self, Verdict::Regression | Verdict::Gone)
    }
}

/// One row of the comparison table.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub name: String,
    pub old_ns: Option<f64>,
    pub new_ns: Option<f64>,
    pub verdict: Verdict,
}

impl CompareRow {
    /// `new/old` slowdown ratio (1.0 = unchanged), when both sides exist.
    pub fn ratio(&self) -> Option<f64> {
        match (self.old_ns, self.new_ns) {
            (Some(o), Some(n)) if o > 0.0 => Some(n / o),
            _ => None,
        }
    }
}

/// Parse a `cascade-bench-v1` snapshot into `(suite, [(name, median_ns)])`.
pub fn parse_snapshot(j: &Json) -> Result<(String, Vec<(String, f64)>), String> {
    if j.get("schema").and_then(Json::as_str) != Some("cascade-bench-v1") {
        return Err("not a cascade-bench-v1 snapshot (missing/unknown \"schema\")".into());
    }
    let suite = j
        .get("suite")
        .and_then(Json::as_str)
        .ok_or("snapshot missing \"suite\"")?
        .to_string();
    let results = j
        .get("results")
        .and_then(Json::as_arr)
        .ok_or("snapshot missing \"results\" array")?;
    let mut out = Vec::new();
    for r in results {
        let name = r
            .get("name")
            .and_then(Json::as_str)
            .ok_or("result entry missing \"name\"")?;
        let median = r
            .get("median_ns")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("result '{name}' missing numeric \"median_ns\""))?;
        out.push((name.to_string(), median));
    }
    Ok((suite, out))
}

/// Compare two snapshots' medians under a symmetric percentage tolerance:
/// a benchmark regresses when `new > old * (1 + tol/100)` and improves
/// when `new < old / (1 + tol/100)`. Rows come out in old-snapshot order
/// with new-only entries appended.
pub fn compare(
    old: &[(String, f64)],
    new: &[(String, f64)],
    tolerance_pct: f64,
) -> Vec<CompareRow> {
    let factor = 1.0 + tolerance_pct / 100.0;
    let mut rows = Vec::new();
    for (name, o) in old {
        let row = match new.iter().find(|(n, _)| n == name) {
            Some((_, nv)) => {
                let verdict = if *nv > o * factor {
                    Verdict::Regression
                } else if *nv < o / factor {
                    Verdict::Improved
                } else {
                    Verdict::Ok
                };
                CompareRow { name: name.clone(), old_ns: Some(*o), new_ns: Some(*nv), verdict }
            }
            None => CompareRow { name: name.clone(), old_ns: Some(*o), new_ns: None, verdict: Verdict::Gone },
        };
        rows.push(row);
    }
    for (name, nv) in new {
        if !old.iter().any(|(n, _)| n == name) {
            rows.push(CompareRow {
                name: name.clone(),
                old_ns: None,
                new_ns: Some(*nv),
                verdict: Verdict::New,
            });
        }
    }
    rows
}

/// Render the verdict table plus a one-line summary.
pub fn render_compare(rows: &[CompareRow], tolerance_pct: f64) -> String {
    use crate::util::bench::fmt_ns;
    let mut out = String::new();
    out.push_str(&format!(
        "{:<52} {:>12} {:>12} {:>8}  verdict\n",
        "benchmark", "old median", "new median", "ratio"
    ));
    let opt = |v: Option<f64>| v.map(|ns| fmt_ns(ns)).unwrap_or_else(|| "-".into());
    for r in rows {
        let ratio = r.ratio().map(|x| format!("{x:.2}x")).unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:<52} {:>12} {:>12} {:>8}  {}\n",
            r.name,
            opt(r.old_ns),
            opt(r.new_ns),
            ratio,
            r.verdict.label()
        ));
    }
    let fails = rows.iter().filter(|r| r.verdict.fails()).count();
    out.push_str(&format!(
        "compare: {} benchmark(s), tolerance {:.0}%: {}\n",
        rows.len(),
        tolerance_pct,
        if fails == 0 { "PASS".to_string() } else { format!("{fails} FAILING") }
    ));
    out
}

fn read_snapshot(path: &str) -> Result<(String, Vec<(String, f64)>), String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("bench --compare: cannot read {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| format!("bench --compare: {path}: {e}"))?;
    parse_snapshot(&j).map_err(|e| format!("bench --compare: {path}: {e}"))
}

/// `cascade bench --compare OLD.json [--against NEW.json] [--tolerance PCT]`:
/// diff two snapshots, print the verdict table, fail on REGRESSION/GONE.
/// Without `--against`, the new side defaults to `BENCH_<suite>.json` in
/// the working directory (the file a `--json` run of OLD's suite writes).
fn compare_cli(args: &Args, old_path: &str) -> Result<(), String> {
    let tolerance: f64 = match args.opt("tolerance") {
        Some(s) => s
            .parse()
            .ok()
            .filter(|t: &f64| *t >= 0.0)
            .ok_or_else(|| format!("bench: bad --tolerance '{s}' (percentage >= 0)"))?,
        None => 50.0,
    };
    let (old_suite, old) = read_snapshot(old_path)?;
    let default_new = format!("BENCH_{old_suite}.json");
    let new_path = args.opt_or("against", &default_new);
    let (new_suite, new) = read_snapshot(new_path)?;
    if new_suite != old_suite {
        println!(
            "bench --compare: note: suites differ ('{old_suite}' vs '{new_suite}') — \
             comparing by benchmark name"
        );
    }
    let rows = compare(&old, &new, tolerance);
    print!("{}", render_compare(&rows, tolerance));
    let failing: Vec<&CompareRow> = rows.iter().filter(|r| r.verdict.fails()).collect();
    if failing.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "bench --compare: {} benchmark(s) failed vs {old_path} (tolerance {tolerance:.0}%): {}",
            failing.len(),
            failing
                .iter()
                .map(|r| format!("{} [{}]", r.name, r.verdict.label()))
                .collect::<Vec<_>>()
                .join(", ")
        ))
    }
}

/// `cascade bench [--suite s1,s2,...] [--json] [--fast]` or
/// `cascade bench --compare OLD.json [--against NEW.json] [--tolerance PCT]`.
///
/// Run mode: `--suite` takes one or more comma-separated suite names (run
/// in order, one `BENCH_<suite>.json` each under `--json`); `--fast`
/// presets tiny warmup/budget (unless the env knobs are already set) so CI
/// smoke runs stay cheap.
///
/// Compare mode: diff two `cascade-bench-v1` snapshots and exit non-zero
/// on any REGRESSION (median slowdown beyond `--tolerance`, default 50%)
/// or GONE (benchmark vanished) verdict — the CI regression gate against
/// `bench/baseline/` (see `docs/performance.md`).
pub fn bench_cli(args: &Args) -> Result<(), String> {
    if let Some(old_path) = args.opt("compare") {
        return compare_cli(args, old_path);
    }
    if args.flag("fast") {
        for (var, val) in
            [("CASCADE_BENCH_WARMUP_MS", "10"), ("CASCADE_BENCH_BUDGET_MS", "60")]
        {
            if std::env::var_os(var).is_none() {
                std::env::set_var(var, val);
            }
        }
    }
    for suite in args.opt_or("suite", "compile").split(',').filter(|s| !s.is_empty()) {
        let mut b = Bencher::new(suite);
        println!("bench: suite '{suite}'...");
        run_suite(suite, &mut b)?;
        b.finish();
        if args.flag("json") {
            let path = format!("BENCH_{suite}.json");
            std::fs::write(&path, to_json(suite, &b).to_string_pretty())
                .map_err(|e| format!("cannot write {path}: {e}"))?;
            println!("wrote {path}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_carries_schema_and_results() {
        std::env::set_var("CASCADE_BENCH_WARMUP_MS", "1");
        std::env::set_var("CASCADE_BENCH_BUDGET_MS", "2");
        let mut b = Bencher::new("selftest");
        b.bench("noop/sum", || (0..64u64).sum::<u64>());
        let j = to_json("selftest", &b).to_string_compact();
        assert!(j.contains("\"schema\":\"cascade-bench-v1\""), "{j}");
        assert!(j.contains("\"suite\":\"selftest\""), "{j}");
        assert!(j.contains("selftest/noop/sum"), "{j}");
        std::env::remove_var("CASCADE_BENCH_WARMUP_MS");
        std::env::remove_var("CASCADE_BENCH_BUDGET_MS");
    }

    #[test]
    fn unknown_suite_is_rejected_with_the_roster() {
        let mut b = Bencher::new("x");
        let err = run_suite("nope", &mut b).unwrap_err();
        assert!(err.contains("compile"), "{err}");
        assert!(err.contains("tables"), "{err}");
    }

    fn snap(entries: &[(&str, f64)]) -> Vec<(String, f64)> {
        entries.iter().map(|(n, v)| (n.to_string(), *v)).collect()
    }

    #[test]
    fn compare_classifies_every_verdict() {
        let old = snap(&[
            ("s/same", 100.0),
            ("s/slower", 100.0),
            ("s/faster", 100.0),
            ("s/gone", 100.0),
        ]);
        let new = snap(&[
            ("s/same", 110.0),   // +10% < 50% tolerance
            ("s/slower", 200.0), // 2.0x > 1.5x
            ("s/faster", 50.0),  // 0.5x < 1/1.5
            ("s/new", 42.0),
        ]);
        let rows = compare(&old, &new, 50.0);
        let verdict = |name: &str| rows.iter().find(|r| r.name == name).unwrap().verdict;
        assert_eq!(verdict("s/same"), Verdict::Ok);
        assert_eq!(verdict("s/slower"), Verdict::Regression);
        assert_eq!(verdict("s/faster"), Verdict::Improved);
        assert_eq!(verdict("s/gone"), Verdict::Gone);
        assert_eq!(verdict("s/new"), Verdict::New);
        assert!(verdict("s/slower").fails() && verdict("s/gone").fails());
        assert!(!verdict("s/faster").fails() && !verdict("s/new").fails());
        let table = render_compare(&rows, 50.0);
        assert!(table.contains("REGRESSION"), "{table}");
        assert!(table.contains("2 FAILING"), "{table}");
        assert!(table.contains("2.00x"), "{table}");
    }

    #[test]
    fn compare_boundary_is_strict() {
        // Exactly at tolerance is OK — only *beyond* the band fails.
        let old = snap(&[("s/x", 100.0)]);
        let rows = compare(&old, &snap(&[("s/x", 150.0)]), 50.0);
        assert_eq!(rows[0].verdict, Verdict::Ok);
        let rows = compare(&old, &snap(&[("s/x", 150.1)]), 50.0);
        assert_eq!(rows[0].verdict, Verdict::Regression);
    }

    #[test]
    fn snapshot_round_trips_through_parse() {
        std::env::set_var("CASCADE_BENCH_WARMUP_MS", "1");
        std::env::set_var("CASCADE_BENCH_BUDGET_MS", "2");
        let mut b = Bencher::new("selftest");
        b.bench("noop/sum", || (0..64u64).sum::<u64>());
        let j = to_json("selftest", &b);
        let (suite, entries) = parse_snapshot(&j).unwrap();
        assert_eq!(suite, "selftest");
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, "selftest/noop/sum");
        assert!(entries[0].1 > 0.0);
        // A self-comparison is all-OK at any tolerance.
        assert!(compare(&entries, &entries, 0.0).iter().all(|r| r.verdict == Verdict::Ok));
        std::env::remove_var("CASCADE_BENCH_WARMUP_MS");
        std::env::remove_var("CASCADE_BENCH_BUDGET_MS");
    }

    #[test]
    fn parse_snapshot_rejects_wrong_schema() {
        let j = Json::parse("{\"schema\":\"other\",\"suite\":\"x\",\"results\":[]}").unwrap();
        assert!(parse_snapshot(&j).unwrap_err().contains("schema"));
    }
}
