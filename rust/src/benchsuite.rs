//! Library home of the benchmark suites.
//!
//! The `cargo bench` targets (`benches/bench_{pnr,sta,sim,tables}.rs`,
//! `harness = false`) are thin mains over the `run_*` functions here, so
//! the same kernels are reachable without a bench build: `cascade bench`
//! drives them from the CLI and — with `--json` — writes a
//! machine-readable `BENCH_<suite>.json` snapshot (schema below) that CI
//! uploads as an artifact.
//!
//! ```json
//! {
//!   "schema": "cascade-bench-v1",
//!   "suite": "compile",
//!   "results": [
//!     {"name": "compile/gaussian_64x64_compute", "iters": 12,
//!      "median_ns": 1.2e7, "mean_ns": 1.3e7, "p10_ns": 1.1e7, "p90_ns": 1.5e7}
//!   ]
//! }
//! ```
//!
//! Budgets come from `CASCADE_BENCH_WARMUP_MS` / `CASCADE_BENCH_BUDGET_MS`
//! (see [`crate::util::bench::Bencher`]); `--fast` presets them small for
//! smoke runs.

use crate::pipeline::{compile, CompileCtx, PipelineConfig};
use crate::util::bench::Bencher;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Suites runnable by name (CLI `--suite`, default first).
pub const SUITE_NAMES: &[&str] = &["compile", "pnr", "sta", "sim", "tables", "fuse"];

/// CI-sized end-to-end suite: small-frame compiles through every pipeline
/// stage plus STA and bitstream encoding in isolation. This is the suite
/// `cascade bench` runs by default — minutes, not tens of minutes, even
/// at the default budget.
pub fn run_compile(b: &mut Bencher) {
    let ctx = CompileCtx::paper();
    let app = crate::apps::dense::gaussian(64, 64, 2);
    b.bench("compile/gaussian_64x64_compute", || {
        compile(&app, &ctx, &PipelineConfig::compute_only(), 3).unwrap().fmax_mhz()
    });
    b.bench("compile/gaussian_64x64_postpnr", || {
        compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap().fmax_mhz()
    });

    let c = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap();
    b.bench("sta/gaussian_64x64", || {
        crate::timing::sta::analyze(&c.design, &ctx.graph).period_ps
    });
    b.bench("encode/gaussian_64x64", || crate::sim::encode::encode_compiled(&c).len());

    let sp = crate::apps::sparse::vec_elemadd(4096, 0.25);
    b.bench("compile/vec_elemadd_sparse", || {
        compile(&sp, &ctx, &PipelineConfig::compute_only(), 3).unwrap().fmax_mhz()
    });
}

/// Place-and-route: SA placement and PathFinder routing on the
/// paper-scale array (the compile-time hot paths).
pub fn run_pnr(b: &mut Bencher) {
    use crate::pnr::{build_nets, place, route, PlaceParams, RouteParams};
    let ctx = CompileCtx::paper();
    let arch = crate::arch::params::ArchParams::paper();

    let app = crate::apps::dense::gaussian(6400, 4800, 16);
    let nets = build_nets(&app.dfg, &arch);
    b.bench("place/gaussian_u16", || {
        place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3)).cost
    });
    b.bench("place/gaussian_u16_alpha", || {
        place(&app.dfg, &nets, &arch, &PlaceParams::cascade(3)).cost
    });
    // Full-recompute reference (`--no-incremental` mode): same moves, same
    // cost bits; the delta vs `place/gaussian_u16` is the incremental win.
    let pp_scratch = PlaceParams { incremental: false, ..PlaceParams::baseline(3) };
    b.bench("place/gaussian_u16_scratch", || {
        place(&app.dfg, &nets, &arch, &pp_scratch).cost
    });

    let placement = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3));
    b.bench("route/gaussian_u16", || {
        route(&app.dfg, &nets, &placement, &arch, &ctx.graph, &RouteParams::default())
            .unwrap()
            .len()
    });
    let rp_scratch = RouteParams { incremental: false, ..RouteParams::default() };
    b.bench("route/gaussian_u16_scratch", || {
        route(&app.dfg, &nets, &placement, &arch, &ctx.graph, &rp_scratch).unwrap().len()
    });

    let harris = crate::apps::dense::harris(1530, 2554, 4);
    let hnets = build_nets(&harris.dfg, &arch);
    b.bench("place/harris_u4", || {
        place(&harris.dfg, &hnets, &arch, &PlaceParams::baseline(5)).cost
    });
}

/// STA hot paths: the analysis runs once per post-PnR pipelining
/// iteration, so its latency bounds compile time.
pub fn run_sta(b: &mut Bencher) {
    use crate::arch::canal::NodeKind;
    use crate::timing::sta::{analyze, StaEngine};
    let ctx = CompileCtx::paper();

    let gauss = compile(
        &crate::apps::dense::gaussian(6400, 4800, 16),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/gaussian_u16", || analyze(&gauss.design, &ctx.graph).period_ps);

    // Incremental engine (post-PnR loop hot path). `noop` bounds the fixed
    // per-call diff cost on an unchanged design; `perturb` toggles one
    // pipelining register per call and re-propagates only downstream of it.
    // Compare both against `analyze/gaussian_u16` for the memoization win.
    let mut d = gauss.design;
    let mut engine = StaEngine::new(&d);
    b.bench("engine/noop_gaussian_u16", || engine.analyze(&d, &ctx.graph).period_ps);
    let toggle = d
        .routes
        .iter()
        .flat_map(|r| r.sink_paths.iter().flatten())
        .copied()
        .find(|&n| matches!(ctx.graph.decode(n).kind, NodeKind::SbOut { .. }))
        .expect("routed design crosses a switch-box output");
    b.bench("engine/perturb_gaussian_u16", || {
        if !d.sb_regs.remove(&toggle) {
            d.sb_regs.insert(toggle);
        }
        engine.analyze(&d, &ctx.graph).period_ps
    });

    let harris = compile(
        &crate::apps::dense::harris(1530, 2554, 4),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/harris_u4", || analyze(&harris.design, &ctx.graph).period_ps);

    let sp = compile(
        &crate::apps::sparse::mat_elemmul(128, 128, 0.1),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/sparse_elemmul", || analyze(&sp.design, &ctx.graph).period_ps);
}

/// Simulators: fabric cycle simulation and the sparse ready-valid actor
/// simulation.
pub fn run_sim(b: &mut Bencher) {
    use std::collections::BTreeMap;

    use crate::sim::dense::FabricSim;
    use crate::sparse::sim::simulate_app;

    let ctx = CompileCtx::paper();
    let c = compile(
        &crate::apps::dense::gaussian(64, 64, 1),
        &ctx,
        &PipelineConfig::with_postpnr(),
        3,
    )
    .unwrap();
    let mut ins = BTreeMap::new();
    ins.insert(0u16, (0..4096).map(|x| (x * 7 + 5) % 31).collect::<Vec<i64>>());
    b.bench("fabric/gaussian_64x64_frame", || {
        FabricSim::run(&c.design, &ins, 4096).outputs.len()
    });

    let interp_g = c.design.dfg.clone();
    b.bench("interp/gaussian_64x64_frame", || {
        crate::dfg::interp::Interp::run(&interp_g, &ins, 4096).outputs.len()
    });

    let app = crate::apps::sparse::mat_elemmul(128, 128, 0.1);
    let data = crate::apps::sparse::data_for("mat_elemmul", 42);
    b.bench("sparse/mat_elemmul_128", || simulate_app("mat_elemmul", &app.dfg, &data).cycles);

    let tt = crate::apps::sparse::tensor_ttv(48, 48, 48, 0.05);
    let tdata = crate::apps::sparse::data_for("ttv", 42);
    b.bench("sparse/ttv_48", || simulate_app("ttv", &tt.dfg, &tdata).cycles);
}

/// End-to-end table regeneration: one measurement per paper table/figure
/// pipeline (compile + pipelining + STA for a representative app of each
/// experiment).
pub fn run_tables(b: &mut Bencher) {
    use crate::timing::gatelevel::{gate_level_period_ps, GateLevelParams};
    let ctx = CompileCtx::paper();

    b.bench("fig6/gaussian_point", || {
        let c = compile(
            &crate::apps::dense::gaussian(64, 64, 2),
            &ctx,
            &PipelineConfig::compute_only(),
            3,
        )
        .unwrap();
        gate_level_period_ps(&c.design, &ctx.graph, &GateLevelParams::default())
    });

    b.bench("table1/unsharp_full", || {
        compile(
            &crate::apps::dense::unsharp(1536, 2560, 4),
            &ctx,
            &PipelineConfig::with_postpnr(),
            3,
        )
        .unwrap()
        .fmax_mhz()
    });

    b.bench("table2/vec_elemadd_all", || {
        let app = crate::apps::sparse::vec_elemadd(4096, 0.25);
        let cfg = PipelineConfig::sparse_ladder().pop().unwrap().1;
        let c = compile(&app, &ctx, &cfg, 11).unwrap();
        let data = crate::apps::sparse::data_for("vec_elemadd", 42);
        crate::sparse::sim::simulate_app("vec_elemadd", &c.design.dfg, &data).cycles
    });
}

/// Paired unfused/fused measurements: the same app compiled through the
/// identical flow with `fusion` off and on, so CI's `BENCH_fuse.json`
/// shows the fusion pass's cost (the extra stage) next to its payoff
/// (fewer placed nodes → smaller PnR problem). Entries come in
/// `<name>_unfused` / `<name>_fused` pairs over the same config.
pub fn run_fuse(b: &mut Bencher) {
    let ctx = CompileCtx::paper();
    let unfused = PipelineConfig::with_postpnr();
    let fused = PipelineConfig { fusion: true, ..PipelineConfig::with_postpnr() };
    for (name, app) in [
        ("unsharp", crate::apps::dense::unsharp(256, 256, 1)),
        ("harris", crate::apps::dense::harris(256, 256, 1)),
    ] {
        b.bench(&format!("compile/{name}_unfused"), || {
            compile(&app, &ctx, &unfused, 3).unwrap().design.dfg.nodes.len()
        });
        b.bench(&format!("compile/{name}_fused"), || {
            compile(&app, &ctx, &fused, 3).unwrap().design.dfg.nodes.len()
        });
    }
}

/// Run one suite by name into the given bencher.
pub fn run_suite(name: &str, b: &mut Bencher) -> Result<(), String> {
    match name {
        "compile" => run_compile(b),
        "pnr" => run_pnr(b),
        "sta" => run_sta(b),
        "sim" => run_sim(b),
        "tables" => run_tables(b),
        "fuse" => run_fuse(b),
        other => {
            return Err(format!(
                "unknown bench suite '{other}' (one of: {})",
                SUITE_NAMES.join(" ")
            ))
        }
    }
    Ok(())
}

/// Machine-readable snapshot of a finished bencher run.
pub fn to_json(suite: &str, b: &Bencher) -> Json {
    let mut j = Json::obj();
    j.set("schema", "cascade-bench-v1").set("suite", suite);
    let mut arr = Json::Arr(vec![]);
    for r in b.results() {
        arr.push(r.to_json());
    }
    j.set("results", arr);
    j
}

/// `cascade bench [--suite NAME] [--json] [--fast]`: run a suite from the
/// CLI. `--fast` presets tiny warmup/budget (unless the env knobs are
/// already set) so CI smoke runs stay cheap; `--json` writes
/// `BENCH_<suite>.json` next to the working directory in addition to the
/// `results/bench_<suite>.json` the bencher itself records.
pub fn bench_cli(args: &Args) -> Result<(), String> {
    let suite = args.opt_or("suite", "compile");
    if args.flag("fast") {
        for (var, val) in
            [("CASCADE_BENCH_WARMUP_MS", "10"), ("CASCADE_BENCH_BUDGET_MS", "60")]
        {
            if std::env::var_os(var).is_none() {
                std::env::set_var(var, val);
            }
        }
    }
    let mut b = Bencher::new(suite);
    println!("bench: suite '{suite}'...");
    run_suite(suite, &mut b)?;
    b.finish();
    if args.flag("json") {
        let path = format!("BENCH_{suite}.json");
        std::fs::write(&path, to_json(suite, &b).to_string_pretty())
            .map_err(|e| format!("cannot write {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_carries_schema_and_results() {
        std::env::set_var("CASCADE_BENCH_WARMUP_MS", "1");
        std::env::set_var("CASCADE_BENCH_BUDGET_MS", "2");
        let mut b = Bencher::new("selftest");
        b.bench("noop/sum", || (0..64u64).sum::<u64>());
        let j = to_json("selftest", &b).to_string_compact();
        assert!(j.contains("\"schema\":\"cascade-bench-v1\""), "{j}");
        assert!(j.contains("\"suite\":\"selftest\""), "{j}");
        assert!(j.contains("selftest/noop/sum"), "{j}");
        std::env::remove_var("CASCADE_BENCH_WARMUP_MS");
        std::env::remove_var("CASCADE_BENCH_BUDGET_MS");
    }

    #[test]
    fn unknown_suite_is_rejected_with_the_roster() {
        let mut b = Bencher::new("x");
        let err = run_suite("nope", &mut b).unwrap_err();
        assert!(err.contains("compile"), "{err}");
        assert!(err.contains("tables"), "{err}");
    }
}
