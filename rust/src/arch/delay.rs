//! Timing-model generation (paper §IV-A, Fig. 3).
//!
//! The paper generates a CGRA timing model by (1) enumerating, from the
//! Canal interconnect graph, all tile-level data and clock paths with
//! significant delay, (2) measuring each path's worst case with a
//! commercial STA tool on the post-place-and-route tile netlist with
//! parasitics, and (3) tabulating those worst-case delays for use by the
//! application STA tool.
//!
//! We do not have the GF12 netlists or PrimeTime, so step (2) is replaced
//! by a *synthetic gate/wire delay model* ([`DelayModelParams`]) calibrated
//! to the delays the paper publishes: a PE tile combinational core of at
//! most 0.7 ns, an interconnect hop (switch-box mux + boundary wire) of
//! about 0.14 ns through a PE tile, longer traversals through the
//! physically larger MEM tiles, direction-dependent wire lengths, and
//! per-tile clock skew. The toolkit only ever consumes the resulting
//! worst-case per-path-class table ([`DelayLib`]), so the substitution
//! preserves every downstream code path (see DESIGN.md §2).

use super::canal::{Edge, EdgeKind, InterconnectGraph, NodeId};
use super::params::{ArchParams, TileCoord, TileKind};

/// Coarse functional classes of PE operations; the DFG maps its opcodes
/// onto these for core-delay lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Add/sub/min/max/abs — full ALU carry chain.
    Add,
    /// 16x16 multiply (the longest PE path, the paper's 0.7 ns).
    Mul,
    /// Multiply-accumulate (mul + add fused; slightly longer than Mul).
    Mac,
    /// Comparisons producing 1-bit results.
    Cmp,
    /// Bitwise logic / select.
    Logic,
    /// Shifts.
    Shift,
    /// Route-through (register-only or wire-only PE usage).
    Pass,
}

/// Synthetic gate/wire model parameters. All delays in picoseconds, all
/// geometry in micrometres.
#[derive(Debug, Clone)]
pub struct DelayModelParams {
    /// Delay of one 2:1 mux level.
    pub mux2_ps: f64,
    /// Wire delay per micrometre (RC-dominated, buffered).
    pub wire_ps_per_um: f64,
    /// Tile dimensions (width, height) per kind.
    pub pe_dims_um: (f64, f64),
    pub mem_dims_um: (f64, f64),
    pub io_dims_um: (f64, f64),
    /// Combinational core delays.
    pub pe_mul_ps: f64,
    pub pe_add_ps: f64,
    pub pe_mac_ps: f64,
    pub pe_cmp_ps: f64,
    pub pe_logic_ps: f64,
    pub pe_shift_ps: f64,
    pub pe_pass_ps: f64,
    /// MEM tile SRAM read (addr-in to data-out).
    pub mem_read_ps: f64,
    /// IO tile boundary delay.
    pub io_ps: f64,
    /// Register clock-to-Q and setup.
    pub clk_q_ps: f64,
    pub setup_ps: f64,
    /// Clock-skew model: H-tree gradient per tile (x / y) plus a bounded
    /// per-instance component derived from the tile coordinate hash.
    pub skew_x_ps_per_tile: f64,
    pub skew_y_ps_per_tile: f64,
    pub skew_random_ps: f64,
}

impl Default for DelayModelParams {
    fn default() -> Self {
        DelayModelParams {
            mux2_ps: 20.0,
            wire_ps_per_um: 1.6,
            pe_dims_um: (50.0, 45.0),
            mem_dims_um: (90.0, 45.0),
            io_dims_um: (50.0, 25.0),
            pe_mul_ps: 700.0,
            pe_add_ps: 380.0,
            pe_mac_ps: 700.0,
            pe_cmp_ps: 300.0,
            pe_logic_ps: 220.0,
            pe_shift_ps: 260.0,
            pe_pass_ps: 80.0,
            mem_read_ps: 900.0,
            io_ps: 150.0,
            clk_q_ps: 60.0,
            setup_ps: 40.0,
            skew_x_ps_per_tile: 3.0,
            skew_y_ps_per_tile: 4.0,
            skew_random_ps: 10.0,
        }
    }
}

/// One enumerated-and-characterized tile-level path (the rows of the
/// generated timing model; kept for reporting and tests).
#[derive(Debug, Clone)]
pub struct PathRecord {
    pub class: EdgeKind,
    pub tile_kind: TileKind,
    pub horizontal: bool,
    pub delay_ps: u32,
}

/// The generated timing model: worst-case delay per tile-level path class,
/// plus core delays and the clock-skew evaluator.
#[derive(Debug, Clone)]
pub struct DelayLib {
    params: ArchParams,
    model: DelayModelParams,
    /// Indexed by tile-kind index.
    sb_turn: [u32; 3],
    sb_drive: [u32; 3],
    cb_tap: [u32; 3],
    /// Half-crossing wire delay per kind, horizontal / vertical.
    half_wire_h: [u32; 3],
    half_wire_v: [u32; 3],
    /// Every enumerated path (the "timing model report").
    pub records: Vec<PathRecord>,
}

fn kind_index(k: TileKind) -> usize {
    match k {
        TileKind::Pe => 0,
        TileKind::Mem => 1,
        TileKind::Io => 2,
    }
}

impl DelayLib {
    /// Generate the timing model for an architecture: enumerate the path
    /// classes present in the interconnect graph and characterize each with
    /// the synthetic gate/wire model (the Fig. 3 flow with the commercial
    /// STA tool swapped for the calibrated model).
    pub fn generate(arch: &ArchParams, model: &DelayModelParams) -> DelayLib {
        let t = arch.tracks as f64;
        let ports_in = arch.data_in_ports.max(arch.bit_in_ports) as f64;
        let ports_out = arch.data_out_ports.max(arch.bit_out_ports) as f64;

        let dims = |k: TileKind| match k {
            TileKind::Pe => model.pe_dims_um,
            TileKind::Mem => model.mem_dims_um,
            TileKind::Io => model.io_dims_um,
        };

        let mux_levels = |inputs: f64| inputs.max(2.0).log2().ceil();

        let mut lib = DelayLib {
            params: arch.clone(),
            model: model.clone(),
            sb_turn: [0; 3],
            sb_drive: [0; 3],
            cb_tap: [0; 3],
            half_wire_h: [0; 3],
            half_wire_v: [0; 3],
            records: Vec::new(),
        };

        for kind in [TileKind::Pe, TileKind::Mem, TileKind::Io] {
            let (w, h) = dims(kind);
            let ki = kind_index(kind);
            // SB output mux inputs: 3 turn inputs + the tile-output drives
            // sharing this track.
            let sb_inputs = 3.0 + 1.0;
            // Internal SB wiring spans ~1/4 of the tile.
            let sb_internal = 0.25 * w.max(h) * model.wire_ps_per_um;
            lib.sb_turn[ki] = (mux_levels(sb_inputs) * model.mux2_ps + sb_internal).round() as u32;
            // Drive path additionally crosses from the core output to the SB.
            lib.sb_drive[ki] =
                (mux_levels(sb_inputs) * model.mux2_ps + 0.4 * w.max(h) * model.wire_ps_per_um)
                    .round() as u32;
            // CB mux selects among all incoming tracks on all four sides.
            let cb_inputs = 4.0 * t;
            lib.cb_tap[ki] = (mux_levels(cb_inputs) * model.mux2_ps
                + 0.3 * w.max(h) * model.wire_ps_per_um)
                .round() as u32;
            lib.half_wire_h[ki] = (0.5 * w * model.wire_ps_per_um).round() as u32;
            lib.half_wire_v[ki] = (0.5 * h * model.wire_ps_per_um).round() as u32;

            for (class, d) in [
                (EdgeKind::SbTurn, lib.sb_turn[ki]),
                (EdgeKind::SbDrive, lib.sb_drive[ki]),
                (EdgeKind::CbTap, lib.cb_tap[ki]),
            ] {
                for horizontal in [false, true] {
                    lib.records
                        .push(PathRecord { class, tile_kind: kind, horizontal, delay_ps: d });
                }
            }
            lib.records.push(PathRecord {
                class: EdgeKind::Wire,
                tile_kind: kind,
                horizontal: true,
                delay_ps: 2 * lib.half_wire_h[ki],
            });
            lib.records.push(PathRecord {
                class: EdgeKind::Wire,
                tile_kind: kind,
                horizontal: false,
                delay_ps: 2 * lib.half_wire_v[ki],
            });
        }
        let _ = ports_in;
        let _ = ports_out;
        lib
    }

    /// Worst-case delay for a concrete RRG edge.
    pub fn edge_delay(&self, g: &InterconnectGraph, src: NodeId, e: &Edge) -> u32 {
        let s = g.decode(src);
        let skind = self.params.tile_kind(s.tile);
        match e.kind {
            EdgeKind::SbTurn => self.sb_turn[kind_index(skind)],
            EdgeKind::SbDrive => self.sb_drive[kind_index(skind)],
            EdgeKind::CbTap => self.cb_tap[kind_index(skind)],
            EdgeKind::Wire => {
                let d = g.decode(e.dst);
                let dkind = self.params.tile_kind(d.tile);
                let horizontal = s.tile.y == d.tile.y;
                if horizontal {
                    self.half_wire_h[kind_index(skind)] + self.half_wire_h[kind_index(dkind)]
                } else {
                    self.half_wire_v[kind_index(skind)] + self.half_wire_v[kind_index(dkind)]
                }
            }
        }
    }

    /// Combinational PE core delay for an operation class.
    pub fn pe_core_ps(&self, op: OpClass) -> u32 {
        let m = &self.model;
        (match op {
            OpClass::Add => m.pe_add_ps,
            OpClass::Mul => m.pe_mul_ps,
            OpClass::Mac => m.pe_mac_ps,
            OpClass::Cmp => m.pe_cmp_ps,
            OpClass::Logic => m.pe_logic_ps,
            OpClass::Shift => m.pe_shift_ps,
            OpClass::Pass => m.pe_pass_ps,
        })
        .round() as u32
    }

    /// Combinational core delay of a fused compound op (`Op::Fused`): the
    /// chained steps share one PE core, so the head pays its full class
    /// delay and each tail step adds its *incremental* cost — its class
    /// delay minus the operand-distribution stage the head already paid
    /// (modeled as the Pass core), floored at one mux level of chaining
    /// overhead. With the default calibration a Mul+Shr+Add compound
    /// comes out well under two back-to-back PE cores, which is the whole
    /// point of fusing.
    pub fn fused_core_ps(&self, classes: &[OpClass]) -> u32 {
        let Some((&head, tail)) = classes.split_first() else {
            return 0;
        };
        let pass = self.pe_core_ps(OpClass::Pass);
        let chain_mux = self.model.mux2_ps.round() as u32;
        let mut total = self.pe_core_ps(head);
        for &c in tail {
            total += self.pe_core_ps(c).saturating_sub(pass).max(chain_mux);
        }
        total
    }

    /// MEM tile core delay (SRAM read path).
    pub fn mem_core_ps(&self) -> u32 {
        self.model.mem_read_ps.round() as u32
    }

    /// IO tile core delay.
    pub fn io_core_ps(&self) -> u32 {
        self.model.io_ps.round() as u32
    }

    pub fn clk_q_ps(&self) -> u32 {
        self.model.clk_q_ps.round() as u32
    }

    pub fn setup_ps(&self) -> u32 {
        self.model.setup_ps.round() as u32
    }

    /// Worst-case clock skew at a tile: H-tree gradient from the array
    /// centre plus a bounded deterministic per-instance component.
    pub fn skew_ps(&self, tile: TileCoord) -> u32 {
        let cx = self.params.cols as f64 / 2.0;
        let cy = self.params.grid_rows() as f64 / 2.0;
        let gx = (tile.x as f64 - cx).abs() * self.model.skew_x_ps_per_tile;
        let gy = (tile.y as f64 - cy).abs() * self.model.skew_y_ps_per_tile;
        // Deterministic "instance" component in [0, skew_random_ps).
        let h = (tile.x as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((tile.y as u64).wrapping_mul(0xBF58476D1CE4E5B9));
        let frac = ((h >> 40) & 0xFFFF) as f64 / 65536.0;
        (gx + gy + frac * self.model.skew_random_ps).round() as u32
    }

    /// Maximum skew difference between any two tiles — the margin the STA
    /// tool budgets on every register-to-register path.
    pub fn max_skew_margin_ps(&self) -> u32 {
        let mut lo = u32::MAX;
        let mut hi = 0u32;
        for tile in self.params.all_tiles() {
            let s = self.skew_ps(tile);
            lo = lo.min(s);
            hi = hi.max(s);
        }
        hi - lo
    }

    /// The architecture this library was generated for.
    pub fn arch(&self) -> &ArchParams {
        &self.params
    }

    /// The underlying gate/wire model (used by the gate-level-simulation
    /// surrogate to derive per-instance delays).
    pub fn model(&self) -> &DelayModelParams {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lib() -> DelayLib {
        DelayLib::generate(&ArchParams::paper(), &DelayModelParams::default())
    }

    #[test]
    fn calibration_matches_paper_magnitudes() {
        let l = lib();
        // One interconnect hop through a PE tile: SB turn + boundary wire.
        let hop = l.sb_turn[0] + 2 * l.half_wire_h[0];
        // Paper: "the delay through one switch box is about 0.14ns".
        assert!((120..=180).contains(&hop), "PE hop {hop} ps");
        // Paper: "the delay through a PE tile is a maximum of 0.7ns".
        assert_eq!(l.pe_core_ps(OpClass::Mul), 700);
        assert!(l.pe_core_ps(OpClass::Add) < l.pe_core_ps(OpClass::Mul));
    }

    #[test]
    fn fused_core_delay_composition() {
        let l = lib();
        // A compound is strictly slower than its head alone...
        let chain = [OpClass::Mul, OpClass::Shift, OpClass::Add];
        let fused = l.fused_core_ps(&chain);
        assert!(fused > l.pe_core_ps(OpClass::Mul));
        // ...but strictly faster than separate PE cores back to back.
        let separate: u32 = chain.iter().map(|&c| l.pe_core_ps(c)).sum();
        assert!(fused < separate, "fused {fused} vs separate {separate}");
        // Degenerate cases.
        assert_eq!(l.fused_core_ps(&[]), 0);
        assert_eq!(l.fused_core_ps(&[OpClass::Add]), l.pe_core_ps(OpClass::Add));
        // A Pass tail still costs at least the chaining mux.
        assert_eq!(
            l.fused_core_ps(&[OpClass::Add, OpClass::Pass]),
            l.pe_core_ps(OpClass::Add) + 20
        );
    }

    #[test]
    fn mem_tiles_slower_than_pe() {
        let l = lib();
        assert!(l.half_wire_h[1] > l.half_wire_h[0], "MEM wider than PE");
        assert!(l.mem_core_ps() > l.pe_core_ps(OpClass::Mul));
    }

    #[test]
    fn direction_asymmetry() {
        let l = lib();
        // PE tiles are wider than tall -> horizontal crossings are longer.
        assert!(l.half_wire_h[0] > l.half_wire_v[0]);
    }

    #[test]
    fn skew_bounded_and_deterministic() {
        let l = lib();
        let a = l.skew_ps(TileCoord::new(0, 0));
        let b = l.skew_ps(TileCoord::new(0, 0));
        assert_eq!(a, b);
        let margin = l.max_skew_margin_ps();
        assert!(margin > 0);
        assert!(margin < 200, "skew margin {margin} ps should be small vs clock period");
    }

    #[test]
    fn record_table_covers_all_classes() {
        let l = lib();
        for class in [EdgeKind::SbTurn, EdgeKind::SbDrive, EdgeKind::CbTap, EdgeKind::Wire] {
            for kind in [TileKind::Pe, TileKind::Mem, TileKind::Io] {
                assert!(
                    l.records.iter().any(|r| r.class == class && r.tile_kind == kind),
                    "missing record {class:?}/{kind:?}"
                );
            }
        }
    }

    #[test]
    fn annotate_assigns_positive_delays() {
        let arch = ArchParams::tiny(3, 4);
        let l = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut g = InterconnectGraph::build(&arch);
        g.annotate_delays(&l);
        let mut checked = 0;
        for id in 0..g.num_nodes() as NodeId {
            for e in g.fanout(id) {
                assert!(e.delay_ps > 0, "zero delay edge {:?}", e.kind);
                checked += 1;
            }
        }
        assert!(checked > 1000);
    }

    #[test]
    fn wire_delay_depends_on_neighbour_kind() {
        let arch = ArchParams::paper();
        let l = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut g = InterconnectGraph::build(&arch);
        g.annotate_delays(&l);
        // Crossing into a MEM column is slower than PE->PE.
        use crate::arch::canal::{NodeKind, Side, Layer};
        let pe_pe = g.node_id(
            TileCoord::new(0, 1),
            Layer::B16,
            NodeKind::SbOut { side: Side::E, track: 0 },
        );
        let pe_mem = g.node_id(
            TileCoord::new(2, 1),
            Layer::B16,
            NodeKind::SbOut { side: Side::E, track: 0 },
        );
        let d_pe_pe = g.fanout(pe_pe)[0].delay_ps;
        let d_pe_mem = g.fanout(pe_mem)[0].delay_ps; // tile 3 is MEM
        assert!(d_pe_mem > d_pe_pe);
    }
}
