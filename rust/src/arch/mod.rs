//! CGRA architecture model.
//!
//! The paper targets a class of CGRAs "like [Amber]": a large tile array
//! (32x16 in the evaluation — 384 PE tiles + 128 MEM tiles), a configurable
//! interconnect that allows single-cycle multi-hop connections from any tile
//! to any other tile, and configurable pipelining registers within every
//! switch box. This module models that architecture:
//!
//! * [`params`] — the architecture parameter set (array geometry, track
//!   counts, port counts, register resources).
//! * [`canal`] — the Canal-style interconnect graph: a routing-resource
//!   graph (RRG) over switch boxes (SB), connection boxes (CB) and tile
//!   ports, on two wiring layers (16-bit data, 1-bit control), including
//!   tile-level path enumeration used for timing-model generation.
//! * [`delay`] — the timing-model generation methodology (paper §IV-A):
//!   enumerate all significant tile-level paths from the interconnect graph
//!   and evaluate them with a calibrated wire/gate delay model standing in
//!   for the commercial STA run on the post-PnR netlist. Also models
//!   per-tile clock skew.
//! * [`bitstream`] — configuration-space encoding: every configurable
//!   feature (SB mux select, SB pipeline register enable, CB select, PE
//!   opcode and input registers, MEM mode/schedule) maps to (address, data)
//!   words; supports the configuration duplication needed by the low
//!   unrolling duplication pass.

pub mod params;
pub mod canal;
pub mod delay;
pub mod bitstream;

pub use canal::{InterconnectGraph, NodeId, NodeKind, Side, Layer};
pub use delay::{DelayLib, DelayModelParams};
pub use params::{ArchParams, TileKind, TileCoord};
