//! Canal-style interconnect graph (routing-resource graph, RRG).
//!
//! Canal [16] describes a CGRA interconnect as a graph: switch boxes (SB)
//! route signals between tiles on horizontal/vertical tracks, connection
//! boxes (CB) tap passing tracks into tile input ports, and tile outputs
//! drive SB outputs. We reproduce that representation with four node kinds
//! per tile per wiring layer:
//!
//! * `SbIn(side, track)` — a track signal arriving at the tile on `side`.
//! * `SbOut(side, track)` — a track signal leaving the tile on `side`;
//!   every SbOut has a configurable pipelining register (paper §V-D: "The
//!   interconnect ... has configurable pipelining registers within every
//!   switchbox of the array ... on every 16-bit and 1-bit track going out
//!   of the switchbox in each of the four directions").
//! * `CbIn(port)` — output of the connection-box mux feeding tile input
//!   `port`.
//! * `TileOut(port)` — tile core output `port`.
//!
//! Two wiring layers exist: [`Layer::B16`] (16-bit data) and [`Layer::B1`]
//! (1-bit control — valid/ready/flush). Edges are tagged with an
//! [`EdgeKind`] so the delay model can assign per-class worst-case delays.
//!
//! Node ids are dense `u32`s computed arithmetically (no hash maps on the
//! hot path); the graph is stored in CSR form.

use super::params::{ArchParams, TileCoord, TileKind};

/// Wiring layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Layer {
    /// 16-bit data layer.
    B16 = 0,
    /// 1-bit control layer (valid / ready / flush routing).
    B1 = 1,
}

impl Layer {
    pub const ALL: [Layer; 2] = [Layer::B16, Layer::B1];

    pub fn index(self) -> usize {
        self as usize
    }
}

/// Side of a tile. `N` points towards row 0 (the IO row).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Side {
    N = 0,
    E = 1,
    S = 2,
    W = 3,
}

impl Side {
    pub const ALL: [Side; 4] = [Side::N, Side::E, Side::S, Side::W];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Side {
        Side::ALL[i]
    }

    pub fn opposite(self) -> Side {
        match self {
            Side::N => Side::S,
            Side::E => Side::W,
            Side::S => Side::N,
            Side::W => Side::E,
        }
    }

    /// (dx, dy) of the neighbouring tile on this side.
    pub fn delta(self) -> (i32, i32) {
        match self {
            Side::N => (0, -1),
            Side::E => (1, 0),
            Side::S => (0, 1),
            Side::W => (-1, 0),
        }
    }

    pub fn is_horizontal(self) -> bool {
        matches!(self, Side::E | Side::W)
    }
}

/// Dense routing-resource node id.
pub type NodeId = u32;

/// Decoded node kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    SbIn { side: Side, track: u8 },
    SbOut { side: Side, track: u8 },
    CbIn { port: u8 },
    TileOut { port: u8 },
}

/// Fully decoded node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Node {
    pub tile: TileCoord,
    pub layer: Layer,
    pub kind: NodeKind,
}

/// Edge class, used by the delay model (paper Fig. 3: "enumerate all
/// possible data and clock paths at the tile level").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EdgeKind {
    /// SbIn -> SbOut through the switch-box mux (straight or turn).
    SbTurn,
    /// TileOut -> SbOut: the tile core driving onto a track.
    SbDrive,
    /// SbIn -> CbIn through the connection-box mux.
    CbTap,
    /// SbOut -> neighbouring tile's SbIn: the physical wire crossing the
    /// tile boundary. Delay depends on the two tile kinds and direction.
    Wire,
}

/// One directed RRG edge.
#[derive(Debug, Clone, Copy)]
pub struct Edge {
    pub dst: NodeId,
    pub kind: EdgeKind,
    /// Worst-case delay in picoseconds (filled by
    /// [`InterconnectGraph::annotate_delays`]).
    pub delay_ps: u32,
}

/// The routing-resource graph for a whole array.
pub struct InterconnectGraph {
    pub params: ArchParams,
    /// Max in-ports / out-ports per tile per layer (uniform layout).
    pub ports_in: usize,
    pub ports_out: usize,
    per_tile_layer: usize,
    num_nodes: usize,
    // CSR fanout.
    offsets: Vec<u32>,
    edges: Vec<Edge>,
    // CSR fanin (dst-indexed list of (src, edge index)).
    fanin_offsets: Vec<u32>,
    fanin: Vec<(NodeId, u32)>,
}

impl InterconnectGraph {
    /// Build the RRG topology for an architecture. Delays are zero until
    /// [`annotate_delays`](Self::annotate_delays) is called.
    pub fn build(params: &ArchParams) -> InterconnectGraph {
        let t = params.tracks;
        let ports_in = params.data_in_ports.max(params.bit_in_ports);
        let ports_out = params.data_out_ports.max(params.bit_out_ports);
        let per_tile_layer = 8 * t + ports_in + ports_out;
        let num_nodes = params.num_tiles() * 2 * per_tile_layer;

        let mut g = InterconnectGraph {
            params: params.clone(),
            ports_in,
            ports_out,
            per_tile_layer,
            num_nodes,
            offsets: Vec::new(),
            edges: Vec::new(),
            fanin_offsets: Vec::new(),
            fanin: Vec::new(),
        };

        // Gather edges per source node, then build CSR.
        let mut adj: Vec<Vec<Edge>> = vec![Vec::new(); num_nodes];
        for tile in params.all_tiles() {
            for layer in Layer::ALL {
                g.build_tile_edges(tile, layer, &mut adj);
            }
        }

        let mut offsets = Vec::with_capacity(num_nodes + 1);
        let mut edges = Vec::new();
        offsets.push(0u32);
        for a in &adj {
            edges.extend_from_slice(a);
            offsets.push(edges.len() as u32);
        }
        g.offsets = offsets;
        g.edges = edges;
        g.rebuild_fanin();
        g
    }

    fn rebuild_fanin(&mut self) {
        let mut fan: Vec<Vec<(NodeId, u32)>> = vec![Vec::new(); self.num_nodes];
        for src in 0..self.num_nodes {
            let (lo, hi) = (self.offsets[src] as usize, self.offsets[src + 1] as usize);
            for ei in lo..hi {
                let e = self.edges[ei];
                fan[e.dst as usize].push((src as NodeId, ei as u32));
            }
        }
        let mut offsets = Vec::with_capacity(self.num_nodes + 1);
        let mut flat = Vec::new();
        offsets.push(0u32);
        for f in &fan {
            flat.extend_from_slice(f);
            offsets.push(flat.len() as u32);
        }
        self.fanin_offsets = offsets;
        self.fanin = flat;
    }

    fn build_tile_edges(&self, tile: TileCoord, layer: Layer, adj: &mut Vec<Vec<Edge>>) {
        let t = self.params.tracks;
        // SbIn -> SbOut (straight + turns, same track, not back out the
        // incoming side).
        for side_in in Side::ALL {
            for track in 0..t {
                let src =
                    self.node_id(tile, layer, NodeKind::SbIn { side: side_in, track: track as u8 });
                for side_out in Side::ALL {
                    if side_out == side_in {
                        continue;
                    }
                    let dst = self.node_id(
                        tile,
                        layer,
                        NodeKind::SbOut { side: side_out, track: track as u8 },
                    );
                    adj[src as usize].push(Edge { dst, kind: EdgeKind::SbTurn, delay_ps: 0 });
                }
                // SbIn -> CbIn taps.
                for port in 0..self.ports_in {
                    let dst = self.node_id(tile, layer, NodeKind::CbIn { port: port as u8 });
                    adj[src as usize].push(Edge { dst, kind: EdgeKind::CbTap, delay_ps: 0 });
                }
            }
        }
        // TileOut -> SbOut. Output port p drives tracks where
        // track % ports_out == p (keeps SB mux sizes realistic while every
        // port can reach every side).
        for port in 0..self.ports_out {
            let src = self.node_id(tile, layer, NodeKind::TileOut { port: port as u8 });
            for side in Side::ALL {
                for track in 0..t {
                    if track % self.ports_out != port {
                        continue;
                    }
                    let dst =
                        self.node_id(tile, layer, NodeKind::SbOut { side, track: track as u8 });
                    adj[src as usize].push(Edge { dst, kind: EdgeKind::SbDrive, delay_ps: 0 });
                }
            }
        }
        // SbOut -> neighbour SbIn (the inter-tile wire).
        for side in Side::ALL {
            let (dx, dy) = side.delta();
            let nx = tile.x as i32 + dx;
            let ny = tile.y as i32 + dy;
            if !self.params.in_bounds(nx, ny) {
                continue;
            }
            let ntile = TileCoord::new(nx as usize, ny as usize);
            for track in 0..t {
                let src = self.node_id(tile, layer, NodeKind::SbOut { side, track: track as u8 });
                let dst = self.node_id(
                    ntile,
                    layer,
                    NodeKind::SbIn { side: side.opposite(), track: track as u8 },
                );
                adj[src as usize].push(Edge { dst, kind: EdgeKind::Wire, delay_ps: 0 });
            }
        }
    }

    /// Encode a node id.
    pub fn node_id(&self, tile: TileCoord, layer: Layer, kind: NodeKind) -> NodeId {
        let t = self.params.tracks;
        let local = match kind {
            NodeKind::SbIn { side, track } => side.index() * t + track as usize,
            NodeKind::SbOut { side, track } => 4 * t + side.index() * t + track as usize,
            NodeKind::CbIn { port } => 8 * t + port as usize,
            NodeKind::TileOut { port } => 8 * t + self.ports_in + port as usize,
        };
        debug_assert!(local < self.per_tile_layer);
        (((self.params.tile_index(tile) * 2) + layer.index()) * self.per_tile_layer + local)
            as NodeId
    }

    /// Decode a node id.
    pub fn decode(&self, id: NodeId) -> Node {
        let t = self.params.tracks;
        let id = id as usize;
        let local = id % self.per_tile_layer;
        let rest = id / self.per_tile_layer;
        let layer = if rest % 2 == 0 { Layer::B16 } else { Layer::B1 };
        let tidx = rest / 2;
        let tile = TileCoord::new(tidx % self.params.cols, tidx / self.params.cols);
        let kind = if local < 4 * t {
            NodeKind::SbIn { side: Side::from_index(local / t), track: (local % t) as u8 }
        } else if local < 8 * t {
            let l = local - 4 * t;
            NodeKind::SbOut { side: Side::from_index(l / t), track: (l % t) as u8 }
        } else if local < 8 * t + self.ports_in {
            NodeKind::CbIn { port: (local - 8 * t) as u8 }
        } else {
            NodeKind::TileOut { port: (local - 8 * t - self.ports_in) as u8 }
        };
        Node { tile, layer, kind }
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Forward adjacency of a node.
    pub fn fanout(&self, id: NodeId) -> &[Edge] {
        let (lo, hi) = (self.offsets[id as usize] as usize, self.offsets[id as usize + 1] as usize);
        &self.edges[lo..hi]
    }

    /// Fanin adjacency: (source node, edge index) pairs.
    pub fn fanin(&self, id: NodeId) -> &[(NodeId, u32)] {
        let (lo, hi) = (
            self.fanin_offsets[id as usize] as usize,
            self.fanin_offsets[id as usize + 1] as usize,
        );
        &self.fanin[lo..hi]
    }

    /// Edge by flat index (as referenced from fanin lists / route trees).
    pub fn edge(&self, idx: u32) -> Edge {
        self.edges[idx as usize]
    }

    /// Does this node carry a configurable pipelining register? (Every
    /// switch-box output does.)
    pub fn has_pipeline_reg(&self, id: NodeId) -> bool {
        matches!(self.decode(id).kind, NodeKind::SbOut { .. })
    }

    /// Assign per-edge worst-case delays from a generated delay library.
    pub fn annotate_delays(&mut self, lib: &super::delay::DelayLib) {
        // Decode endpoints first to avoid borrowing issues.
        let n = self.edges.len();
        for i in 0..n {
            let e = self.edges[i];
            // Reconstruct the source node by scanning offsets is O(log n)
            // via binary search on the CSR offsets.
            let src = self.edge_src(i as u32);
            let d = lib.edge_delay(self, src, &e);
            self.edges[i].delay_ps = d;
        }
    }

    /// Source node of an edge index (binary search over CSR offsets).
    pub fn edge_src(&self, edge_idx: u32) -> NodeId {
        let mut lo = 0usize;
        let mut hi = self.num_nodes;
        // Find the node whose [offsets[n], offsets[n+1]) contains edge_idx.
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.offsets[mid + 1] <= edge_idx {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as NodeId
    }

    /// All (src, dst, kind) tile-level path templates that the timing-model
    /// generator must characterize, expressed as distinct
    /// (EdgeKind, TileKind, horizontal?) combinations present in this
    /// architecture — the Fig. 3 "enumerate all paths of interest" step.
    pub fn enumerate_path_classes(&self) -> Vec<(EdgeKind, TileKind, bool)> {
        let mut out = Vec::new();
        for kind in [TileKind::Pe, TileKind::Mem, TileKind::Io] {
            for horiz in [false, true] {
                for ek in [EdgeKind::SbTurn, EdgeKind::SbDrive, EdgeKind::CbTap, EdgeKind::Wire] {
                    out.push((ek, kind, horiz));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> InterconnectGraph {
        InterconnectGraph::build(&ArchParams::tiny(3, 4))
    }

    #[test]
    fn id_roundtrip_all_nodes() {
        let g = tiny_graph();
        for id in 0..g.num_nodes() as NodeId {
            let n = g.decode(id);
            assert_eq!(g.node_id(n.tile, n.layer, n.kind), id);
        }
    }

    #[test]
    fn edge_src_consistent() {
        let g = tiny_graph();
        for src in 0..g.num_nodes() as NodeId {
            let lo = g.offsets[src as usize];
            let hi = g.offsets[src as usize + 1];
            for ei in lo..hi {
                assert_eq!(g.edge_src(ei), src);
            }
        }
    }

    #[test]
    fn no_uturns_in_sb() {
        let g = tiny_graph();
        for id in 0..g.num_nodes() as NodeId {
            let n = g.decode(id);
            if let NodeKind::SbIn { side, .. } = n.kind {
                for e in g.fanout(id) {
                    if e.kind == EdgeKind::SbTurn {
                        let d = g.decode(e.dst);
                        if let NodeKind::SbOut { side: out_side, .. } = d.kind {
                            assert_ne!(out_side, side, "u-turn at {:?}", n);
                        } else {
                            panic!("SbTurn edge must end at SbOut");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn wires_connect_adjacent_tiles_same_track() {
        let g = tiny_graph();
        for id in 0..g.num_nodes() as NodeId {
            let n = g.decode(id);
            if let NodeKind::SbOut { side, track } = n.kind {
                for e in g.fanout(id) {
                    assert_eq!(e.kind, EdgeKind::Wire, "SbOut fans out only via wires");
                    let d = g.decode(e.dst);
                    assert_eq!(d.layer, n.layer);
                    match d.kind {
                        NodeKind::SbIn { side: in_side, track: in_track } => {
                            assert_eq!(in_side, side.opposite());
                            assert_eq!(in_track, track);
                            let (dx, dy) = side.delta();
                            assert_eq!(d.tile.x as i32, n.tile.x as i32 + dx);
                            assert_eq!(d.tile.y as i32, n.tile.y as i32 + dy);
                        }
                        _ => panic!("wire must end at SbIn"),
                    }
                }
            }
        }
    }

    #[test]
    fn boundary_tiles_have_no_outward_wires() {
        let g = tiny_graph();
        let p = &g.params;
        // North-west corner: SbOut N and W have no wire edges.
        let corner = TileCoord::new(0, 0);
        for side in [Side::N, Side::W] {
            let id = g.node_id(corner, Layer::B16, NodeKind::SbOut { side, track: 0 });
            assert!(g.fanout(id).is_empty());
        }
        // Interior tile: all four sides wired.
        let mid = TileCoord::new(1, 1);
        for side in Side::ALL {
            let id = g.node_id(mid, Layer::B16, NodeKind::SbOut { side, track: 0 });
            assert_eq!(g.fanout(id).len(), 1);
        }
        let _ = p;
    }

    #[test]
    fn fanin_matches_fanout() {
        let g = tiny_graph();
        let mut count_from_fanout = 0usize;
        for src in 0..g.num_nodes() as NodeId {
            count_from_fanout += g.fanout(src).len();
        }
        let count_from_fanin: usize =
            (0..g.num_nodes() as NodeId).map(|n| g.fanin(n).len()).sum();
        assert_eq!(count_from_fanout, count_from_fanin);
        // Spot-check a CbIn: fanin must all be CbTap edges from SbIn.
        let cb = g.node_id(TileCoord::new(1, 1), Layer::B16, NodeKind::CbIn { port: 0 });
        assert!(!g.fanin(cb).is_empty());
        for &(src, ei) in g.fanin(cb) {
            assert_eq!(g.edge(ei).kind, EdgeKind::CbTap);
            assert!(matches!(g.decode(src).kind, NodeKind::SbIn { .. }));
        }
    }

    #[test]
    fn tileout_reaches_all_sides() {
        let g = tiny_graph();
        let out = g.node_id(TileCoord::new(1, 1), Layer::B16, NodeKind::TileOut { port: 0 });
        let mut sides_reached = std::collections::HashSet::new();
        for e in g.fanout(out) {
            assert_eq!(e.kind, EdgeKind::SbDrive);
            if let NodeKind::SbOut { side, track } = g.decode(e.dst).kind {
                assert_eq!(track as usize % g.ports_out, 0);
                sides_reached.insert(side.index());
            }
        }
        assert_eq!(sides_reached.len(), 4);
    }

    #[test]
    fn pipeline_regs_only_on_sbout() {
        let g = tiny_graph();
        for id in 0..g.num_nodes() as NodeId {
            let is_sbout = matches!(g.decode(id).kind, NodeKind::SbOut { .. });
            assert_eq!(g.has_pipeline_reg(id), is_sbout);
        }
    }

    #[test]
    fn paper_size_graph_builds() {
        let g = InterconnectGraph::build(&ArchParams::paper());
        // 32 cols * 17 rows * 2 layers * (8*5 + 4 + 3) nodes.
        assert_eq!(g.num_nodes(), 32 * 17 * 2 * 47);
        assert!(g.num_edges() > 100_000);
    }
}
