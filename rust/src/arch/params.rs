//! Architecture parameters for the target CGRA.
//!
//! Defaults reproduce the paper's evaluation array: 32 columns x 16 rows of
//! core tiles (every 4th column a MEM column: 384 PE + 128 MEM) plus a row
//! of IO tiles along the top edge, 5 routing tracks per side on each of the
//! two wiring layers (16-bit data and 1-bit control), a pipelining register
//! on every switch-box output, registers on every PE input, and a small
//! register file in every PE tile usable as a variable-length shift
//! register.

/// Kind of a tile in the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TileKind {
    /// Processing element: ALU + input registers + register file.
    Pe,
    /// Memory tile: SRAM + address/schedule generators.
    Mem,
    /// IO tile on the array boundary (streams data in/out of the global
    /// buffer).
    Io,
}

/// Tile coordinate. `x` is the column, `y` the row; `y == 0` is the IO row,
/// core tiles occupy `1..=rows`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileCoord {
    pub x: u16,
    pub y: u16,
}

impl TileCoord {
    pub fn new(x: usize, y: usize) -> TileCoord {
        TileCoord { x: x as u16, y: y as u16 }
    }

    /// Manhattan distance between tile centers, in tiles.
    pub fn manhattan(self, other: TileCoord) -> usize {
        (self.x as i32 - other.x as i32).unsigned_abs() as usize
            + (self.y as i32 - other.y as i32).unsigned_abs() as usize
    }
}

/// Full architecture parameter set.
#[derive(Debug, Clone)]
pub struct ArchParams {
    /// Core rows (excluding the IO row).
    pub rows: usize,
    /// Columns.
    pub cols: usize,
    /// Every `mem_col_period`-th column (1-based: columns where
    /// `(x + 1) % mem_col_period == 0`) is a MEM column.
    pub mem_col_period: usize,
    /// Routing tracks per side, per layer.
    pub tracks: usize,
    /// Data input ports per core tile (16-bit layer CB count).
    pub data_in_ports: usize,
    /// Data output ports per core tile.
    pub data_out_ports: usize,
    /// 1-bit input ports per core tile (valid/ready/control).
    pub bit_in_ports: usize,
    /// 1-bit output ports per core tile.
    pub bit_out_ports: usize,
    /// Register-file words per PE tile (usable as variable-length shift
    /// registers by the register-chain transform).
    pub regfile_words: usize,
    /// Depth of the FIFOs inserted when pipelining sparse (ready-valid)
    /// applications.
    pub fifo_depth: usize,
    /// Whether the flush broadcast signal is hardened into a dedicated
    /// per-column network (paper §VI) instead of being routed on the
    /// configurable interconnect.
    pub hardened_flush: bool,
}

impl Default for ArchParams {
    fn default() -> Self {
        ArchParams {
            rows: 16,
            cols: 32,
            mem_col_period: 4,
            tracks: 5,
            data_in_ports: 2,
            data_out_ports: 2,
            // 1-bit ports carry valid/ready/flush/select. Convention:
            //   CbIn B1 ports 0..1  = valid / control for data ports 0..1
            //   CbIn B1 ports 2..3  = ready returns from this node's sinks
            //   TileOut B1 port 0   = valid / 1-bit data out
            //   TileOut B1 ports 1..2 = ready outputs for data in-ports 0..1
            bit_in_ports: 4,
            bit_out_ports: 3,
            regfile_words: 32,
            fifo_depth: 2,
            hardened_flush: false,
        }
    }
}

impl ArchParams {
    /// The paper's evaluation array (32x16, 384 PE + 128 MEM).
    pub fn paper() -> ArchParams {
        ArchParams::default()
    }

    /// Routing-track override (consuming, chainable) — an `explore` sweep
    /// axis: fewer tracks shrink the switch boxes but risk congestion.
    pub fn with_tracks(mut self, tracks: usize) -> ArchParams {
        self.tracks = tracks;
        self
    }

    /// Register-file-words override (consuming, chainable) — an `explore`
    /// sweep axis bounding the register-chain transform.
    pub fn with_regfile_words(mut self, words: usize) -> ArchParams {
        self.regfile_words = words;
        self
    }

    /// Sparse-FIFO-depth override (consuming, chainable) — an `explore`
    /// sweep axis for the ready-valid pipelining variant (§VII).
    pub fn with_fifo_depth(mut self, depth: usize) -> ArchParams {
        self.fifo_depth = depth;
        self
    }

    /// A small array for fast unit tests.
    pub fn tiny(rows: usize, cols: usize) -> ArchParams {
        ArchParams { rows, cols, ..ArchParams::default() }
    }

    /// Total grid height including the IO row.
    pub fn grid_rows(&self) -> usize {
        self.rows + 1
    }

    /// Tile kind at a coordinate. Row 0 is the IO row.
    pub fn tile_kind(&self, c: TileCoord) -> TileKind {
        if c.y == 0 {
            TileKind::Io
        } else if (c.x as usize + 1) % self.mem_col_period == 0 {
            TileKind::Mem
        } else {
            TileKind::Pe
        }
    }

    /// Is this a valid coordinate on the grid?
    pub fn in_bounds(&self, x: i32, y: i32) -> bool {
        x >= 0 && (x as usize) < self.cols && y >= 0 && (y as usize) < self.grid_rows()
    }

    /// Number of core tiles of each kind: (PE count, MEM count).
    pub fn core_tile_counts(&self) -> (usize, usize) {
        let mem_cols = (0..self.cols).filter(|x| (x + 1) % self.mem_col_period == 0).count();
        let mem = mem_cols * self.rows;
        (self.cols * self.rows - mem, mem)
    }

    /// Iterate all tile coordinates (including the IO row).
    pub fn all_tiles(&self) -> impl Iterator<Item = TileCoord> + '_ {
        let cols = self.cols;
        (0..self.grid_rows()).flat_map(move |y| (0..cols).map(move |x| TileCoord::new(x, y)))
    }

    /// Iterate core (PE/MEM) tile coordinates.
    pub fn core_tiles(&self) -> impl Iterator<Item = TileCoord> + '_ {
        self.all_tiles().filter(|c| c.y != 0)
    }

    /// Linear tile index for dense arrays over the grid.
    pub fn tile_index(&self, c: TileCoord) -> usize {
        c.y as usize * self.cols + c.x as usize
    }

    pub fn num_tiles(&self) -> usize {
        self.grid_rows() * self.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_array_tile_counts() {
        let p = ArchParams::paper();
        let (pe, mem) = p.core_tile_counts();
        assert_eq!(pe, 384);
        assert_eq!(mem, 128);
        assert_eq!(p.num_tiles(), 32 * 17);
    }

    #[test]
    fn tile_kinds() {
        let p = ArchParams::paper();
        assert_eq!(p.tile_kind(TileCoord::new(0, 0)), TileKind::Io);
        assert_eq!(p.tile_kind(TileCoord::new(0, 1)), TileKind::Pe);
        // Columns 3, 7, 11, ... are MEM ((x+1) % 4 == 0).
        assert_eq!(p.tile_kind(TileCoord::new(3, 1)), TileKind::Mem);
        assert_eq!(p.tile_kind(TileCoord::new(7, 5)), TileKind::Mem);
        assert_eq!(p.tile_kind(TileCoord::new(4, 5)), TileKind::Pe);
    }

    #[test]
    fn builder_overrides() {
        let p = ArchParams::paper().with_tracks(3).with_regfile_words(64).with_fifo_depth(4);
        assert_eq!(p.tracks, 3);
        assert_eq!(p.regfile_words, 64);
        assert_eq!(p.fifo_depth, 4);
        // Everything else keeps the paper values.
        assert_eq!(p.cols, 32);
        assert_eq!(p.rows, 16);
    }

    #[test]
    fn manhattan_distance() {
        let a = TileCoord::new(1, 2);
        let b = TileCoord::new(4, 0);
        assert_eq!(a.manhattan(b), 5);
        assert_eq!(b.manhattan(a), 5);
        assert_eq!(a.manhattan(a), 0);
    }

    #[test]
    fn bounds() {
        let p = ArchParams::tiny(4, 8);
        assert!(p.in_bounds(0, 0));
        assert!(p.in_bounds(7, 4));
        assert!(!p.in_bounds(8, 0));
        assert!(!p.in_bounds(0, 5));
        assert!(!p.in_bounds(-1, 0));
    }

    #[test]
    fn iterators_cover_grid() {
        let p = ArchParams::tiny(2, 3);
        assert_eq!(p.all_tiles().count(), 3 * 3); // 2 core rows + IO row
        assert_eq!(p.core_tiles().count(), 2 * 3);
        let idx: Vec<usize> = p.all_tiles().map(|c| p.tile_index(c)).collect();
        let mut sorted = idx.clone();
        sorted.sort();
        assert_eq!(idx, sorted); // row-major enumeration
    }
}
