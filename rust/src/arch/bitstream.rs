//! Configuration space and bitstream encoding.
//!
//! Every configurable feature of the fabric maps to a (address, data) word
//! pair, mirroring how real CGRAs (Amber/Garnet) are configured through a
//! word-addressed configuration bus. The address encodes (tile, feature
//! register); the data encodes the feature value.
//!
//! The encoding is fully invertible: the fabric simulator reconstructs tile
//! behaviour purely from a [`Bitstream`], which lets integration tests prove
//! `place+route+pipeline -> encode -> decode -> simulate` equals the DFG
//! reference semantics, and lets the low-unrolling-duplication pass (§V-E)
//! operate directly on configuration words.

use std::collections::BTreeMap;

use super::canal::{Layer, Side};
use super::params::{ArchParams, TileCoord};

/// A configurable feature within one tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Feature {
    /// Switch-box output mux select for (layer, side, track). Value: see
    /// [`SbSource`] encoding.
    SbSel { layer: Layer, side: Side, track: u8 },
    /// Switch-box output pipelining register enable (0/1).
    SbRegEn { layer: Layer, side: Side, track: u8 },
    /// Connection-box select for input `port`: value = side*tracks + track
    /// of the tapped incoming track, or [`CB_UNUSED`].
    CbSel { layer: Layer, port: u8 },
    /// PE opcode (see `dfg::ir::AluOp` encoding).
    PeOp,
    /// PE input-register enable for data port `port` (compute pipelining).
    PeInRegEn { port: u8 },
    /// PE constant operand (16-bit immediate).
    PeConst,
    /// Number of extra register-file delay words on input `port`
    /// (variable-length shift register, §V-A Fig. 4 right).
    PeRfDelay { port: u8 },
    /// MEM tile mode (0 = unused, 1 = ROM, 2 = line buffer, 3 = scheduled
    /// read/write, 4 = FIFO).
    MemMode,
    /// MEM schedule parameter word `idx` (extents/strides/offset).
    MemParam { idx: u8 },
    /// IO tile mode (0 = unused, 1 = input stream, 2 = output stream).
    IoMode,
    /// Sparse ready-valid FIFO enable on input `port` (§VII pipelining of
    /// sparse applications inserts FIFOs rather than bare registers).
    FifoEn { port: u8 },
}

/// CB select value meaning "port unused".
pub const CB_UNUSED: u32 = 0xFFFF;

/// Decoded switch-box output source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SbSource {
    /// Driven by the track arriving on `side` (same track number).
    In { side: Side },
    /// Driven by tile output `port`.
    TileOut { port: u8 },
    /// Mux not configured (output floats; never sampled).
    Unused,
}

/// The three incoming sides that can drive an output on `out_side`,
/// in canonical (side-index ascending) order — defines SbSel values 0..2.
pub fn sb_in_sides(out_side: Side) -> [Side; 3] {
    let mut v = [Side::N; 3];
    let mut i = 0;
    for s in Side::ALL {
        if s != out_side {
            v[i] = s;
            i += 1;
        }
    }
    v
}

/// Encode an [`SbSource`] to a config value.
pub fn encode_sb_source(out_side: Side, src: SbSource) -> u32 {
    match src {
        SbSource::Unused => 0xFF,
        SbSource::In { side } => {
            let sides = sb_in_sides(out_side);
            sides.iter().position(|&s| s == side).expect("invalid sb source side") as u32
        }
        SbSource::TileOut { port } => 3 + port as u32,
    }
}

/// Decode a config value back to an [`SbSource`].
pub fn decode_sb_source(out_side: Side, value: u32) -> SbSource {
    if value == 0xFF {
        SbSource::Unused
    } else if value < 3 {
        SbSource::In { side: sb_in_sides(out_side)[value as usize] }
    } else {
        SbSource::TileOut { port: (value - 3) as u8 }
    }
}

/// Number of MEM schedule parameter words.
pub const MEM_PARAM_WORDS: u8 = 12;

/// Deterministic feature -> register-index mapping for one tile.
pub struct ConfigSpace {
    tracks: usize,
    ports_in: usize,
    regs_per_tile: usize,
}

impl ConfigSpace {
    pub fn new(params: &ArchParams) -> ConfigSpace {
        let tracks = params.tracks;
        let ports_in = params.data_in_ports.max(params.bit_in_ports);
        let mut cs = ConfigSpace { tracks, ports_in, regs_per_tile: 0 };
        // regs_per_tile = index one past the last feature.
        cs.regs_per_tile = cs.feature_index(Feature::FifoEn { port: (ports_in - 1) as u8 }) + 1;
        cs
    }

    /// Register index of a feature within its tile.
    pub fn feature_index(&self, f: Feature) -> usize {
        let t = self.tracks;
        let p = self.ports_in;
        let sb_block = 2 * 4 * t; // layers * sides * tracks
        match f {
            Feature::SbSel { layer, side, track } => {
                layer.index() * 4 * t + side.index() * t + track as usize
            }
            Feature::SbRegEn { layer, side, track } => {
                sb_block + layer.index() * 4 * t + side.index() * t + track as usize
            }
            Feature::CbSel { layer, port } => 2 * sb_block + layer.index() * p + port as usize,
            Feature::PeOp => 2 * sb_block + 2 * p,
            Feature::PeInRegEn { port } => 2 * sb_block + 2 * p + 1 + port as usize,
            Feature::PeConst => 2 * sb_block + 3 * p + 1,
            Feature::PeRfDelay { port } => 2 * sb_block + 3 * p + 2 + port as usize,
            Feature::MemMode => 2 * sb_block + 4 * p + 2,
            Feature::MemParam { idx } => 2 * sb_block + 4 * p + 3 + idx as usize,
            Feature::IoMode => 2 * sb_block + 4 * p + 3 + MEM_PARAM_WORDS as usize,
            Feature::FifoEn { port } => {
                2 * sb_block + 4 * p + 4 + MEM_PARAM_WORDS as usize + port as usize
            }
        }
    }

    /// Inverse of [`feature_index`](Self::feature_index).
    pub fn decode_index(&self, idx: usize) -> Feature {
        let t = self.tracks;
        let p = self.ports_in;
        let sb_block = 2 * 4 * t;
        let layer_of = |i: usize| if i / (4 * t) == 0 { Layer::B16 } else { Layer::B1 };
        if idx < sb_block {
            let l = layer_of(idx);
            let r = idx % (4 * t);
            Feature::SbSel { layer: l, side: Side::from_index(r / t), track: (r % t) as u8 }
        } else if idx < 2 * sb_block {
            let i = idx - sb_block;
            let l = layer_of(i);
            let r = i % (4 * t);
            Feature::SbRegEn { layer: l, side: Side::from_index(r / t), track: (r % t) as u8 }
        } else if idx < 2 * sb_block + 2 * p {
            let i = idx - 2 * sb_block;
            Feature::CbSel {
                layer: if i / p == 0 { Layer::B16 } else { Layer::B1 },
                port: (i % p) as u8,
            }
        } else if idx == 2 * sb_block + 2 * p {
            Feature::PeOp
        } else if idx < 2 * sb_block + 3 * p + 1 {
            Feature::PeInRegEn { port: (idx - (2 * sb_block + 2 * p + 1)) as u8 }
        } else if idx == 2 * sb_block + 3 * p + 1 {
            Feature::PeConst
        } else if idx < 2 * sb_block + 4 * p + 2 {
            Feature::PeRfDelay { port: (idx - (2 * sb_block + 3 * p + 2)) as u8 }
        } else if idx == 2 * sb_block + 4 * p + 2 {
            Feature::MemMode
        } else if idx < 2 * sb_block + 4 * p + 3 + MEM_PARAM_WORDS as usize {
            Feature::MemParam { idx: (idx - (2 * sb_block + 4 * p + 3)) as u8 }
        } else if idx == 2 * sb_block + 4 * p + 3 + MEM_PARAM_WORDS as usize {
            Feature::IoMode
        } else {
            Feature::FifoEn {
                port: (idx - (2 * sb_block + 4 * p + 4 + MEM_PARAM_WORDS as usize)) as u8,
            }
        }
    }

    pub fn regs_per_tile(&self) -> usize {
        self.regs_per_tile
    }
}

/// A full-array configuration: sparse map of (addr -> data). Unset features
/// hold their reset value (0 / unused).
#[derive(Debug, Clone, Default)]
pub struct Bitstream {
    /// addr -> data. BTreeMap keeps the serialized order deterministic.
    words: BTreeMap<u64, u32>,
}

impl Bitstream {
    pub fn new() -> Bitstream {
        Bitstream::default()
    }

    fn addr(params: &ArchParams, cs: &ConfigSpace, tile: TileCoord, f: Feature) -> u64 {
        (params.tile_index(tile) as u64) * cs.regs_per_tile() as u64 + cs.feature_index(f) as u64
    }

    pub fn set(
        &mut self,
        params: &ArchParams,
        cs: &ConfigSpace,
        tile: TileCoord,
        f: Feature,
        value: u32,
    ) {
        let a = Self::addr(params, cs, tile, f);
        if value == 0 {
            self.words.remove(&a);
        } else {
            self.words.insert(a, value);
        }
    }

    pub fn get(&self, params: &ArchParams, cs: &ConfigSpace, tile: TileCoord, f: Feature) -> u32 {
        self.words.get(&Self::addr(params, cs, tile, f)).copied().unwrap_or(0)
    }

    /// Number of non-reset configuration words.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Iterate raw (addr, data) words.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u32)> + '_ {
        self.words.iter().map(|(&a, &d)| (a, d))
    }

    /// Iterate (tile, feature, value) triples.
    pub fn features<'a>(
        &'a self,
        params: &'a ArchParams,
        cs: &'a ConfigSpace,
    ) -> impl Iterator<Item = (TileCoord, Feature, u32)> + 'a {
        self.words.iter().map(move |(&a, &d)| {
            let tidx = (a / cs.regs_per_tile() as u64) as usize;
            let fidx = (a % cs.regs_per_tile() as u64) as usize;
            let tile = TileCoord::new(tidx % params.cols, tidx / params.cols);
            (tile, cs.decode_index(fidx), d)
        })
    }

    /// Canonical text serialization: one `addr data` pair per line, both
    /// zero-padded hex, in ascending address order. Deterministic (the
    /// word map is ordered), so two encodings of the same design are
    /// byte-identical — the property `cascade encode --from-cache` is
    /// checked against.
    pub fn to_text(&self) -> String {
        let mut s = String::with_capacity(self.words.len() * 26);
        for (&a, &d) in &self.words {
            s.push_str(&format!("{a:016x} {d:08x}\n"));
        }
        s
    }

    /// Parse [`Self::to_text`] output. Rejects malformed lines and
    /// zero-valued words (a stored zero would silently differ from the
    /// reset-implies-absent encoding `set` maintains).
    pub fn from_text(text: &str) -> Result<Bitstream, String> {
        let mut words = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            let (a, d) = line
                .split_once(' ')
                .ok_or_else(|| format!("bitstream line {}: missing separator", i + 1))?;
            let addr = u64::from_str_radix(a, 16)
                .map_err(|_| format!("bitstream line {}: bad address '{a}'", i + 1))?;
            let data = u32::from_str_radix(d, 16)
                .map_err(|_| format!("bitstream line {}: bad data '{d}'", i + 1))?;
            if data == 0 {
                return Err(format!("bitstream line {}: zero word stored", i + 1));
            }
            if words.insert(addr, data).is_some() {
                return Err(format!("bitstream line {}: duplicate address", i + 1));
            }
        }
        Ok(Bitstream { words })
    }

    /// Copy the configuration of a rectangular region to another origin —
    /// the bitstream-level primitive behind low unrolling duplication
    /// (§V-E): PnR one unroll, then stamp its configuration across the
    /// array.
    pub fn duplicate_region(
        &mut self,
        params: &ArchParams,
        cs: &ConfigSpace,
        src_origin: TileCoord,
        size: (usize, usize),
        dst_origin: TileCoord,
    ) {
        let mut updates = Vec::new();
        for (tile, f, v) in self.features(params, cs) {
            let dx = tile.x as i64 - src_origin.x as i64;
            let dy = tile.y as i64 - src_origin.y as i64;
            if dx < 0 || dy < 0 || dx >= size.0 as i64 || dy >= size.1 as i64 {
                continue;
            }
            let nx = dst_origin.x as i64 + dx;
            let ny = dst_origin.y as i64 + dy;
            assert!(
                params.in_bounds(nx as i32, ny as i32),
                "duplicate_region target out of bounds"
            );
            let ntile = TileCoord::new(nx as usize, ny as usize);
            // Duplication must be kind-preserving: a PE config can only
            // land on a PE tile, MEM on MEM (guaranteed when the column
            // offset is a multiple of mem_col_period).
            assert_eq!(
                params.tile_kind(tile),
                params.tile_kind(ntile),
                "duplicate_region must map tiles onto the same kind"
            );
            updates.push((ntile, f, v));
        }
        for (tile, f, v) in updates {
            self.set(params, cs, tile, f, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ArchParams, ConfigSpace) {
        let p = ArchParams::paper();
        let cs = ConfigSpace::new(&p);
        (p, cs)
    }

    #[test]
    fn feature_index_roundtrip() {
        let (_p, cs) = setup();
        for idx in 0..cs.regs_per_tile() {
            let f = cs.decode_index(idx);
            assert_eq!(cs.feature_index(f), idx, "feature {f:?}");
        }
    }

    #[test]
    fn sb_source_roundtrip() {
        for out in Side::ALL {
            for src_side in Side::ALL {
                if src_side == out {
                    continue;
                }
                let v = encode_sb_source(out, SbSource::In { side: src_side });
                assert_eq!(decode_sb_source(out, v), SbSource::In { side: src_side });
            }
            for port in 0..2u8 {
                let v = encode_sb_source(out, SbSource::TileOut { port });
                assert_eq!(decode_sb_source(out, v), SbSource::TileOut { port });
            }
            assert_eq!(decode_sb_source(out, 0xFF), SbSource::Unused);
        }
    }

    #[test]
    fn set_get_roundtrip() {
        let (p, cs) = setup();
        let mut bs = Bitstream::new();
        let tile = TileCoord::new(5, 3);
        bs.set(&p, &cs, tile, Feature::PeOp, 7);
        bs.set(&p, &cs, tile, Feature::PeInRegEn { port: 1 }, 1);
        assert_eq!(bs.get(&p, &cs, tile, Feature::PeOp), 7);
        assert_eq!(bs.get(&p, &cs, tile, Feature::PeInRegEn { port: 1 }), 1);
        assert_eq!(bs.get(&p, &cs, tile, Feature::PeInRegEn { port: 0 }), 0);
        assert_eq!(bs.len(), 2);
    }

    #[test]
    fn setting_zero_clears() {
        let (p, cs) = setup();
        let mut bs = Bitstream::new();
        let tile = TileCoord::new(1, 1);
        bs.set(&p, &cs, tile, Feature::PeOp, 3);
        bs.set(&p, &cs, tile, Feature::PeOp, 0);
        assert!(bs.is_empty());
    }

    #[test]
    fn features_iteration_decodes() {
        let (p, cs) = setup();
        let mut bs = Bitstream::new();
        let tile = TileCoord::new(8, 2);
        bs.set(&p, &cs, tile, Feature::SbRegEn { layer: Layer::B1, side: Side::W, track: 3 }, 1);
        let feats: Vec<_> = bs.features(&p, &cs).collect();
        assert_eq!(feats.len(), 1);
        assert_eq!(feats[0].0, tile);
        assert_eq!(feats[0].1, Feature::SbRegEn { layer: Layer::B1, side: Side::W, track: 3 });
        assert_eq!(feats[0].2, 1);
    }

    #[test]
    fn duplicate_region_stamps_config() {
        let (p, cs) = setup();
        let mut bs = Bitstream::new();
        // Configure a 4x2 block at (0,1) (a PE/PE/PE/MEM column pattern).
        for x in 0..4 {
            for y in 1..3 {
                bs.set(&p, &cs, TileCoord::new(x, y), Feature::PeOp, (x + y) as u32);
            }
        }
        // Duplicate 4 columns right (preserves the MEM column phase).
        bs.duplicate_region(&p, &cs, TileCoord::new(0, 1), (4, 2), TileCoord::new(4, 1));
        for x in 0..4 {
            for y in 1..3 {
                assert_eq!(
                    bs.get(&p, &cs, TileCoord::new(x + 4, y), Feature::PeOp),
                    (x + y) as u32
                );
            }
        }
        assert_eq!(bs.len(), 16);
    }

    #[test]
    #[should_panic(expected = "same kind")]
    fn duplicate_region_rejects_kind_mismatch() {
        let (p, cs) = setup();
        let mut bs = Bitstream::new();
        bs.set(&p, &cs, TileCoord::new(0, 1), Feature::PeOp, 1);
        // Offset of 3 columns maps PE column 0 onto MEM column 3.
        bs.duplicate_region(&p, &cs, TileCoord::new(0, 1), (1, 1), TileCoord::new(3, 1));
    }

    #[test]
    fn text_serialization_round_trips_and_rejects_garbage() {
        let (p, cs) = setup();
        let mut bs = Bitstream::new();
        bs.set(&p, &cs, TileCoord::new(5, 3), Feature::PeOp, 7);
        bs.set(&p, &cs, TileCoord::new(0, 1), Feature::PeConst, 0xFFFF);
        bs.set(&p, &cs, TileCoord::new(8, 2), Feature::PeInRegEn { port: 1 }, 1);
        let text = bs.to_text();
        assert_eq!(text.lines().count(), 3);
        let back = Bitstream::from_text(&text).unwrap();
        assert_eq!(back.to_text(), text, "text form must round-trip byte-identically");
        assert_eq!(back.get(&p, &cs, TileCoord::new(5, 3), Feature::PeOp), 7);
        assert!(Bitstream::from_text("not hex\n").is_err());
        assert!(Bitstream::from_text("0123\n").is_err());
        assert!(Bitstream::from_text("0000000000000001 00000000\n").is_err(), "zero word");
        assert!(
            Bitstream::from_text("0000000000000001 1\n0000000000000001 2\n").is_err(),
            "duplicate address"
        );
        assert_eq!(Bitstream::from_text("").unwrap().len(), 0);
    }

    #[test]
    fn addresses_unique_across_tiles() {
        let (p, cs) = setup();
        let mut bs = Bitstream::new();
        bs.set(&p, &cs, TileCoord::new(0, 0), Feature::PeOp, 1);
        bs.set(&p, &cs, TileCoord::new(1, 0), Feature::PeOp, 2);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs.get(&p, &cs, TileCoord::new(0, 0), Feature::PeOp), 1);
        assert_eq!(bs.get(&p, &cs, TileCoord::new(1, 0), Feature::PeOp), 2);
    }
}
